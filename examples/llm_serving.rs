//! **The end-to-end driver** (DESIGN.md "End-to-end validation"): serve
//! batched requests through the full three-layer stack.
//!
//! * Tokens are generated for real: the AOT-compiled decode step
//!   (JAX transformer block + Pallas quantized GEMM, lowered once by
//!   `make artifacts`) executes through PJRT from Rust — Python is not
//!   running.
//! * Every kernel of the corresponding full-size LLM (GPT-3 6.7B) is
//!   mapped by the RACAM mapping engine, giving the simulated-hardware
//!   clock reported next to the host wall clock.
//! * Numerics are validated in-line: a sampled GEMM tile is executed both
//!   through the PJRT oracle and through the functional bit-serial
//!   simulator and compared exactly.
//!
//! ```bash
//! make artifacts && cargo run --release --example llm_serving
//! ```

use racam::config::{gpt3_6_7b, racam_paper, racam_tiny, Precision};
use racam::coordinator::{HloDecodeEngine, Request, Server};
use racam::metrics::fmt_ns;
use racam::pim::{gemm_reference, BlockExecutor};
use racam::runtime::{ArtifactSet, Runtime};
use racam::workloads::RacamSystem;

fn main() -> racam::Result<()> {
    let artifacts = ArtifactSet::discover();
    artifacts.require()?;

    // ---- Layer composition check: PJRT oracle vs bit-serial simulator.
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let (m, k, n) = (16usize, 64usize, 8usize);
    let oracle = rt.load_hlo_text(&artifacts.gemm(m, k, n))?;
    let x: Vec<i64> = (0..m * k).map(|i| (i as i64 * 37 % 255) - 127).collect();
    let w: Vec<i64> = (0..k * n).map(|i| (i as i64 * 101 % 255) - 127).collect();
    let xi: Vec<i32> = x.iter().map(|&v| v as i32).collect();
    let wi: Vec<i32> = w.iter().map(|&v| v as i32).collect();
    let from_pjrt = oracle.run_i32(&[(&xi, &[m as i64, k as i64]), (&wi, &[k as i64, n as i64])])?;
    let (from_sim, _) = BlockExecutor::new(&racam_tiny()).gemm(&x, &w, m, k, n, Precision::Int8);
    let reference = gemm_reference(&x, &w, m, k, n);
    assert_eq!(from_sim, reference);
    assert!(from_pjrt.iter().map(|&v| v as i64).eq(reference.iter().copied()));
    println!("✓ sampled {m}x{k}x{n} GEMM: PJRT oracle == bit-serial simulator == reference\n");

    // ---- Serve a batch of requests.
    let decode = rt.load_hlo_text(&artifacts.decode_step())?;
    let engine = HloDecodeEngine::new(decode, 64, 256);
    let spec = gpt3_6_7b(); // the model whose kernels the RACAM clock prices
    let mut server = Server::new(engine, RacamSystem::new(&racam_paper()), spec.clone(), 4);

    let prompts: Vec<Vec<u32>> = vec![
        vec![12, 74, 3, 99, 5],
        vec![200, 1],
        vec![7, 7, 7, 7, 7, 7, 7, 7],
        vec![42],
        vec![150, 30, 60, 90],
        vec![88, 11, 22],
    ];
    let new_tokens = 32;
    for (id, prompt) in prompts.iter().enumerate() {
        server.submit(Request::new(id as u64, prompt.clone(), new_tokens));
    }

    #[allow(clippy::disallowed_methods)] // example wall timing, display only
    let t0 = std::time::Instant::now();
    let report = server.run_to_completion()?;
    let wall = t0.elapsed();

    println!("served {} requests × {} tokens (batch ≤ 4, continuous batching):", prompts.len(), new_tokens);
    println!(
        "{:<4} {:>8} {:>14} {:>14}  first tokens",
        "req", "prompt", "sim TTFT", "sim total"
    );
    for r in &report.results {
        println!(
            "{:<4} {:>8} {:>14} {:>14}  {:?}",
            r.id,
            prompts[r.id as usize].len(),
            fmt_ns(r.sim_ttft_ns),
            fmt_ns(r.sim_total_ns),
            &r.tokens[..6.min(r.tokens.len())]
        );
    }
    println!("\ntotals:");
    println!("  tokens generated          : {}", report.total_tokens);
    println!("  host wall clock           : {:.2?} ({:.1} tok/s real PJRT execution)", wall, report.wall_tokens_per_s);
    println!(
        "  simulated RACAM throughput: {:.1} tok/s for {} (batch-1 hardware clock)",
        report.sim_tokens_per_s, spec.name
    );
    Ok(())
}
