//! Quickstart: the RACAM public API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Build (or load) a hardware configuration.
//! 2. Run a GEMM *functionally* through the bit-serial locality-buffer
//!    pipeline and check it against a reference.
//! 3. Search the full mapping space for a big GEMM and inspect the winner.
//! 4. Price an LLM decode step on RACAM vs. the H100/Proteus baselines.

use racam::baselines::{H100Model, ProteusModel};
use racam::config::{gpt3_175b, racam_paper, racam_tiny, MatmulShape, Precision};
use racam::mapping::{HwModel, MappingEngine};
use racam::metrics::fmt_ns;
use racam::pim::{gemm_reference, BlockExecutor};
use racam::workloads::{decode_kernels, stage_latency, RacamSystem};

fn main() -> racam::Result<()> {
    // ❶ Hardware configs are plain structs (JSON-loadable); presets match
    //    the paper's Table 4.
    let hw = racam_paper();
    hw.validate().expect("valid config");
    println!(
        "RACAM system: {} GB DRAM, {} PEs, {:.1} int8 TOPS peak\n",
        hw.capacity_bytes() >> 30,
        hw.total_pes(),
        hw.peak_tops(Precision::Int8),
    );

    // ❷ Functional bit-serial GEMM: every product computed bit-by-bit
    //    through the Fig. 6 locality-buffer schedule.
    let (m, k, n) = (4usize, 96usize, 3usize);
    let x: Vec<i64> = (0..m * k).map(|i| (i as i64 % 255) - 127).collect();
    let w: Vec<i64> = (0..k * n).map(|i| ((i * 31) as i64 % 255) - 127).collect();
    let mut exec = BlockExecutor::new(&racam_tiny());
    let (out, stats) = exec.gemm(&x, &w, m, k, n, Precision::Int8);
    assert_eq!(out, gemm_reference(&x, &w, m, k, n));
    println!(
        "❷ bit-serial {}x{}x{} GEMM ✓  ({} SIMD passes, {} row accesses = 4n per pass)",
        m, k, n, stats.passes, stats.row_accesses
    );

    // ❸ Automated mapping: parallel exhaustive search over 1458 candidates
    //    (bit-identical winner to the serial reference).
    let engine = MappingEngine::new(HwModel::new(&hw));
    let shape = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
    let r = engine.search(&shape).expect("non-degenerate GEMM evaluates");
    println!(
        "\n❸ best mapping for {}: {}\n   latency {} (compute {}, io {}), PE util {:.1}%, spread {:.0}x",
        shape.label(),
        r.best.mapping,
        fmt_ns(r.best.total_ns()),
        fmt_ns(r.best.compute_ns),
        fmt_ns(r.best.io_ns()),
        r.best.pe_util * 100.0,
        r.spread(),
    );

    // ❹ LLM decode step on the three systems — all priced through the
    //    same `CostModel` interface.
    let spec = gpt3_175b();
    let kernels = decode_kernels(&spec, 1024);
    let racam_sys = RacamSystem::new(&hw);
    let h100 = H100Model::for_model(&spec);
    let proteus = ProteusModel::for_model(&spec);
    let r_ns = stage_latency(&racam_sys, &kernels)?.total_ns();
    let h_ns = stage_latency(&h100, &kernels)?.total_ns();
    let p_ns = stage_latency(&proteus, &kernels)?.total_ns();
    println!("\n❹ {} decode token (ctx 1024):", spec.name);
    println!("   H100    {}", fmt_ns(h_ns));
    println!("   Proteus {}  ({:.3}x H100)", fmt_ns(p_ns), h_ns / p_ns);
    println!("   RACAM   {}  ({:.1}x H100)", fmt_ns(r_ns), h_ns / r_ns);
    Ok(())
}
