//! Mapping-space explorer: the paper's Fig. 15 study as an interactive
//! tool.  Evaluates every hierarchical × block mapping for a GEMM, prints
//! the per-block-mapping winners and the worst offenders, and shows why
//! automated search beats hand-crafted layouts.
//!
//! ```bash
//! cargo run --release --example mapping_explorer -- 1024 12288 12288
//! ```

use racam::config::{racam_paper, MatmulShape, Precision};
use racam::mapping::{HwModel, MappingEngine};
use racam::metrics::fmt_ns;
use std::collections::BTreeMap;

fn main() {
    let args: Vec<u64> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let (m, k, n) = match args.as_slice() {
        [m, k, n] => (*m, *k, *n),
        _ => (1024, 12288, 12288), // the paper's Fig. 15 shape
    };
    let shape = MatmulShape::new(m, k, n, Precision::Int8);
    let engine = MappingEngine::new(HwModel::new(&racam_paper()));

    #[allow(clippy::disallowed_methods)] // example wall timing, display only
    let t0 = std::time::Instant::now();
    let evals = engine.evaluate_all(&shape);
    let search_time = t0.elapsed();

    let mut sorted: Vec<_> = evals.iter().collect();
    sorted.sort_by(|a, b| a.total_ns().total_cmp(&b.total_ns()));
    let best = sorted[0];
    let worst = sorted[sorted.len() - 1];

    println!(
        "explored {} mappings of {} in {:.1} ms ({:.1} µs/candidate)\n",
        evals.len(),
        shape.label(),
        search_time.as_secs_f64() * 1e3,
        search_time.as_secs_f64() * 1e6 / evals.len() as f64
    );

    println!("top 5 mappings:");
    for e in sorted.iter().take(5) {
        println!(
            "  {:<55} {:>12}  util {:>5.1}%  io {:>5.1}%",
            e.mapping.to_string(),
            fmt_ns(e.total_ns()),
            e.pe_util * 100.0,
            e.io_ns() / e.total_ns() * 100.0
        );
    }
    println!("\nworst 3 mappings:");
    for e in sorted.iter().rev().take(3) {
        println!("  {:<55} {:>12}", e.mapping.to_string(), fmt_ns(e.total_ns()));
    }

    // Per-block-mapping ("array mapping") winners — the Fig. 15 grouping.
    let mut groups: BTreeMap<String, (f64, String)> = BTreeMap::new();
    for e in &evals {
        let entry = groups
            .entry(e.mapping.block.label())
            .or_insert((f64::INFINITY, String::new()));
        if e.total_ns() < entry.0 {
            *entry = (e.total_ns(), e.mapping.hier.to_string());
        }
    }
    println!("\nbest per array mapping:");
    for (label, (ns, hier)) in &groups {
        println!(
            "  {label:<7} {:>12}  ({:.2}x best)  with {hier}",
            fmt_ns(*ns),
            ns / best.total_ns()
        );
    }

    println!(
        "\nspread: worst/best = {:.1}x  (paper reports 510.85x for this shape)",
        worst.total_ns() / best.total_ns()
    );
    println!(
        "winner uses popcount column-reduction: {} (paper: RNCMK-style mappings win)",
        best.mapping.block.k_on_cols()
    );
}
