//! Open-loop serving under live traffic: generate a bursty request
//! stream, serve it through declaratively built clusters
//! (`config::ClusterSpec` → `coordinator::ClusterBuilder`) under FCFS and
//! EDF admission — and under the chunked-prefill + deadline-preemption
//! serving policy — grading every run with SLO tail metrics, then show a
//! prefill/decode-disaggregated cluster with KV-transfer accounting and
//! async admission of requests *while the run executes*.
//!
//! No PJRT artifacts needed (synthetic token engine):
//!
//! ```bash
//! cargo run --release --example traffic_serving
//! ```

use racam::config::{
    gpt3_6_7b, racam_paper, ArrivalProcess, ClusterSpec, LengthDist, SchedulerKind,
    ServingPolicy, TrafficSpec,
};
use racam::coordinator::{ClusterBuilder, Request, SyntheticEngine};
use racam::mapping::MappingService;
use racam::report::Table;
use racam::traffic::{generate, SloSummary};

fn serve(
    services: &[MappingService],
    stream: &[Request],
    label: &str,
    policy: ServingPolicy,
    scheduler: SchedulerKind,
) -> racam::Result<SloSummary> {
    let mut spec = ClusterSpec::unified(services.len(), 4);
    spec.groups[0].scheduler = scheduler;
    spec.groups[0].policy = policy;
    let mut coord =
        ClusterBuilder::with_spec_and_services(spec, gpt3_6_7b(), services.to_vec())?
            .build(|_| SyntheticEngine::new(64, 256));
    for req in stream {
        coord.submit(req.clone());
    }
    let report = coord.run_to_completion()?;
    println!(
        "{label}: served {} requests, {} tokens, {:.0} simulated tok/s ({} shed)",
        report.results.len(),
        report.total_tokens,
        report.sim_tokens_per_s,
        report.shards.iter().map(|s| s.shed).sum::<usize>()
    );
    Ok(SloSummary::from_report(&report))
}

fn main() -> racam::Result<()> {
    // A bursty open-loop stream: 200 req/s mean rate arriving in bursts of
    // 4, mixed prompt lengths, 100 ms end-to-end deadline.
    let spec = TrafficSpec {
        seed: 42,
        requests: 32,
        arrival: ArrivalProcess::Bursty { rate_per_s: 200.0, burst: 4 },
        prompt: LengthDist::LogNormal { median: 128, sigma: 0.8, cap: 1024 },
        output: LengthDist::Uniform { lo: 4, hi: 16 },
        deadline_ns: Some(100_000_000),
    };
    let stream = generate(&spec);
    println!(
        "generated {} requests over {:.1} ms of simulated arrivals\n",
        stream.len(),
        stream.last().expect("non-empty").arrival_ns as f64 / 1e6
    );

    // Two shards, each pricing against its honest 4-of-8-channel share of
    // the paper device; every policy prices identical kernels from the
    // same caches.
    let services = ClusterBuilder::new(ClusterSpec::unified(2, 4), &racam_paper(), gpt3_6_7b())?
        .services()
        .to_vec();
    let whole = ServingPolicy::whole_prefill();
    let fcfs = serve(&services, &stream, "fcfs", whole, SchedulerKind::Fcfs)?;
    let edf = serve(&services, &stream, "edf ", whole, SchedulerKind::Edf)?;
    // The interactive policy: 256-token prefill chunks so short requests
    // stop queueing behind long prompts, plus deadline preemption so EDF
    // sheds past-deadline work under overload instead of dragging tails.
    let interactive =
        serve(&services, &stream, "edf+i", ServingPolicy::interactive(), SchedulerKind::Edf)?;

    let mut t = Table::new("SLO comparison (same stream, same caches)", &SloSummary::table_headers());
    t.row(fcfs.table_row("fcfs/whole"));
    t.row(edf.table_row("edf/whole"));
    t.row(interactive.table_row("edf/chunk256+preempt"));
    println!("\n{}", t.render());

    // ---- Disaggregation: one prefill shard feeding one decode shard over
    // the simulated KV link, declared in four lines of spec.
    let mut coord = ClusterBuilder::new(
        ClusterSpec::disaggregated(1, 1, 4),
        &racam_paper(),
        gpt3_6_7b(),
    )?
    .build(|_| SyntheticEngine::new(64, 256));
    for req in &stream {
        coord.submit(req.clone());
    }
    let report = coord.run_to_completion()?;
    let slo = SloSummary::from_report(&report);
    println!(
        "disaggregated 1p+1d: {} requests, {} handoffs crossed the KV link",
        report.results.len(),
        slo.handoffs,
    );
    println!("{}", slo.utilization_table("group utilization (disaggregated)", false).render());

    // ---- Async admission: requests can arrive while the run executes.
    let mut coord = ClusterBuilder::with_spec_and_services(
        ClusterSpec::unified(2, 4),
        gpt3_6_7b(),
        services.clone(),
    )?
    .build(|_| SyntheticEngine::new(64, 256));
    for req in &stream[..8] {
        coord.submit(req.clone());
    }
    let mut intake = coord.intake();
    #[allow(clippy::disallowed_methods)] // example demonstrates async intake
    let submitter = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(10));
        for id in 0..4u64 {
            intake.submit(Request::new(1000 + id, vec![3, 1, 4], 8));
        }
        // Dropping the intake lets run_to_completion finish.
    });
    let report = coord.run_to_completion()?;
    submitter.join().expect("submitter thread");
    let live = report.results.iter().filter(|r| r.id >= 1000).count();
    println!(
        "async admission: {} pre-run + {live} live-submitted requests all completed",
        report.results.len() - live
    );
    println!(
        "mapping cache across everything: {} searches, {} hits",
        services[0].misses(),
        services[0].hits()
    );
    Ok(())
}
