//! End-to-end LLM inference study: reproduce the paper's headline
//! comparison (Figs. 9/10) from the public API, for one model, with full
//! per-kernel visibility.
//!
//! ```bash
//! cargo run --release --example llm_inference -- gpt3-175b
//! ```

use racam::baselines::{H100Model, ProteusModel};
use racam::config::{self, racam_paper, Scenario};
use racam::metrics::fmt_ns;
use racam::workloads::{
    decode_kernels, e2e_latency, prefill_kernels, stage_latency, RacamSystem,
};

fn main() -> racam::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "gpt3-175b".into());
    let spec = match model.as_str() {
        "gpt3-6.7b" => config::gpt3_6_7b(),
        "gpt3-175b" => config::gpt3_175b(),
        "llama3-8b" => config::llama3_8b(),
        "llama3-70b" => config::llama3_70b(),
        other => anyhow::bail!("unknown model '{other}'"),
    };
    println!(
        "{}: {} layers, hidden {}, {} heads, {:.1} GB int8 weights\n",
        spec.name,
        spec.layers,
        spec.hidden,
        spec.heads,
        spec.weight_bytes() as f64 / (1u64 << 30) as f64
    );

    // Per-kernel decode breakdown on RACAM (ctx = 1024).
    let racam_sys = RacamSystem::new(&racam_paper());
    println!("decode kernels (ctx 1024) on RACAM:");
    println!("{:<10} {:>22} {:>12} {:>10} {:>8}", "kernel", "shape", "latency", "mapping", "util");
    for k in decode_kernels(&spec, 1024) {
        let r = racam_sys.search(&k.shape).expect("decode kernels always map");
        println!(
            "{:<10} {:>22} {:>12} {:>10} {:>7.1}%",
            k.label,
            k.shape.label(),
            fmt_ns(r.best.total_ns() * k.count as f64),
            r.best.mapping.block.label(),
            r.best.pe_util * 100.0
        );
    }

    // Stage + scenario comparison across systems (uniform `CostModel`).
    let h100 = H100Model::for_model(&spec);
    let proteus = ProteusModel::for_model(&spec);
    println!("\n{:<22} {:>14} {:>14} {:>14} {:>9}", "workload", "H100", "Proteus", "RACAM", "speedup");
    let prefill = prefill_kernels(&spec, 1024);
    let decode = decode_kernels(&spec, 1024);
    let rows: Vec<(&str, f64, f64, f64)> = vec![
        (
            "prefill (1024 tok)",
            stage_latency(&h100, &prefill)?.total_ns(),
            stage_latency(&proteus, &prefill)?.total_ns(),
            stage_latency(&racam_sys, &prefill)?.total_ns(),
        ),
        (
            "decode token",
            stage_latency(&h100, &decode)?.total_ns(),
            stage_latency(&proteus, &decode)?.total_ns(),
            stage_latency(&racam_sys, &decode)?.total_ns(),
        ),
        (
            "e2e CodeGeneration",
            e2e_latency(&h100, &spec, &Scenario::CODE_GENERATION)?.total_ns(),
            e2e_latency(&proteus, &spec, &Scenario::CODE_GENERATION)?.total_ns(),
            e2e_latency(&racam_sys, &spec, &Scenario::CODE_GENERATION)?.total_ns(),
        ),
        (
            "e2e ContextUnderst.",
            e2e_latency(&h100, &spec, &Scenario::CONTEXT_UNDERSTANDING)?.total_ns(),
            e2e_latency(&proteus, &spec, &Scenario::CONTEXT_UNDERSTANDING)?.total_ns(),
            e2e_latency(&racam_sys, &spec, &Scenario::CONTEXT_UNDERSTANDING)?.total_ns(),
        ),
    ];
    for (label, h, p, r) in rows {
        println!(
            "{:<22} {:>14} {:>14} {:>14} {:>8.1}x",
            label,
            fmt_ns(h),
            fmt_ns(p),
            fmt_ns(r),
            h / r
        );
    }
    println!(
        "\nmapping cache: {} unique shapes searched, {} hits",
        racam_sys.service().misses(),
        racam_sys.service().hits()
    );
    Ok(())
}
