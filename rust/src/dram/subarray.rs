//! Functional bit-cell storage for one subarray, with the vertically
//! transposed layout bit-serial computation requires (paper §2.2).
//!
//! Rows are stored as packed `u64` words so the functional executor can
//! operate on 64 columns at a time — this word-packing is the simulator's
//! hot-path representation (see `pim::exec`).

/// One DRAM subarray: `rows × cols` bit cells.
///
/// Row-major bit-plane storage: `data[row]` is the row's bits packed LSB
/// first into `u64` words.
#[derive(Debug, Clone)]
pub struct Subarray {
    rows: u32,
    cols: u32,
    words_per_row: usize,
    data: Vec<Vec<u64>>,
    /// Currently open (activated) row, if any — used for ACT/PRE accounting.
    open_row: Option<u32>,
}

impl Subarray {
    pub fn new(rows: u32, cols: u32) -> Self {
        let words_per_row = (cols as usize).div_ceil(64);
        Subarray {
            rows,
            cols,
            words_per_row,
            data: vec![vec![0u64; words_per_row]; rows as usize],
            open_row: None,
        }
    }

    pub fn rows(&self) -> u32 {
        self.rows
    }

    pub fn cols(&self) -> u32 {
        self.cols
    }

    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    pub fn open_row(&self) -> Option<u32> {
        self.open_row
    }

    /// Assert the wordline (ACT). Returns `true` if this was a row switch
    /// (i.e. a real activation, possibly preceded by a precharge).
    pub fn activate(&mut self, row: u32) -> bool {
        assert!(row < self.rows, "row {row} out of range");
        if self.open_row == Some(row) {
            false
        } else {
            self.open_row = Some(row);
            true
        }
    }

    /// Precharge (close) the open row.
    pub fn precharge(&mut self) {
        self.open_row = None;
    }

    /// Read the full row as packed words (sense amplifiers → row buffer).
    pub fn read_row(&self, row: u32) -> &[u64] {
        &self.data[row as usize]
    }

    /// Overwrite the full row.
    pub fn write_row(&mut self, row: u32, words: &[u64]) {
        assert_eq!(words.len(), self.words_per_row);
        self.data[row as usize].copy_from_slice(words);
        self.mask_tail(row);
    }

    /// Read a single bit cell.
    pub fn get(&self, row: u32, col: u32) -> bool {
        assert!(col < self.cols);
        (self.data[row as usize][(col / 64) as usize] >> (col % 64)) & 1 == 1
    }

    /// Write a single bit cell.
    pub fn set(&mut self, row: u32, col: u32, v: bool) {
        assert!(col < self.cols);
        let w = &mut self.data[row as usize][(col / 64) as usize];
        let mask = 1u64 << (col % 64);
        if v {
            *w |= mask;
        } else {
            *w &= !mask;
        }
    }

    /// Zero any bits beyond `cols` in the last word (keeps popcounts exact).
    fn mask_tail(&mut self, row: u32) {
        let rem = self.cols as usize % 64;
        if rem != 0 {
            let last = self.words_per_row - 1;
            self.data[row as usize][last] &= (1u64 << rem) - 1;
        }
    }

    /// Store `value`'s low `bits` bits vertically at `col`, starting at
    /// `row0` (bit *i* of the value lands in row `row0 + i`): the transposed
    /// layout of §2.2. Two's-complement: callers pass the raw bit pattern.
    pub fn store_vertical(&mut self, col: u32, row0: u32, value: u64, bits: u32) {
        assert!(row0 + bits <= self.rows, "vertical operand exceeds subarray rows");
        for i in 0..bits {
            self.set(row0 + i, col, (value >> i) & 1 == 1);
        }
    }

    /// Load a vertically-stored `bits`-bit value at `col` starting `row0`.
    pub fn load_vertical(&self, col: u32, row0: u32, bits: u32) -> u64 {
        let mut v = 0u64;
        for i in 0..bits {
            if self.get(row0 + i, col) {
                v |= 1 << i;
            }
        }
        v
    }

    /// A lane view for bulk vertical stores across a column range.
    pub fn lane(&mut self, col0: u32, width: u32) -> VerticalLane<'_> {
        assert!(col0 + width <= self.cols);
        VerticalLane { sa: self, col0, width }
    }
}

/// Helper for writing/reading vectors of vertically-laid-out operands over a
/// contiguous column range (one operand element per column).
pub struct VerticalLane<'a> {
    sa: &'a mut Subarray,
    col0: u32,
    width: u32,
}

impl VerticalLane<'_> {
    /// Store `values[j]` (low `bits` bits) at column `col0 + j`, rows
    /// `row0..row0+bits`.
    pub fn store(&mut self, row0: u32, values: &[u64], bits: u32) {
        assert!(values.len() as u32 <= self.width, "lane overflow");
        for (j, &v) in values.iter().enumerate() {
            self.sa.store_vertical(self.col0 + j as u32, row0, v, bits);
        }
    }

    /// Load `count` values back out.
    pub fn load(&self, row0: u32, count: u32, bits: u32) -> Vec<u64> {
        assert!(count <= self.width);
        (0..count).map(|j| self.sa.load_vertical(self.col0 + j, row0, bits)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_bit_set_get() {
        let mut sa = Subarray::new(8, 100);
        sa.set(3, 77, true);
        assert!(sa.get(3, 77));
        assert!(!sa.get(3, 76));
        sa.set(3, 77, false);
        assert!(!sa.get(3, 77));
    }

    #[test]
    fn vertical_roundtrip() {
        let mut sa = Subarray::new(32, 64);
        for (col, v) in [(0u32, 0xA5u64), (13, 0xFF), (63, 0x00), (7, 0x5A)] {
            sa.store_vertical(col, 4, v, 8);
            assert_eq!(sa.load_vertical(col, 4, 8), v, "col {col}");
        }
    }

    #[test]
    fn lane_bulk_roundtrip() {
        let mut sa = Subarray::new(16, 128);
        let vals: Vec<u64> = (0..100).map(|i| (i * 7) % 256).collect();
        sa.lane(10, 110).store(0, &vals, 8);
        let got = sa.lane(10, 110).load(0, 100, 8);
        assert_eq!(got, vals);
    }

    #[test]
    fn activation_tracking() {
        let mut sa = Subarray::new(8, 64);
        assert!(sa.activate(2)); // cold activation
        assert!(!sa.activate(2)); // row already open
        assert!(sa.activate(5)); // row switch
        sa.precharge();
        assert_eq!(sa.open_row(), None);
        assert!(sa.activate(5));
    }

    #[test]
    fn tail_masking_on_full_row_write() {
        let mut sa = Subarray::new(2, 70); // 70 cols => 2 words, 6-bit tail
        sa.write_row(0, &[u64::MAX, u64::MAX]);
        let w = sa.read_row(0);
        assert_eq!(w[1].count_ones(), 6);
    }

    #[test]
    #[should_panic(expected = "exceeds subarray rows")]
    fn vertical_overflow_panics() {
        let mut sa = Subarray::new(8, 8);
        sa.store_vertical(0, 4, 0xFF, 8);
    }
}
