//! DRAM command protocol, including RACAM's extended PIM commands and their
//! instruction encodings (paper Table 1).
//!
//! PIM commands are encoded into previously-unused command encodings; the
//! opcode travels on the command bus and operand/control fields are
//! transferred over the address bus across multiple cycles (§3.1). `encode`
//! / `decode` implement exactly the Table 1 format and round-trip.


/// Opcode field values of Table 1 (6 bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum PimOpcode {
    BroadcastEnable = 0b000000,
    BroadcastDisable = 0b000001,
    PimEnable = 0b000010,
    PimDisable = 0b000011,
    PimAdd = 0b010000,
    PimMul = 0b010001,
    PimMulRed = 0b010010,
    PimAddParallel = 0b010011,
}

impl PimOpcode {
    pub fn from_bits(b: u8) -> Option<Self> {
        use PimOpcode::*;
        match b {
            0b000000 => Some(BroadcastEnable),
            0b000001 => Some(BroadcastDisable),
            0b000010 => Some(PimEnable),
            0b000011 => Some(PimDisable),
            0b010000 => Some(PimAdd),
            0b010001 => Some(PimMul),
            0b010010 => Some(PimMulRed),
            0b010011 => Some(PimAddParallel),
            _ => None,
        }
    }
}

/// A command on the (extended) DRAM command interface.
///
/// Register operands `r_*` name vertically-laid-out operand base rows within
/// the active block; `prec` is the 4-bit runtime precision control field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DramCommand {
    /// Standard row activation.
    Act { bank: u32, row: u32 },
    /// Standard precharge.
    Pre { bank: u32 },
    /// Standard column read (one burst).
    Rd { bank: u32, col: u32 },
    /// Standard column write (one burst).
    Wr { bank: u32, col: u32 },
    /// Enable PIM mode via MRS write.
    PimEnable,
    /// Disable PIM mode, restore normal decoding.
    PimDisable,
    /// Enable broadcast-write mode; `bank_bc`/`col_bc` select which demux
    /// levels replicate (Table 1 control field).
    BroadcastEnable { bank_bc: bool, col_bc: bool },
    BroadcastDisable,
    /// Bit-serial addition: `r_dst = r_src1 + r_src2` at `prec` bits.
    PimAdd { r_dst: u8, r_src1: u8, r_src2: u8, prec: u8 },
    /// Bit-serial multiplication.
    PimMul { r_dst: u8, r_src1: u8, r_src2: u8, prec: u8 },
    /// Fused multiply + column-wise popcount reduction.
    PimMulRed { r_dst: u8, r_src1: u8, r_src2: u8, prec: u8 },
    /// Bit-parallel int32 add in the popcount reduction unit's accumulator.
    PimAddParallel { r_dst: u8, r_src1: u8, r_src2: u8 },
}

impl DramCommand {
    pub fn is_pim(&self) -> bool {
        !matches!(
            self,
            DramCommand::Act { .. }
                | DramCommand::Pre { .. }
                | DramCommand::Rd { .. }
                | DramCommand::Wr { .. }
        )
    }
}

/// Encode a PIM command into its Table 1 wire format:
/// `[5:0]` opcode, `[13:6]` dst, `[21:14]` src1, `[29:22]` src2,
/// `[33:30]` prec / control bits.
///
/// Standard commands (`Act`/`Pre`/`Rd`/`Wr`) are not PIM-encoded; `encode`
/// returns `None` for them.
pub fn encode(cmd: &DramCommand) -> Option<u64> {
    use DramCommand::*;
    let pack = |op: PimOpcode, dst: u8, s1: u8, s2: u8, ctl: u8| -> u64 {
        (op as u64)
            | (dst as u64) << 6
            | (s1 as u64) << 14
            | (s2 as u64) << 22
            | (ctl as u64 & 0xF) << 30
    };
    Some(match *cmd {
        PimEnable => pack(PimOpcode::PimEnable, 0, 0, 0, 0),
        PimDisable => pack(PimOpcode::PimDisable, 0, 0, 0, 0),
        BroadcastEnable { bank_bc, col_bc } => {
            pack(PimOpcode::BroadcastEnable, 0, 0, 0, (bank_bc as u8) | (col_bc as u8) << 1)
        }
        BroadcastDisable => pack(PimOpcode::BroadcastDisable, 0, 0, 0, 0),
        PimAdd { r_dst, r_src1, r_src2, prec } => {
            pack(PimOpcode::PimAdd, r_dst, r_src1, r_src2, prec)
        }
        PimMul { r_dst, r_src1, r_src2, prec } => {
            pack(PimOpcode::PimMul, r_dst, r_src1, r_src2, prec)
        }
        PimMulRed { r_dst, r_src1, r_src2, prec } => {
            pack(PimOpcode::PimMulRed, r_dst, r_src1, r_src2, prec)
        }
        PimAddParallel { r_dst, r_src1, r_src2 } => {
            pack(PimOpcode::PimAddParallel, r_dst, r_src1, r_src2, 0)
        }
        Act { .. } | Pre { .. } | Rd { .. } | Wr { .. } => return None,
    })
}

/// Decode a Table 1 wire word back into a command.
pub fn decode(word: u64) -> Option<DramCommand> {
    let op = PimOpcode::from_bits((word & 0x3F) as u8)?;
    let dst = ((word >> 6) & 0xFF) as u8;
    let s1 = ((word >> 14) & 0xFF) as u8;
    let s2 = ((word >> 22) & 0xFF) as u8;
    let ctl = ((word >> 30) & 0xF) as u8;
    use PimOpcode::*;
    Some(match op {
        PimEnable => DramCommand::PimEnable,
        PimDisable => DramCommand::PimDisable,
        BroadcastEnable => {
            DramCommand::BroadcastEnable { bank_bc: ctl & 1 == 1, col_bc: ctl & 2 == 2 }
        }
        BroadcastDisable => DramCommand::BroadcastDisable,
        PimAdd => DramCommand::PimAdd { r_dst: dst, r_src1: s1, r_src2: s2, prec: ctl },
        PimMul => DramCommand::PimMul { r_dst: dst, r_src1: s1, r_src2: s2, prec: ctl },
        PimMulRed => DramCommand::PimMulRed { r_dst: dst, r_src1: s1, r_src2: s2, prec: ctl },
        PimAddParallel => DramCommand::PimAddParallel { r_dst: dst, r_src1: s1, r_src2: s2 },
    })
}

/// Number of address-bus cycles needed to transfer a command's operand and
/// control fields (fields are sent over the address bus across multiple
/// cycles, §3.1). DDR5 CA bus is 14 bits per edge.
pub fn address_bus_cycles(cmd: &DramCommand) -> u32 {
    match encode(cmd) {
        None => 1, // standard command: single CA slot
        Some(word) => {
            let payload_bits = 64 - word.leading_zeros().min(63);
            payload_bits.div_ceil(14).max(1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_pim_commands() -> Vec<DramCommand> {
        use DramCommand::*;
        vec![
            PimEnable,
            PimDisable,
            BroadcastEnable { bank_bc: true, col_bc: false },
            BroadcastEnable { bank_bc: false, col_bc: true },
            BroadcastEnable { bank_bc: true, col_bc: true },
            BroadcastDisable,
            PimAdd { r_dst: 3, r_src1: 7, r_src2: 11, prec: 8 },
            PimMul { r_dst: 0, r_src1: 255, r_src2: 1, prec: 4 },
            PimMulRed { r_dst: 9, r_src1: 2, r_src2: 200, prec: 2 },
            PimAddParallel { r_dst: 1, r_src1: 2, r_src2: 3 },
        ]
    }

    #[test]
    fn encode_decode_roundtrip() {
        for cmd in all_pim_commands() {
            let word = encode(&cmd).expect("pim commands encode");
            assert_eq!(decode(word), Some(cmd), "roundtrip failed for {cmd:?}");
        }
    }

    #[test]
    fn table1_opcodes() {
        // Pin the exact Table 1 opcode assignments.
        assert_eq!(PimOpcode::BroadcastEnable as u8, 0b000000);
        assert_eq!(PimOpcode::BroadcastDisable as u8, 0b000001);
        assert_eq!(PimOpcode::PimEnable as u8, 0b000010);
        assert_eq!(PimOpcode::PimDisable as u8, 0b000011);
        assert_eq!(PimOpcode::PimAdd as u8, 0b010000);
        assert_eq!(PimOpcode::PimMul as u8, 0b010001);
        assert_eq!(PimOpcode::PimMulRed as u8, 0b010010);
        assert_eq!(PimOpcode::PimAddParallel as u8, 0b010011);
    }

    #[test]
    fn standard_commands_do_not_pim_encode() {
        assert_eq!(encode(&DramCommand::Act { bank: 0, row: 1 }), None);
        assert_eq!(encode(&DramCommand::Pre { bank: 0 }), None);
    }

    #[test]
    fn unknown_opcode_decodes_to_none() {
        assert_eq!(decode(0b111111), None);
    }

    #[test]
    fn multi_cycle_address_transfer() {
        // A full pim_mul carries 34 payload bits -> 3 CA cycles at 14b.
        let c = DramCommand::PimMul { r_dst: 200, r_src1: 200, r_src2: 200, prec: 8 };
        assert_eq!(address_bus_cycles(&c), 3);
        assert_eq!(address_bus_cycles(&DramCommand::Act { bank: 0, row: 0 }), 1);
    }

    #[test]
    fn is_pim_classification() {
        assert!(DramCommand::PimEnable.is_pim());
        assert!(!DramCommand::Rd { bank: 0, col: 0 }.is_pim());
    }
}
