//! DRAM hierarchy geometry: physical addresses and the block ↔ subarray
//! projection the mapping framework relies on (paper §4: "the mapping
//! framework views the subarrays of DRAM as many vertically-divided Blocks").

use crate::config::DramConfig;

/// A fully-qualified physical location in the DRAM hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PhysAddr {
    pub channel: u32,
    pub rank: u32,
    pub device: u32,
    pub bank: u32,
    pub subarray: u32,
    pub row: u32,
    pub col: u32,
}

/// A *block*: one vertical slice of one subarray, `pe_width` columns wide.
/// Blocks are the finest spatial mapping unit (level `A`); the projection to
/// (subarray, column range) is what `Geometry::project_block` computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId {
    pub channel: u32,
    pub rank: u32,
    pub device: u32,
    pub bank: u32,
    /// Block index within the bank: `subarray * slices_per_subarray + slice`.
    pub block: u32,
}

/// Geometry calculator for a DRAM configuration plus the PE width that
/// determines block slicing.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    pub dram: DramConfig,
    /// Width of one block in columns (= PEs per bank).
    pub pe_width: u32,
}

impl Geometry {
    pub fn new(dram: DramConfig, pe_width: u32) -> Self {
        assert!(pe_width > 0 && dram.cols % pe_width == 0, "cols must divide into PE-width slices");
        Geometry { dram, pe_width }
    }

    /// Vertical slices per subarray.
    pub fn slices_per_subarray(&self) -> u32 {
        self.dram.cols / self.pe_width
    }

    /// Blocks per bank (level-A count for the mapping framework).
    pub fn blocks_per_bank(&self) -> u32 {
        self.dram.subarrays * self.slices_per_subarray()
    }

    /// Total blocks in the system.
    pub fn total_blocks(&self) -> u64 {
        self.dram.total_banks() * self.blocks_per_bank() as u64
    }

    /// Project a block id to its (subarray, first column) location.
    pub fn project_block(&self, b: BlockId) -> (u32, u32) {
        let slices = self.slices_per_subarray();
        let subarray = b.block / slices;
        let col0 = (b.block % slices) * self.pe_width;
        (subarray, col0)
    }

    /// Inverse of [`Self::project_block`].
    pub fn block_of(&self, channel: u32, rank: u32, device: u32, bank: u32, subarray: u32, col: u32) -> BlockId {
        let slices = self.slices_per_subarray();
        BlockId { channel, rank, device, bank, block: subarray * slices + col / self.pe_width }
    }

    /// Linear index of a block across the whole system (row-major over
    /// channel → rank → device → bank → block).
    pub fn linear_block(&self, b: BlockId) -> u64 {
        let d = &self.dram;
        ((((b.channel as u64 * d.ranks as u64 + b.rank as u64) * d.devices as u64
            + b.device as u64)
            * d.banks as u64
            + b.bank as u64)
            * self.blocks_per_bank() as u64)
            + b.block as u64
    }

    /// Decompose a linear block index back into a `BlockId`.
    pub fn block_from_linear(&self, mut idx: u64) -> BlockId {
        let bpb = self.blocks_per_bank() as u64;
        let d = &self.dram;
        let block = (idx % bpb) as u32;
        idx /= bpb;
        let bank = (idx % d.banks as u64) as u32;
        idx /= d.banks as u64;
        let device = (idx % d.devices as u64) as u32;
        idx /= d.devices as u64;
        let rank = (idx % d.ranks as u64) as u32;
        idx /= d.ranks as u64;
        let channel = idx as u32;
        BlockId { channel, rank, device, bank, block }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, racam_tiny};

    fn geo() -> Geometry {
        let hw = racam_paper();
        Geometry::new(hw.dram, hw.periph.pes_per_bank)
    }

    #[test]
    fn paper_block_counts() {
        let g = geo();
        assert_eq!(g.slices_per_subarray(), 16);
        assert_eq!(g.blocks_per_bank(), 128 * 16);
        assert_eq!(g.total_blocks(), 8 * 32 * 8 * 16 * 2048);
    }

    #[test]
    fn block_projection_roundtrip() {
        let g = geo();
        for block in [0u32, 1, 15, 16, 17, 2047] {
            let b = BlockId { channel: 3, rank: 11, device: 2, bank: 9, block };
            let (sa, col0) = g.project_block(b);
            assert!(sa < g.dram.subarrays && col0 < g.dram.cols);
            assert_eq!(g.block_of(3, 11, 2, 9, sa, col0), b);
        }
    }

    #[test]
    fn linear_roundtrip() {
        let hw = racam_tiny();
        let g = Geometry::new(hw.dram, hw.periph.pes_per_bank);
        for idx in 0..g.total_blocks() {
            let b = g.block_from_linear(idx);
            assert_eq!(g.linear_block(b), idx);
        }
    }

    #[test]
    #[should_panic(expected = "PE-width")]
    fn rejects_non_dividing_width() {
        let hw = racam_tiny();
        Geometry::new(hw.dram, 100);
    }
}
