//! SALP-MASA subarray-level parallelism model (paper §3.3, citing Kim et
//! al. [41]): rows that will be accessed successively are mapped to
//! *different* subarrays so their activations overlap, saturating the global
//! bitline and giving the locality buffer its highest bandwidth.

use crate::config::TimingParams;

/// Scheduler that decides whether a stream of row accesses can be overlapped
/// (consecutive accesses hit different subarrays) and prices the stream.
#[derive(Debug, Clone)]
pub struct SalpScheduler {
    t: TimingParams,
    /// Number of subarrays available for round-robin row placement.
    subarrays: u32,
    /// When false (ablation), every access pays a full ACT–PRE cycle.
    enabled: bool,
}

impl SalpScheduler {
    pub fn new(t: TimingParams, subarrays: u32) -> Self {
        SalpScheduler { t, subarrays, enabled: true }
    }

    pub fn disabled(t: TimingParams, subarrays: u32) -> Self {
        SalpScheduler { t, subarrays, enabled: false }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Assign `n_rows` successive rows round-robin across subarrays; returns
    /// the subarray index per row (the data-layout side of §3.3).
    pub fn place_rows(&self, n_rows: u32) -> Vec<u32> {
        (0..n_rows).map(|i| i % self.subarrays).collect()
    }

    /// Latency in ns of streaming `n_rows` successive row accesses into the
    /// locality buffer, including the pipeline-fill tRCD.
    ///
    /// With SALP (and >1 subarray) the activations pipeline: one tRCD of
    /// fill latency, then one global-bitline beat per row.  Without it, each
    /// access is a serial ACT–PRE.
    pub fn stream_ns(&self, n_rows: u64) -> f64 {
        if n_rows == 0 {
            return 0.0;
        }
        if self.enabled && self.subarrays > 1 {
            self.t.salp_stream_ns(n_rows)
        } else {
            self.t.serial_rows_ns(n_rows)
        }
    }

    /// Steady-state stream latency: when passes run back-to-back, the next
    /// pass's activations overlap the current pass's beats, so the tRCD
    /// fill is paid once per kernel (folded into the kernel overhead by
    /// the software model), not once per pass.
    pub fn steady_stream_ns(&self, n_rows: u64) -> f64 {
        if self.enabled && self.subarrays > 1 {
            n_rows as f64 * self.t.t_cas_ns
        } else {
            self.t.serial_rows_ns(n_rows)
        }
    }

    /// Speedup of the overlapped stream vs. serial accesses.
    pub fn overlap_speedup(&self, n_rows: u64) -> f64 {
        self.t.serial_rows_ns(n_rows) / self.stream_ns(n_rows).max(f64::MIN_POSITIVE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ddr5_5200_timing;

    #[test]
    fn placement_round_robins() {
        let s = SalpScheduler::new(ddr5_5200_timing(), 4);
        assert_eq!(s.place_rows(6), vec![0, 1, 2, 3, 0, 1]);
        // Consecutive rows never share a subarray (the property §3.3 needs).
        let p = s.place_rows(64);
        for w in p.windows(2) {
            assert_ne!(w[0], w[1]);
        }
    }

    #[test]
    fn overlap_beats_serial_and_grows() {
        let s = SalpScheduler::new(ddr5_5200_timing(), 128);
        assert!(s.overlap_speedup(4) > 1.0);
        assert!(s.overlap_speedup(64) > s.overlap_speedup(4));
    }

    #[test]
    fn disabled_scheduler_serializes() {
        let t = ddr5_5200_timing();
        let s = SalpScheduler::disabled(t, 128);
        assert!((s.stream_ns(16) - t.serial_rows_ns(16)).abs() < 1e-9);
    }

    #[test]
    fn single_subarray_cannot_overlap() {
        let t = ddr5_5200_timing();
        let s = SalpScheduler::new(t, 1);
        assert!((s.stream_ns(16) - t.serial_rows_ns(16)).abs() < 1e-9);
    }
}
