//! DRAM substrate: hierarchy geometry, functional bit-cell storage with the
//! vertical (transposed) layout bit-serial PIM requires, a DDR5 command
//! timing engine, and the SALP-MASA subarray-overlap model (paper §2.1, §3.3).
//!
//! This is the substrate the paper's evaluation rests on (it validates
//! against Ramulator); here it is a self-contained engine that produces the
//! same aggregate quantities RACAM's analytical model consumes: ACT/PRE
//! counts, row-stream latencies, and channel bandwidth.

mod commands;
mod geometry;
mod reliability;
mod salp;
mod subarray;
mod timing;

pub use commands::{decode, encode, DramCommand, PimOpcode};
pub use reliability::{DisturbanceSpec, ReliabilityModel, ReliabilityVerdict};
pub use geometry::{BlockId, Geometry, PhysAddr};
pub use salp::SalpScheduler;
pub use subarray::{Subarray, VerticalLane};
pub use timing::{CommandTimer, TimingStats};
