//! DRAM reliability model (paper §7 "Reliability"): massively parallel
//! bit-serial PIM generates dense, highly regular ACT–PRE sequences that
//! repeatedly toggle the same wordlines — RowHammer-like disturbance.  This
//! module tracks per-row activation rates within a refresh window, flags
//! rows that exceed a disturbance threshold, and computes the throttling
//! factor a scheduler must apply to stay within spec — exactly the
//! "practical limits on how aggressively bit-level parallelism can be
//! exploited" the paper discusses.

/// Disturbance parameters for a DDR5-class part.
#[derive(Debug, Clone, Copy)]
pub struct DisturbanceSpec {
    /// Refresh window tREFW, ns (64 ms standard).
    pub refresh_window_ns: f64,
    /// Maximum tolerated activations of one row per refresh window before
    /// neighbouring rows risk disturbance (RowHammer threshold; modern
    /// parts are in the 10k–50k range).
    pub max_acts_per_row: u64,
    /// Minimum spacing between activations of the same row, ns (charge
    /// restoration; §7 "reducing the time available for cells to restore").
    pub min_same_row_spacing_ns: f64,
}

impl Default for DisturbanceSpec {
    fn default() -> Self {
        DisturbanceSpec {
            refresh_window_ns: 64e6,
            max_acts_per_row: 25_000,
            min_same_row_spacing_ns: 60.0,
        }
    }
}

/// Verdict for one workload's activation pattern.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReliabilityVerdict {
    /// Peak activations of any single row per refresh window.
    pub peak_row_acts_per_window: f64,
    /// Fraction of the disturbance budget consumed (>1 ⇒ unsafe).
    pub budget_fraction: f64,
    /// Throttle factor (≥1) the scheduler must apply to become safe.
    pub required_throttle: f64,
    /// Same-row revisit interval, ns.
    pub revisit_interval_ns: f64,
}

impl ReliabilityVerdict {
    pub fn is_safe(&self) -> bool {
        self.budget_fraction <= 1.0
    }
}

/// Activation-rate checker.
#[derive(Debug, Clone, Default)]
pub struct ReliabilityModel {
    pub spec: DisturbanceSpec,
}

impl ReliabilityModel {
    pub fn new(spec: DisturbanceSpec) -> Self {
        ReliabilityModel { spec }
    }

    /// Analyze a steady-state kernel loop: `row_acts_per_pass` activations
    /// spread round-robin over `rows_in_rotation` distinct rows (the SALP
    /// placement of §3.3), one pass every `pass_ns`.
    ///
    /// The locality buffer is exactly what keeps `rows_in_rotation` large
    /// relative to the activation count — without reuse, the same operand
    /// rows are re-activated every pass.
    pub fn analyze(
        &self,
        row_acts_per_pass: u64,
        rows_in_rotation: u64,
        pass_ns: f64,
    ) -> ReliabilityVerdict {
        let rows = rows_in_rotation.max(1) as f64;
        let acts_per_row_per_pass = row_acts_per_pass as f64 / rows;
        let passes_per_window = self.spec.refresh_window_ns / pass_ns.max(f64::MIN_POSITIVE);
        let peak = acts_per_row_per_pass * passes_per_window;
        let budget = peak / self.spec.max_acts_per_row as f64;
        let revisit = pass_ns / acts_per_row_per_pass.max(f64::MIN_POSITIVE);
        let spacing_throttle = self.spec.min_same_row_spacing_ns / revisit;
        ReliabilityVerdict {
            peak_row_acts_per_window: peak,
            budget_fraction: budget,
            required_throttle: budget.max(spacing_throttle).max(1.0),
            revisit_interval_ns: revisit,
        }
    }

    /// Activation pressure of sustaining `macs_per_s` multiply-accumulates
    /// over a data footprint of `data_rows` operand rows, given
    /// `row_accesses_per_mult` row activations per `simd_width`-wide
    /// multiply: the per-row activation count inside one refresh window.
    ///
    /// This is the §7 comparison: at *equal throughput*, a no-reuse PUD
    /// design (O(n²) accesses per multiply) pressures every row
    /// `n²/4n = n/4` times harder than RACAM's O(n) schedule.
    pub fn pressure(
        &self,
        macs_per_s: f64,
        simd_width: u64,
        row_accesses_per_mult: u64,
        data_rows: u64,
    ) -> ReliabilityVerdict {
        let mults_per_s = macs_per_s / simd_width.max(1) as f64;
        let acts_per_s = mults_per_s * row_accesses_per_mult as f64;
        let acts_per_row_per_window =
            acts_per_s * (self.spec.refresh_window_ns / 1e9) / data_rows.max(1) as f64;
        let budget = acts_per_row_per_window / self.spec.max_acts_per_row as f64;
        let revisit = 1e9 * data_rows as f64 / acts_per_s.max(f64::MIN_POSITIVE);
        ReliabilityVerdict {
            peak_row_acts_per_window: acts_per_row_per_window,
            budget_fraction: budget,
            required_throttle: budget.max(self.spec.min_same_row_spacing_ns / revisit).max(1.0),
            revisit_interval_ns: revisit,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_free_pud_needs_heavier_throttling() {
        // Same sustained throughput (1 TMAC/s), same SIMD width, same data
        // footprint: the O(n²) design pressures rows n/4 + ε times harder.
        let m = ReliabilityModel::default();
        let (macs, width, rows) = (1e12, 1024, 1u64 << 20);
        let racam = m.pressure(macs, width, 4 * 8, rows); // 4n
        let pud = m.pressure(macs, width, 3 * 64 + 2 * 8, rows); // 3n²+2n
        let ratio = pud.peak_row_acts_per_window / racam.peak_row_acts_per_window;
        assert!((6.0..7.5).contains(&ratio), "pressure ratio {ratio}");
        assert!(pud.required_throttle >= racam.required_throttle);
    }

    #[test]
    fn dense_hammering_is_flagged_unsafe() {
        let m = ReliabilityModel::default();
        // One row re-activated every 100 ns for a whole refresh window.
        let v = m.analyze(1, 1, 100.0);
        assert!(!v.is_safe());
        assert!(v.required_throttle > 1.0);
    }

    #[test]
    fn spread_rotation_is_safe() {
        let m = ReliabilityModel::default();
        // 32 accesses over 32 rows, 1 µs per pass → 32k row-acts/window/32rows
        // = 2000 per row < 25k budget... compute: passes/window = 64e6/1000
        // = 64000, acts/row/pass = 1 → 64000 > 25000: still unsafe! Spread
        // further: 128-row rotation at 10 µs.
        let v = m.analyze(32, 128, 10_000.0);
        assert!(v.is_safe(), "budget {}", v.budget_fraction);
        assert!((v.required_throttle - 1.0).abs() < 1e-9);
    }

    #[test]
    fn throttle_scales_with_budget_overrun() {
        let m = ReliabilityModel::default();
        let mild = m.analyze(8, 8, 1_000.0);
        let harsh = m.analyze(8, 8, 100.0);
        assert!(harsh.budget_fraction > 9.0 * mild.budget_fraction);
        assert!(harsh.required_throttle > mild.required_throttle);
    }

    #[test]
    fn revisit_interval_math() {
        let m = ReliabilityModel::default();
        let v = m.analyze(4, 4, 400.0);
        // 1 activation per row per 400 ns pass.
        assert!((v.revisit_interval_ns - 400.0).abs() < 1e-9);
    }
}
