//! DDR5 command timing engine: accounts nanoseconds and ACT/PRE statistics
//! for command streams, the quantities the paper validates against Ramulator.

use super::commands::DramCommand;
use crate::config::TimingParams;
use std::collections::HashMap;

/// Aggregate statistics of an accounted command stream.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TimingStats {
    pub activations: u64,
    pub precharges: u64,
    pub reads: u64,
    pub writes: u64,
    pub pim_commands: u64,
    pub total_ns: f64,
}

/// Per-bank open-row tracker + latency accumulator.
///
/// The model is intentionally simple (single-channel, closed-form): an ACT to
/// a bank with an open row implies an implicit precharge; reads/writes to the
/// open row cost `t_cas`; PIM commands cost only their address-bus transfer
/// here (their execution latency is modelled by `pim::isa`).
#[derive(Debug, Clone)]
pub struct CommandTimer {
    t: TimingParams,
    open_rows: HashMap<u32, u32>,
    stats: TimingStats,
}

impl CommandTimer {
    pub fn new(t: TimingParams) -> Self {
        CommandTimer { t, open_rows: HashMap::new(), stats: TimingStats::default() }
    }

    pub fn stats(&self) -> &TimingStats {
        &self.stats
    }

    pub fn elapsed_ns(&self) -> f64 {
        self.stats.total_ns
    }

    /// Account one command; returns its latency contribution in ns.
    pub fn issue(&mut self, cmd: &DramCommand) -> f64 {
        let ns = match *cmd {
            DramCommand::Act { bank, row } => {
                let mut ns = 0.0;
                match self.open_rows.get(&bank) {
                    Some(&open) if open == row => 0.0, // row hit: free
                    Some(_) => {
                        // Row switch: implicit precharge, then activate.
                        self.stats.precharges += 1;
                        self.stats.activations += 1;
                        self.open_rows.insert(bank, row);
                        ns += self.t.t_rp_ns + self.t.t_rcd_ns;
                        ns
                    }
                    None => {
                        self.stats.activations += 1;
                        self.open_rows.insert(bank, row);
                        ns += self.t.t_rcd_ns;
                        ns
                    }
                }
            }
            DramCommand::Pre { bank } => {
                if self.open_rows.remove(&bank).is_some() {
                    self.stats.precharges += 1;
                    self.t.t_rp_ns
                } else {
                    0.0
                }
            }
            DramCommand::Rd { .. } => {
                self.stats.reads += 1;
                self.t.t_cas_ns
            }
            DramCommand::Wr { .. } => {
                self.stats.writes += 1;
                self.t.t_cas_ns
            }
            ref pim => {
                debug_assert!(pim.is_pim());
                self.stats.pim_commands += 1;
                // Address-bus transfer cycles at the I/O clock.
                super::commands::address_bus_cycles(pim) as f64 * self.t.pe_cycle_ns()
            }
        };
        self.stats.total_ns += ns;
        ns
    }

    /// Account a whole stream.
    pub fn issue_all<'a>(&mut self, cmds: impl IntoIterator<Item = &'a DramCommand>) -> f64 {
        cmds.into_iter().map(|c| self.issue(c)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ddr5_5200_timing;
    use DramCommand::*;

    fn timer() -> CommandTimer {
        CommandTimer::new(ddr5_5200_timing())
    }

    #[test]
    fn row_hit_is_free() {
        let mut t = timer();
        let first = t.issue(&Act { bank: 0, row: 5 });
        let hit = t.issue(&Act { bank: 0, row: 5 });
        assert!(first > 0.0);
        assert_eq!(hit, 0.0);
        assert_eq!(t.stats().activations, 1);
    }

    #[test]
    fn row_switch_pays_pre_plus_act() {
        let mut t = timer();
        t.issue(&Act { bank: 0, row: 1 });
        let switch = t.issue(&Act { bank: 0, row: 2 });
        let tp = ddr5_5200_timing();
        assert!((switch - (tp.t_rp_ns + tp.t_rcd_ns)).abs() < 1e-9);
        assert_eq!(t.stats().precharges, 1);
        assert_eq!(t.stats().activations, 2);
    }

    #[test]
    fn banks_track_independently() {
        let mut t = timer();
        t.issue(&Act { bank: 0, row: 1 });
        t.issue(&Act { bank: 1, row: 9 });
        assert_eq!(t.issue(&Act { bank: 0, row: 1 }), 0.0);
        assert_eq!(t.issue(&Act { bank: 1, row: 9 }), 0.0);
        assert_eq!(t.stats().activations, 2);
    }

    #[test]
    fn precharge_idempotent() {
        let mut t = timer();
        t.issue(&Act { bank: 0, row: 1 });
        assert!(t.issue(&Pre { bank: 0 }) > 0.0);
        assert_eq!(t.issue(&Pre { bank: 0 }), 0.0);
        assert_eq!(t.stats().precharges, 1);
    }

    #[test]
    fn stream_accumulates() {
        let mut t = timer();
        let cmds =
            vec![Act { bank: 0, row: 0 }, Rd { bank: 0, col: 0 }, Rd { bank: 0, col: 1 }, Pre { bank: 0 }];
        let total = t.issue_all(&cmds);
        assert!((total - t.elapsed_ns()).abs() < 1e-9);
        assert_eq!(t.stats().reads, 2);
    }

    #[test]
    fn pim_commands_counted() {
        let mut t = timer();
        t.issue(&PimEnable);
        t.issue(&PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 8 });
        assert_eq!(t.stats().pim_commands, 2);
        assert!(t.elapsed_ns() > 0.0);
    }
}
