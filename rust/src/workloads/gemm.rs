//! Standalone GEMM/GEMV sweeps for the sensitivity studies (paper Fig. 16:
//! three groups each; M and N fixed within a group, K swept).

use crate::config::{MatmulShape, Precision};

/// One sweep point with its group label.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub group: &'static str,
    pub shape: MatmulShape,
}

/// Fig. 16a GEMM sweep: square-ish GEMMs from 2048³ up to 32768³,
/// grouped by (M, N) with K swept ×4 within each group.
pub fn gemm_sweep(prec: Precision) -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for (group, mn) in [("G2048", 2048u64), ("G8192", 8192), ("G32768", 32768)] {
        for k in [mn, mn * 2, mn * 4] {
            v.push(SweepPoint { group, shape: MatmulShape::new(mn, k, mn, prec) });
        }
    }
    v
}

/// Fig. 16b GEMV sweep: M = 1, N fixed per group, K swept.
pub fn gemv_sweep(prec: Precision) -> Vec<SweepPoint> {
    let mut v = Vec::new();
    for (group, n) in [("V2048", 2048u64), ("V8192", 8192), ("V32768", 32768)] {
        for k in [n, n * 2, n * 4] {
            v.push(SweepPoint { group, shape: MatmulShape::new(1, k, n, prec) });
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_sizes() {
        assert_eq!(gemm_sweep(Precision::Int8).len(), 9);
        assert_eq!(gemv_sweep(Precision::Int8).len(), 9);
    }

    #[test]
    fn gemm_compute_span_covers_the_papers_4096x() {
        // Paper: 2048³ → 32768³ is a 4096× compute growth; the sweep must
        // contain both endpoints.
        let sweep = gemm_sweep(Precision::Int8);
        let small = sweep.iter().find(|p| p.shape.label() == "2048x2048x2048").unwrap();
        let big = sweep.iter().find(|p| p.shape.label() == "32768x32768x32768").unwrap();
        assert_eq!(big.shape.macs() / small.shape.macs(), 4096);
    }

    #[test]
    fn gemvs_are_gemvs() {
        assert!(gemv_sweep(Precision::Int8).iter().all(|p| p.shape.is_gemv()));
    }
}
