//! LLM parser: decomposes a transformer's prefill and decode stages into
//! the GEMM/GEMV kernel sequences the mapping engine consumes (paper §4.4's
//! "LLM parser", built per-layer from the Table 3 hyper-parameters).

use super::CostModel;
use crate::config::{LlmSpec, MatmulShape, Precision, Scenario};
use crate::metrics::LatencyBreakdown;

/// One kernel shape plus how many times it executes per forward pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelInstance {
    pub shape: MatmulShape,
    pub count: u64,
    /// Human label for reports ("qkv", "scores", ...).
    pub label: &'static str,
}

impl KernelInstance {
    fn new(label: &'static str, shape: MatmulShape, count: u64) -> Self {
        KernelInstance { shape, count, label }
    }
}

/// Kernels of one full prefill forward pass over `seq` prompt tokens.
///
/// Weight matmuls are `weight_static` (pre-laid in DRAM / resident in HBM);
/// attention matmuls multiply two dynamic activations.
pub fn prefill_kernels(spec: &LlmSpec, seq: u64) -> Vec<KernelInstance> {
    let h = spec.hidden;
    let dh = spec.head_dim();
    let kv = spec.kv_heads as u64 * dh;
    let l = spec.layers as u64;
    let p = spec.prec;
    // Attention heads are data-parallel: the parser folds them into the N
    // dimension (heads × per-head width), so one PIM kernel per layer maps
    // them across the hierarchy instead of issuing `heads` serial GEMMs
    // (MAC count and per-output reduction length are preserved exactly).
    let heads = spec.heads as u64;
    let mut v = vec![
        KernelInstance::new("qkv", MatmulShape::new(seq, h, h + 2 * kv, p).resident(), l),
        KernelInstance::new("scores", MatmulShape::dynamic(seq, dh, heads * seq, p).resident(), l),
        KernelInstance::new("attn_v", MatmulShape::dynamic(seq, seq, heads * dh, p).resident(), l),
        KernelInstance::new("out_proj", MatmulShape::new(seq, h, h, p).resident(), l),
        KernelInstance::new("ffn_up", MatmulShape::new(seq, h, spec.ffn, p).resident(), l),
        KernelInstance::new("ffn_down", MatmulShape::new(seq, spec.ffn, h, p).resident(), l),
    ];
    if spec.gated_ffn {
        v.push(KernelInstance::new("ffn_gate", MatmulShape::new(seq, h, spec.ffn, p).resident(), l));
    }
    // LM head: only the last position feeds generation.
    v.push(KernelInstance::new("lm_head", MatmulShape::new(1, h, spec.vocab, p).resident(), 1));
    v
}

/// Kernels of one decode step at KV-cache context length `ctx`.
///
/// The KV cache lives in (PIM) DRAM where it was produced, so the
/// attention matmuls against it are `weight_static`; only the per-token
/// activations move.
pub fn decode_kernels(spec: &LlmSpec, ctx: u64) -> Vec<KernelInstance> {
    let h = spec.hidden;
    let dh = spec.head_dim();
    let kv = spec.kv_heads as u64 * dh;
    let l = spec.layers as u64;
    let p = spec.prec;
    let heads = spec.heads as u64;
    let mut v = vec![
        KernelInstance::new("qkv", MatmulShape::new(1, h, h + 2 * kv, p).resident(), l),
        KernelInstance::new("scores", MatmulShape::new(1, dh, heads * ctx, p).resident(), l),
        KernelInstance::new("attn_v", MatmulShape::new(1, ctx, heads * dh, p).resident(), l),
        KernelInstance::new("out_proj", MatmulShape::new(1, h, h, p).resident(), l),
        KernelInstance::new("ffn_up", MatmulShape::new(1, h, spec.ffn, p).resident(), l),
        KernelInstance::new("ffn_down", MatmulShape::new(1, spec.ffn, h, p).resident(), l),
    ];
    if spec.gated_ffn {
        v.push(KernelInstance::new("ffn_gate", MatmulShape::new(1, h, spec.ffn, p).resident(), l));
    }
    v.push(KernelInstance::new("lm_head", MatmulShape::new(1, h, spec.vocab, p).resident(), 1));
    v
}

/// Total latency of a kernel list on a system.  Errors when a kernel shape
/// is degenerate and the system cannot price it (which an [`LlmSpec`] with
/// non-zero hyper-parameters never produces).
pub fn stage_latency(
    sys: &dyn CostModel,
    kernels: &[KernelInstance],
) -> crate::Result<LatencyBreakdown> {
    let mut total = LatencyBreakdown::default();
    for k in kernels {
        let cost = sys.kernel_cost(&k.shape).ok_or_else(|| {
            let (name, shape) = (sys.name(), k.shape.label());
            anyhow::anyhow!("{name}: no valid mapping for kernel '{}' ({shape})", k.label)
        })?;
        total.add(&cost.scaled(k.count as f64));
    }
    Ok(total)
}

/// Number of context-length sample points used to integrate decode latency
/// over a generation (mappings are cached per shape, so per-token evaluation
/// would be exact but slow; the latency is near-linear in context length).
const DECODE_SAMPLES: u64 = 8;

/// Total decode latency for generating `output_tokens` after a
/// `prompt_tokens` prompt: samples the per-token latency at several context
/// lengths and integrates trapezoidally.
pub fn decode_total(
    sys: &dyn CostModel,
    spec: &LlmSpec,
    prompt_tokens: u64,
    output_tokens: u64,
) -> crate::Result<LatencyBreakdown> {
    if output_tokens == 0 {
        return Ok(LatencyBreakdown::default());
    }
    let samples = DECODE_SAMPLES.min(output_tokens);
    let mut total = LatencyBreakdown::default();
    let seg = output_tokens as f64 / samples as f64;
    for s in 0..samples {
        // Mid-point context length of this segment.
        let ctx = prompt_tokens + ((s as f64 + 0.5) * seg) as u64;
        let per_token = stage_latency(sys, &decode_kernels(spec, ctx.max(1)))?;
        total.add(&per_token.scaled(seg));
    }
    Ok(total)
}

/// End-to-end scenario latency: one prefill pass + the full generation.
pub fn e2e_latency(
    sys: &dyn CostModel,
    spec: &LlmSpec,
    sc: &Scenario,
) -> crate::Result<LatencyBreakdown> {
    let mut total = stage_latency(sys, &prefill_kernels(spec, sc.prompt_tokens))?;
    total.add(&decode_total(sys, spec, sc.prompt_tokens, sc.output_tokens)?);
    Ok(total)
}

/// Convenience: int8 per-token decode MAC count (sanity checks / roofline).
pub fn decode_macs(spec: &LlmSpec, ctx: u64) -> u64 {
    decode_kernels(spec, ctx).iter().map(|k| k.count * k.shape.macs()).sum()
}

#[allow(dead_code)]
fn _assert_precision_is_int8(p: Precision) {
    debug_assert_eq!(p.bits(), 8);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpt3_175b, gpt3_6_7b, llama3_8b, Scenario};

    /// A trivial system: latency proportional to MACs (+ constant).
    struct MacSystem;
    impl CostModel for MacSystem {
        fn name(&self) -> &str {
            "mac"
        }
        fn kernel_cost(&self, shape: &MatmulShape) -> Option<LatencyBreakdown> {
            Some(LatencyBreakdown::new(shape.macs() as f64 * 1e-3, 10.0))
        }
    }

    #[test]
    fn prefill_macs_match_closed_form() {
        // GPT-3 (MHA, non-gated): per layer ≈ S·h·3h + 2·S²·h + S·h·h + 2·S·h·4h.
        let spec = gpt3_6_7b();
        let s = 1024u64;
        let macs: u64 =
            prefill_kernels(&spec, s).iter().map(|k| k.count * k.shape.macs()).sum();
        let h = spec.hidden;
        let per_layer = s * h * 3 * h + 2 * s * s * h + s * h * h + 2 * s * h * 4 * h;
        let expect = spec.layers as u64 * per_layer + spec.vocab * h;
        assert_eq!(macs, expect);
    }

    #[test]
    fn decode_kernels_are_gemv() {
        for k in decode_kernels(&gpt3_175b(), 4096) {
            assert!(k.shape.is_gemv(), "{} is not a GEMV", k.label);
        }
    }

    #[test]
    fn gqa_shrinks_kv_projection() {
        let llama = llama3_8b();
        let qkv = decode_kernels(&llama, 128).iter().find(|k| k.label == "qkv").unwrap().shape;
        // 4096 + 2·(8 heads × 128) = 6144 < 3·4096.
        assert_eq!(qkv.n, 4096 + 2 * 1024);
    }

    #[test]
    fn gated_ffn_adds_a_matmul() {
        let llama = llama3_8b();
        let gpt = gpt3_6_7b();
        let l = prefill_kernels(&llama, 64).iter().filter(|k| k.label.starts_with("ffn")).count();
        let g = prefill_kernels(&gpt, 64).iter().filter(|k| k.label.starts_with("ffn")).count();
        assert_eq!(l, 3);
        assert_eq!(g, 2);
    }

    #[test]
    fn decode_total_grows_with_context() {
        let spec = gpt3_6_7b();
        let short = decode_total(&MacSystem, &spec, 128, 64).unwrap();
        let long = decode_total(&MacSystem, &spec, 8192, 64).unwrap();
        assert!(long.total_ns() > short.total_ns());
    }

    #[test]
    fn decode_total_scales_with_token_count() {
        let spec = gpt3_6_7b();
        let few = decode_total(&MacSystem, &spec, 1024, 10).unwrap();
        let many = decode_total(&MacSystem, &spec, 1024, 1000).unwrap();
        // More than 50x (context also grows), at least linear-ish.
        assert!(many.total_ns() > 50.0 * few.total_ns());
        assert_eq!(decode_total(&MacSystem, &spec, 1024, 0).unwrap().total_ns(), 0.0);
    }

    #[test]
    fn e2e_is_prefill_plus_decode() {
        let spec = gpt3_6_7b();
        let sc = Scenario::CODE_GENERATION;
        let e2e = e2e_latency(&MacSystem, &spec, &sc).unwrap();
        let prefill = stage_latency(&MacSystem, &prefill_kernels(&spec, sc.prompt_tokens)).unwrap();
        let decode = decode_total(&MacSystem, &spec, sc.prompt_tokens, sc.output_tokens).unwrap();
        let sum = prefill.total_ns() + decode.total_ns();
        assert!((e2e.total_ns() - sum).abs() / sum < 1e-12);
    }

    #[test]
    fn unpriceable_kernel_propagates_an_error() {
        struct NoneSystem;
        impl CostModel for NoneSystem {
            fn name(&self) -> &str {
                "none"
            }
            fn kernel_cost(&self, _shape: &MatmulShape) -> Option<LatencyBreakdown> {
                None
            }
        }
        let spec = gpt3_6_7b();
        let err = stage_latency(&NoneSystem, &decode_kernels(&spec, 16)).unwrap_err();
        assert!(err.to_string().contains("no valid mapping"), "{err}");
        assert!(e2e_latency(&NoneSystem, &spec, &Scenario::CODE_GENERATION).is_err());
    }

    #[test]
    fn dynamic_attention_operands_in_prefill_only() {
        let spec = gpt3_6_7b();
        let pre = prefill_kernels(&spec, 512);
        assert!(pre.iter().any(|k| !k.shape.weight_static));
        // In decode the KV cache is already DRAM-resident.
        let dec = decode_kernels(&spec, 512);
        assert!(dec.iter().all(|k| k.shape.weight_static));
    }
}
