//! Workloads: the LLM-to-kernel parser (paper §5.3, built in the spirit of
//! the LLMCompass-based parser of §5.1), standalone GEMM/GEMV sweeps, and
//! the end-to-end inference scenarios.

mod gemm;
mod llm;
mod racam;

pub use gemm::{gemm_sweep, gemv_sweep, SweepPoint};
pub use llm::{
    decode_kernels, decode_macs, decode_total, e2e_latency, prefill_kernels, stage_latency,
    KernelInstance,
};
pub use racam::RacamSystem;

use crate::metrics::LatencyBreakdown;
use crate::config::MatmulShape;

/// Anything that can price a matmul kernel: the RACAM simulator or one of
/// the baseline system models (H100, Proteus).
pub trait InferenceSystem {
    /// System name for reports.
    fn name(&self) -> &str;
    /// Latency of one kernel execution.
    fn kernel_latency(&mut self, shape: &MatmulShape) -> LatencyBreakdown;
}
