//! Workloads: the LLM-to-kernel parser (paper §5.3, built in the spirit of
//! the LLMCompass-based parser of §5.1), standalone GEMM/GEMV sweeps, and
//! the end-to-end inference scenarios.

mod gemm;
mod llm;
mod racam;

pub use gemm::{gemm_sweep, gemv_sweep, SweepPoint};
pub use llm::{
    decode_kernels, decode_macs, decode_total, e2e_latency, prefill_kernels, stage_latency,
    KernelInstance,
};
pub use racam::RacamSystem;

use crate::config::MatmulShape;
use crate::metrics::LatencyBreakdown;

/// Anything that can price a matmul kernel: the RACAM simulator (backed by
/// the shared [`crate::mapping::MappingService`]) or one of the baseline
/// system models (H100 roofline, Proteus).
///
/// Pricing is `&self` — implementations are internally synchronized (the
/// RACAM path caches through the thread-safe mapping service; the
/// baselines are pure functions), so one model instance can serve every
/// worker shard concurrently.  `kernel_cost` returns `None` only for
/// degenerate shapes (a zero-sized dimension) that no mapping can serve.
pub trait CostModel: Send + Sync {
    /// System name for reports.
    fn name(&self) -> &str;
    /// Latency of one kernel execution, or `None` for unpriceable shapes.
    fn kernel_cost(&self, shape: &MatmulShape) -> Option<LatencyBreakdown>;
}
