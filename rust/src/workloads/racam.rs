//! The RACAM system as a [`CostModel`]: every kernel goes through the
//! shared [`MappingService`] (cached parallel exhaustive search) and is
//! priced by the analytical models.  Constructing with
//! [`RacamSystem::with_service`] shares one mapping cache across any number
//! of systems — serving shards, experiments, baseline sweeps — so a
//! repeated shape is searched exactly once system-wide.

use super::CostModel;
use crate::config::{HwConfig, MatmulShape};
use crate::mapping::{MappingService, SearchResult};
use crate::metrics::LatencyBreakdown;

pub struct RacamSystem {
    name: String,
    service: MappingService,
}

impl RacamSystem {
    /// A system with its own (unshared) mapping service.
    pub fn new(hw: &HwConfig) -> Self {
        Self::with_service(MappingService::for_config(hw))
    }

    /// A system pricing against an existing shared mapping service.
    pub fn with_service(service: MappingService) -> Self {
        RacamSystem {
            name: format!("RACAM[{}]", service.hw().features().label()),
            service,
        }
    }

    /// The backing mapping service (shared cache, hit/miss counters,
    /// persistence hooks).
    pub fn service(&self) -> &MappingService {
        &self.service
    }

    /// Full search result (mapping + breakdown) for a kernel; `None` for
    /// degenerate shapes no mapping can serve.
    pub fn search(&self, shape: &MatmulShape) -> Option<SearchResult> {
        self.service.search_cached(shape)
    }
}

impl CostModel for RacamSystem {
    fn name(&self) -> &str {
        &self.name
    }

    fn kernel_cost(&self, shape: &MatmulShape) -> Option<LatencyBreakdown> {
        self.search(shape).map(|r| LatencyBreakdown::new(r.best.compute_ns, r.best.io_ns()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, MatmulShape, Precision};

    #[test]
    fn kernel_cost_matches_search_best() {
        let sys = RacamSystem::new(&racam_paper());
        let s = MatmulShape::new(1, 4096, 4096, Precision::Int8);
        let b = sys.kernel_cost(&s).unwrap();
        let r = sys.search(&s).unwrap();
        assert!((b.total_ns() - r.best.total_ns()).abs() < 1e-9);
    }

    #[test]
    fn name_carries_feature_label() {
        let sys = RacamSystem::new(&racam_paper());
        assert_eq!(sys.name(), "RACAM[Complete]");
    }

    #[test]
    fn degenerate_shape_is_unpriceable() {
        let sys = RacamSystem::new(&racam_paper());
        assert!(sys.kernel_cost(&MatmulShape::new(0, 64, 64, Precision::Int8)).is_none());
    }

    #[test]
    fn shared_service_dedupes_searches_across_systems() {
        let service = MappingService::for_config(&racam_paper());
        let a = RacamSystem::with_service(service.clone());
        let b = RacamSystem::with_service(service.clone());
        let s = MatmulShape::new(1, 2048, 2048, Precision::Int8);
        a.kernel_cost(&s).unwrap();
        b.kernel_cost(&s).unwrap();
        assert_eq!(service.misses(), 1, "one search serves both systems");
        assert_eq!(service.hits(), 1);
    }
}
