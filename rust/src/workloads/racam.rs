//! The RACAM system as an [`InferenceSystem`]: every kernel goes through
//! the mapping engine (cached exhaustive search) and is priced by the
//! analytical models.

use super::InferenceSystem;
use crate::config::{HwConfig, MatmulShape};
use crate::mapping::{HwModel, MappingEngine, SearchResult};
use crate::metrics::LatencyBreakdown;

pub struct RacamSystem {
    name: String,
    engine: MappingEngine,
}

impl RacamSystem {
    pub fn new(hw: &HwConfig) -> Self {
        RacamSystem { name: format!("RACAM[{}]", hw.features.label()), engine: MappingEngine::new(HwModel::new(hw)) }
    }

    pub fn engine(&self) -> &MappingEngine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut MappingEngine {
        &mut self.engine
    }

    /// Full search result (mapping + breakdown) for a kernel.
    pub fn search(&mut self, shape: &MatmulShape) -> SearchResult {
        self.engine.search_cached(shape)
    }
}

impl InferenceSystem for RacamSystem {
    fn name(&self) -> &str {
        &self.name
    }

    fn kernel_latency(&mut self, shape: &MatmulShape) -> LatencyBreakdown {
        let r = self.engine.search_cached(shape);
        LatencyBreakdown::new(r.best.compute_ns, r.best.io_ns())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, MatmulShape, Precision};

    #[test]
    fn kernel_latency_matches_search_best() {
        let mut sys = RacamSystem::new(&racam_paper());
        let s = MatmulShape::new(1, 4096, 4096, Precision::Int8);
        let b = sys.kernel_latency(&s);
        let r = sys.search(&s);
        assert!((b.total_ns() - r.best.total_ns()).abs() < 1e-9);
    }

    #[test]
    fn name_carries_feature_label() {
        let sys = RacamSystem::new(&racam_paper());
        assert_eq!(sys.name(), "RACAM[Complete]");
    }
}
