//! Functional block executor: computes GEMM tiles **bit-serially**, through
//! the same locality-buffer schedule, PE array and popcount unit the
//! analytical model prices.  This is the ground truth that (a) proves the
//! §3 micro-architecture computes correct products and (b) is cross-checked
//! against the AOT-compiled JAX/PJRT oracle in the integration tests and
//! the serving example.
//!
//! Signed operands use sign-magnitude: magnitudes multiply through the
//! Fig. 6 schedule, and the reduction runs one popcount pass over
//! positive-product lanes and one subtracting pass over negative lanes
//! (two accumulator passes per output, same hardware).

use super::bitplane::{lane_mask, to_planes};
use super::locality_buffer::LocalityBuffer;
use super::pe::PeArray;
use super::popcount::PopcountUnit;
use crate::config::{HwConfig, Precision};

/// Operation counters of a functional execution — compared against the
/// analytical model's predictions in the integration tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// SIMD multiply passes (one `pim_mul_red` each).
    pub passes: u64,
    /// Locality-buffer row accesses (loads + writebacks).
    pub row_accesses: u64,
    /// PE cycles.
    pub pe_cycles: u64,
    /// Popcount-unit cycles.
    pub popcount_cycles: u64,
    /// Scalar multiply-accumulates performed.
    pub macs: u64,
}

/// Functional executor for one block (one bank's PE width worth of columns).
pub struct BlockExecutor {
    width: u32,
    lb: LocalityBuffer,
    pes: PeArray,
    popcount: PopcountUnit,
    /// Reusable product-plane scratch (32 planes covers up to int16).
    scratch: Vec<Vec<u64>>,
}

impl BlockExecutor {
    pub fn new(hw: &HwConfig) -> Self {
        let width = hw.periph.pes_per_bank;
        let words = (width as usize).div_ceil(64);
        BlockExecutor {
            width,
            lb: LocalityBuffer::new(hw.periph.locality_buffer_rows, width),
            pes: PeArray::new(width),
            popcount: PopcountUnit::new(hw.periph.popcount_width),
            scratch: vec![vec![0u64; words]; 32],
        }
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    /// `O[M,N] = I[M,K] · W[K,N]` with signed `prec`-bit operands,
    /// row-major buffers, i32-range outputs.
    ///
    /// Layout is the paper's `{R: MN, C: K}` block mapping: each output
    /// element reduces K across columns via `pim_mul_red`, chunked by PE
    /// width when K exceeds it (the extra chunks accumulate through
    /// `pim_add_parallel`, i.e. the popcount accumulator).
    pub fn gemm(
        &mut self,
        i_mat: &[i64],
        w_mat: &[i64],
        m: usize,
        k: usize,
        n: usize,
        prec: Precision,
    ) -> (Vec<i64>, ExecStats) {
        assert_eq!(i_mat.len(), m * k);
        assert_eq!(w_mat.len(), k * n);
        let bits = prec.bits() as usize;
        let bound = 1i64 << (bits - 1);
        let in_range = |v: &i64| *v >= -bound && *v < bound;
        assert!(i_mat.iter().all(in_range), "input exceeds {}-bit signed range", bits);
        assert!(w_mat.iter().all(in_range), "weight exceeds {}-bit signed range", bits);

        let mut stats = ExecStats::default();
        let mut out = vec![0i64; m * n];
        let width = self.width as usize;
        let words = width.div_ceil(64);
        let chunks = k.div_ceil(width);

        // Pre-pack every operand chunk once (hot path): the input's
        // (chunk, row) planes and the weight's (chunk, col) planes are
        // reused across all n (resp. m) outputs — the software analogue of
        // the locality buffer's operand reuse.
        let pack = |vals: &mut dyn Iterator<Item = i64>| -> (Vec<Vec<u64>>, Vec<u64>) {
            let mut mags = Vec::with_capacity(width);
            let mut sign = vec![0u64; words];
            for (lane, v) in vals.enumerate() {
                mags.push(v.unsigned_abs());
                if v < 0 {
                    sign[lane / 64] |= 1 << (lane % 64);
                }
            }
            (to_planes(&mags, bits, self.width), sign)
        };
        let mut i_packed = Vec::with_capacity(chunks * m);
        let mut w_packed = Vec::with_capacity(chunks * n);
        for c in 0..chunks {
            let k0 = c * width;
            let kc = (k - k0).min(width);
            for mi in 0..m {
                i_packed.push(pack(&mut (k0..k0 + kc).map(|kk| i_mat[mi * k + kk])));
            }
            for ni in 0..n {
                w_packed.push(pack(&mut (k0..k0 + kc).map(|kk| w_mat[kk * n + ni])));
            }
        }

        for mi in 0..m {
            for ni in 0..n {
                let mut acc = 0i64;
                for c in 0..chunks {
                    let k0 = c * width;
                    let kc = (k - k0).min(width);
                    let (op1, i_sign) = &i_packed[c * m + mi];
                    let (op2, w_sign) = &w_packed[c * n + ni];
                    // Product sign per lane: sign(i) XOR sign(w).
                    let neg_mask: Vec<u64> =
                        i_sign.iter().zip(w_sign).map(|(a, b)| a ^ b).collect();
                    // pim_mul_red over the chunk: Fig. 6 multiply …
                    let trace =
                        self.lb.multiply_into(&mut self.pes, op1, op2, &mut self.scratch);
                    let prod = &self.scratch[..2 * bits];
                    stats.passes += 1;
                    stats.row_accesses += trace.total_row_accesses();
                    stats.pe_cycles += trace.pe_cycles;
                    stats.macs += kc as u64;

                    // … then the two-pass signed popcount reduction: one
                    // accumulating pass over positive-product lanes, one
                    // subtracting pass over negative lanes (masks built
                    // once per chunk).
                    let valid = lane_mask(kc as u32, self.width);
                    let pos_mask: Vec<u64> =
                        valid.iter().zip(&neg_mask).map(|(v, nm)| v & !nm).collect();
                    let sub_mask: Vec<u64> =
                        valid.iter().zip(&neg_mask).map(|(v, nm)| v & nm).collect();
                    self.popcount.clear();
                    for (sig, plane) in prod.iter().enumerate() {
                        self.popcount.consume_masked(plane, &pos_mask, sig as u32, false);
                        self.popcount.consume_masked(plane, &sub_mask, sig as u32, true);
                    }
                    // pim_add_parallel folds the chunk into the output.
                    acc = self.popcount.add_parallel(acc, self.popcount.sum());
                }
                stats.popcount_cycles = self.popcount.cycles();
                out[mi * n + ni] = acc;
            }
        }
        (out, stats)
    }
}

/// Plain scalar GEMM reference (i64 accumulation).
pub fn gemm_reference(i_mat: &[i64], w_mat: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0i64;
            for kk in 0..k {
                acc += i_mat[mi * k + kk] * w_mat[kk * n + ni];
            }
            out[mi * n + ni] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::racam_tiny;

    fn lcg(seed: &mut u64) -> i64 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (*seed >> 33) as i64
    }

    fn rand_mat(len: usize, bound: i64, seed: &mut u64) -> Vec<i64> {
        (0..len).map(|_| lcg(seed).rem_euclid(2 * bound) - bound).collect()
    }

    #[test]
    fn int8_gemm_matches_reference() {
        let mut seed = 42;
        let (m, k, n) = (4, 200, 3); // k > PE width (128) forces chunking
        let i_mat = rand_mat(m * k, 128, &mut seed);
        let w_mat = rand_mat(k * n, 128, &mut seed);
        let mut ex = BlockExecutor::new(&racam_tiny());
        let (got, stats) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        assert_eq!(got, gemm_reference(&i_mat, &w_mat, m, k, n));
        assert_eq!(stats.macs, (m * k * n) as u64);
        assert_eq!(stats.passes, (m * n * 2) as u64); // ceil(200/128) = 2 chunks
    }

    #[test]
    fn int4_and_int2_gemm() {
        let mut seed = 7;
        let (m, k, n) = (3, 64, 5);
        for (prec, bound) in [(Precision::Int4, 8i64), (Precision::Int2, 2)] {
            let i_mat = rand_mat(m * k, bound, &mut seed);
            let w_mat = rand_mat(k * n, bound, &mut seed);
            let mut ex = BlockExecutor::new(&racam_tiny());
            let (got, _) = ex.gemm(&i_mat, &w_mat, m, k, n, prec);
            assert_eq!(got, gemm_reference(&i_mat, &w_mat, m, k, n), "{prec:?}");
        }
    }

    #[test]
    fn gemv_path() {
        let mut seed = 99;
        let (m, k, n) = (1, 300, 4);
        let i_mat = rand_mat(m * k, 128, &mut seed);
        let w_mat = rand_mat(k * n, 128, &mut seed);
        let mut ex = BlockExecutor::new(&racam_tiny());
        let (got, _) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        assert_eq!(got, gemm_reference(&i_mat, &w_mat, m, k, n));
    }

    #[test]
    fn extreme_values() {
        // -128 magnitudes and all-negative operands.
        let i_mat = vec![-128, 127, -128, 127];
        let w_mat = vec![-128, -128, 127, 127, -1, 1, 0, -128];
        let mut ex = BlockExecutor::new(&racam_tiny());
        let (got, _) = ex.gemm(&i_mat, &w_mat, 2, 2, 4, Precision::Int8);
        assert_eq!(got, gemm_reference(&i_mat, &w_mat, 2, 2, 4));
    }

    #[test]
    fn row_access_accounting_is_o_n() {
        let (m, k, n) = (2, 64, 2);
        let i_mat = vec![1i64; m * k];
        let w_mat = vec![1i64; k * n];
        let mut ex = BlockExecutor::new(&racam_tiny());
        let (_, s8) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        let mut ex = BlockExecutor::new(&racam_tiny());
        let (_, s4) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int4);
        // 4n row accesses per pass: int8 = 32/pass, int4 = 16/pass.
        assert_eq!(s8.row_accesses, s8.passes * 32);
        assert_eq!(s4.row_accesses, s4.passes * 16);
    }

    #[test]
    #[should_panic(expected = "signed range")]
    fn range_check() {
        let mut ex = BlockExecutor::new(&racam_tiny());
        ex.gemm(&[300], &[1], 1, 1, 1, Precision::Int8);
    }
}
