//! Trace-driven validation: expand a PIM instruction through the device
//! FSM into its *actual DRAM command stream* (ACT/RD/WR per micro-op, with
//! SALP round-robin row placement), price it on the cycle-accounting
//! [`CommandTimer`], and compare against the closed-form analytical model —
//! the same role Ramulator validation plays in the paper's methodology
//! (§5.1).

use super::fsm::{DeviceFsm, MicroOp};
use crate::config::{Features, Precision, TimingParams};
use crate::dram::{CommandTimer, DramCommand, SalpScheduler, TimingStats};

/// Result of tracing one PIM instruction.
#[derive(Debug, Clone)]
pub struct TraceResult {
    /// DRAM command statistics from the cycle-accounting timer.
    pub stats: TimingStats,
    /// PE-pipeline time, ns (overlaps the row stream in hardware).
    pub pe_ns: f64,
    /// Serial (non-overlapped) trace latency from the command timer, ns.
    pub serial_ns: f64,
    /// Row accesses observed in the trace (loads + writebacks).
    pub row_accesses: u64,
}

/// Expand `cmd` through a fresh FSM and price the command stream.
///
/// Rows are placed round-robin across `subarrays` (the §3.3 SALP layout);
/// each `LoadPlane`/`WritePlane` micro-op becomes ACT+RD / ACT+WR on the
/// next subarray in rotation.
pub fn trace_instruction(
    cmd: &DramCommand,
    subarrays: u32,
    t: &TimingParams,
) -> Result<TraceResult, super::fsm::FsmError> {
    let mut fsm = DeviceFsm::new(16);
    fsm.dispatch(&DramCommand::PimEnable)?;
    let micro_ops = fsm.dispatch(cmd)?;

    let mut timer = CommandTimer::new(*t);
    let mut pe_cycles: u64 = 0;
    let mut row_accesses: u64 = 0;
    let mut rotation = 0u32;

    // SALP placement: access i lands on subarray i mod S, row i / S —
    // successive accesses never share a subarray and every visit opens a
    // fresh row (streaming operand planes, not revisiting).
    let mut place = |timer: &mut CommandTimer, write: bool| {
        let bank = rotation % subarrays;
        let row = rotation / subarrays;
        timer.issue(&DramCommand::Act { bank, row });
        if write {
            timer.issue(&DramCommand::Wr { bank, col: 0 });
        } else {
            timer.issue(&DramCommand::Rd { bank, col: 0 });
        }
        rotation += 1;
    };

    for op in &micro_ops {
        match op {
            MicroOp::LoadPlane { .. } => {
                place(&mut timer, false);
                row_accesses += 1;
            }
            MicroOp::WritePlane | MicroOp::WriteHorizontal => {
                place(&mut timer, true);
                row_accesses += 1;
            }
            MicroOp::PeStep | MicroOp::CarryOut => pe_cycles += 1,
            MicroOp::PopcountSlice { .. } => pe_cycles += t.popcount_cycles as u64,
            MicroOp::ParallelAdd => pe_cycles += t.parallel_add_cycles as u64,
            MicroOp::SetModeRegister { .. } => {}
        }
    }

    Ok(TraceResult {
        serial_ns: timer.elapsed_ns(),
        stats: timer.stats().clone(),
        pe_ns: pe_cycles as f64 * t.pe_cycle_ns(),
        row_accesses,
    })
}

/// Validate the analytical instruction model against the trace for one
/// instruction class: returns (analytical_row_accesses, traced_row_accesses,
/// analytical_ns, trace_overlapped_ns).
pub fn validate_against_analytical(
    prec: Precision,
    subarrays: u32,
    t: &TimingParams,
) -> crate::Result<(u64, u64, f64, f64)> {
    let cmd = DramCommand::PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: prec.bits() as u8 };
    let trace = trace_instruction(&cmd, subarrays, t)?;
    let salp = SalpScheduler::new(*t, subarrays);
    let analytical =
        super::isa::instr_latency(super::isa::InstrClass::Mul, prec, t, &salp, &Features::ALL);
    // Overlap the traced stream the way SALP does: rows pipeline at one
    // beat each behind the PE pipeline.
    let overlapped = trace.pe_ns.max(trace.row_accesses as f64 * t.t_cas_ns);
    Ok((analytical.row_accesses, trace.row_accesses, analytical.total_ns(), overlapped))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ddr5_5200_timing;

    #[test]
    fn traced_row_accesses_match_analytical_exactly() {
        let t = ddr5_5200_timing();
        for prec in [Precision::Int2, Precision::Int4, Precision::Int8] {
            let (analytical, traced, _, _) = validate_against_analytical(prec, 128, &t).unwrap();
            assert_eq!(analytical, traced, "{prec:?}");
            assert_eq!(traced, 4 * prec.bits() as u64);
        }
    }

    #[test]
    fn overlapped_trace_latency_matches_analytical_model() {
        let t = ddr5_5200_timing();
        for prec in [Precision::Int4, Precision::Int8] {
            let (_, _, analytical_ns, overlapped_ns) = validate_against_analytical(prec, 128, &t).unwrap();
            let rel = (analytical_ns - overlapped_ns).abs() / analytical_ns;
            assert!(rel < 0.05, "{prec:?}: analytical {analytical_ns} vs trace {overlapped_ns}");
        }
    }

    #[test]
    fn trace_counts_activations_per_subarray_rotation() {
        let t = ddr5_5200_timing();
        let cmd = DramCommand::PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 8 };
        let trace = trace_instruction(&cmd, 4, &t).unwrap();
        // 32 row accesses across a 4-subarray rotation: every access is a
        // row switch on its subarray (rows advance), so ACT count equals
        // accesses.
        assert_eq!(trace.stats.activations, 32);
        assert_eq!(trace.stats.reads, 16); // op1 + op2 planes
        assert_eq!(trace.stats.writes, 16); // 2n product planes
    }

    #[test]
    fn serial_trace_is_slower_than_overlapped() {
        let t = ddr5_5200_timing();
        let cmd = DramCommand::PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 8 };
        let trace = trace_instruction(&cmd, 128, &t).unwrap();
        let overlapped = trace.pe_ns.max(trace.row_accesses as f64 * t.t_cas_ns);
        assert!(trace.serial_ns > overlapped, "{} vs {overlapped}", trace.serial_ns);
    }

    #[test]
    fn compute_commands_require_pim_mode() {
        let t = ddr5_5200_timing();
        // trace_instruction itself enables PIM mode; a raw FSM must refuse.
        let mut fsm = DeviceFsm::new(8);
        assert!(fsm
            .dispatch(&DramCommand::PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 8 })
            .is_err());
        let _ = t;
    }
}
