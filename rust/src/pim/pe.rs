//! Bit-serial processing element (paper §3.2, Fig. 5a).
//!
//! One PE is attached to each column of the locality buffer.  Per cycle it
//! sees three input bits — `A` (op1 bit), `B` (op2 bit, the gate), `C`
//! (current result bit) — and an internal carry:
//!
//! * `B = 1`: full-add `A + C + carry` → output bit, update carry.
//! * `B = 0`: route `C` through unchanged, hold the carry.
//!
//! The simulator never models PEs one at a time: [`PeWord`] packs 64 PE
//! lanes into `u64` bitwise logic (the functional hot path), and [`PeArray`]
//! is a whole bank's worth of lanes.

/// 64 bit-serial PEs evaluated in parallel with word-wide boolean algebra.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeWord {
    carry: u64,
}

impl PeWord {
    pub fn new() -> Self {
        PeWord { carry: 0 }
    }

    pub fn carry(&self) -> u64 {
        self.carry
    }

    /// Reset carries (start of a new serial add).
    pub fn clear(&mut self) {
        self.carry = 0;
    }

    /// One PE cycle across 64 lanes. Returns the 64 output bits.
    #[inline]
    pub fn step(&mut self, a: u64, b: u64, c: u64) -> u64 {
        let sum = a ^ c ^ self.carry;
        let maj = (a & c) | (a & self.carry) | (c & self.carry);
        let out = (b & sum) | (!b & c);
        self.carry = (b & maj) | (!b & self.carry);
        out
    }

    /// Drain the carry into an output bit where `b` is set (the final
    /// carry-out write of a serial add window).
    #[inline]
    pub fn carry_out(&mut self, b: u64) -> u64 {
        let out = b & self.carry;
        self.carry &= !b;
        out
    }
}

/// A bank's PE array: `width` PEs as `ceil(width/64)` packed words.
#[derive(Debug, Clone)]
pub struct PeArray {
    width: u32,
    words: Vec<PeWord>,
}

impl PeArray {
    pub fn new(width: u32) -> Self {
        PeArray { width, words: vec![PeWord::new(); (width as usize).div_ceil(64)] }
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    pub fn num_words(&self) -> usize {
        self.words.len()
    }

    pub fn clear(&mut self) {
        for w in &mut self.words {
            w.clear();
        }
    }

    /// One cycle over the whole array. `a`, `b`, `c` are packed bit-planes
    /// (one bit per column); `out` receives the output plane.
    pub fn step_plane(&mut self, a: &[u64], b: &[u64], c: &[u64], out: &mut [u64]) {
        for (i, w) in self.words.iter_mut().enumerate() {
            out[i] = w.step(a[i], b[i], c[i]);
        }
    }

    /// Final carry-out plane for lanes where `b` is set.
    pub fn carry_out_plane(&mut self, b: &[u64], out: &mut [u64]) {
        for (i, w) in self.words.iter_mut().enumerate() {
            out[i] = w.carry_out(b[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scalar reference: serial add of two `n`-bit values through one PE.
    fn serial_add_via_pe(x: u64, y: u64, n: u32) -> u64 {
        let mut pe = PeWord::new();
        let mut out = 0u64;
        for i in 0..n {
            let a = (x >> i) & 1;
            let c = (y >> i) & 1;
            // Use lane 0 only; B=1 everywhere.
            let bit = pe.step(a.wrapping_neg() & 1, u64::MAX, c.wrapping_neg() & 1) & 1;
            out |= bit << i;
        }
        out |= (pe.carry_out(u64::MAX) & 1) << n;
        out
    }

    #[test]
    fn serial_add_matches_integer_add() {
        for x in 0..16u64 {
            for y in 0..16u64 {
                assert_eq!(serial_add_via_pe(x, y, 4), x + y, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn b_zero_routes_c_through() {
        let mut pe = PeWord::new();
        // Set up a pending carry in every lane.
        pe.step(u64::MAX, u64::MAX, u64::MAX); // 1+1 -> carry=1
        let carry_before = pe.carry();
        let out = pe.step(u64::MAX, 0, 0xDEADBEEF);
        assert_eq!(out, 0xDEADBEEF, "C must pass through when B=0");
        assert_eq!(pe.carry(), carry_before, "carry must hold when B=0");
    }

    #[test]
    fn lanes_are_independent() {
        let mut pe = PeWord::new();
        // Lane 0: 1+1 (carry); lane 1: 0+0 (no carry). B=1 both.
        let out = pe.step(0b01, u64::MAX, 0b01);
        assert_eq!(out & 0b11, 0b00);
        assert_eq!(pe.carry() & 0b11, 0b01);
    }

    #[test]
    fn array_planes() {
        let mut arr = PeArray::new(128);
        assert_eq!(arr.num_words(), 2);
        let a = vec![u64::MAX; 2];
        let b = vec![u64::MAX; 2];
        let c = vec![0u64; 2];
        let mut out = vec![0u64; 2];
        arr.step_plane(&a, &b, &c, &mut out);
        assert_eq!(out, vec![u64::MAX; 2]); // 1+0 = 1, no carry
        arr.step_plane(&a, &b, &a, &mut out);
        assert_eq!(out, vec![0u64; 2]); // 1+1 = 0 carry 1
        arr.carry_out_plane(&b, &mut out);
        assert_eq!(out, vec![u64::MAX; 2]);
    }

    #[test]
    fn clear_resets_carry() {
        let mut pe = PeWord::new();
        pe.step(u64::MAX, u64::MAX, u64::MAX);
        assert_ne!(pe.carry(), 0);
        pe.clear();
        assert_eq!(pe.carry(), 0);
    }
}
