//! Latency model of the extended PIM instruction set (paper Table 1 +
//! §3.2–3.4).  This is the *compute model* half of the paper's hardware
//! model: given an instruction class, operand precision and the feature set,
//! it returns the block-level latency split into PE time and row-traffic
//! time, mirroring how the paper's analytical model sums "latencies of all
//! PIM instructions executed on the locality buffers, PEs, and reduction
//! units".

use crate::config::{Features, Precision, TimingParams};
use crate::dram::SalpScheduler;

/// The compute instruction classes of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InstrClass {
    /// `pim_add`: bit-serial addition.
    Add,
    /// `pim_mul`: bit-serial multiplication.
    Mul,
    /// `pim_mul_red`: multiplication fused with column-wise popcount
    /// reduction.
    MulRed,
    /// `pim_add_parallel`: int32 bit-parallel add in the reduction unit.
    AddParallel,
}

/// Latency decomposition of one SIMD instruction pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InstrLatency {
    /// PE pipeline time, ns.
    pub pe_ns: f64,
    /// Row-traffic time (array ↔ locality buffer / array RMW), ns.
    pub row_ns: f64,
    /// Reduction-unit drain time, ns (MulRed / AddParallel only).
    pub reduce_ns: f64,
    /// Row accesses performed (the Fig. 1 x-axis quantity).
    pub row_accesses: u64,
}

impl InstrLatency {
    /// Total latency: PE work overlaps row streaming (both are pipelined
    /// against each other, §3.3), the reduction drain is serial.
    pub fn total_ns(&self) -> f64 {
        self.pe_ns.max(self.row_ns) + self.reduce_ns
    }
}

/// Row accesses of an n-bit multiply for each design point (Table 5's
/// "Row ACTs of n-bit Mult" column).
pub fn mul_row_accesses(n: u64, locality_buffer: bool) -> u64 {
    if locality_buffer {
        // op1 once (n) + op2 once (n) + 2n result writebacks — O(n).
        4 * n
    } else {
        // Every multiplier bit re-reads the multiplicand from the array and
        // read-modify-writes the result window — O(n²).
        n * n + 3 * n
    }
}

/// Latency of one SIMD instruction pass over one block.
///
/// `t` is the timing preset, `salp` prices the row stream, `f` selects the
/// present hardware.  The pass covers the whole PE width regardless of how
/// many columns carry valid data (the utilization model accounts waste).
pub fn instr_latency(
    class: InstrClass,
    prec: Precision,
    t: &TimingParams,
    salp: &SalpScheduler,
    f: &Features,
) -> InstrLatency {
    let n = prec.bits() as u64;
    let cyc = t.pe_cycle_ns();
    match class {
        InstrClass::Add => {
            // Serial add: one PE cycle per bit + carry, operands/result
            // stream through the buffer (3n+1 planes).
            let pe = (n + 2) as f64 * cyc;
            let rows = 3 * n + 1;
            let (row_ns, row_accesses) = row_traffic(rows, rows, t, salp, f);
            InstrLatency { pe_ns: pe, row_ns, reduce_ns: 0.0, row_accesses }
        }
        InstrClass::Mul => {
            let pe = (n * n + 4) as f64 * cyc;
            let accesses = mul_row_accesses(n, f.locality_buffer);
            let (row_ns, row_accesses) =
                row_traffic(accesses, mul_row_accesses(n, true), t, salp, f);
            InstrLatency { pe_ns: pe, row_ns, reduce_ns: 0.0, row_accesses }
        }
        InstrClass::MulRed => {
            let mul = instr_latency(InstrClass::Mul, prec, t, salp, f);
            // The popcount unit consumes product bit-slices as the multiply
            // produces them ("efficiently pipelined", §3.4); only the tail
            // slice, the accumulator add and the horizontal writeback are
            // exposed — the fixed cost that makes Fig. 14 sub-linear.
            let reduce = if f.popcount_reduction {
                (t.popcount_cycles + t.parallel_add_cycles) as f64 * cyc + t.t_cas_ns
            } else {
                // Without PR the reduction happens host-side; the I/O model
                // prices the export. No in-DRAM drain.
                0.0
            };
            InstrLatency {
                pe_ns: mul.pe_ns,
                row_ns: mul.row_ns,
                reduce_ns: reduce,
                row_accesses: mul.row_accesses + f.popcount_reduction as u64,
            }
        }
        InstrClass::AddParallel => {
            let reduce = t.parallel_add_cycles as f64 * cyc;
            // Read two horizontal int32 rows, write one.
            let (row_ns, row_accesses) = row_traffic(3, 3, t, salp, f);
            InstrLatency { pe_ns: 0.0, row_ns, reduce_ns: reduce, row_accesses }
        }
    }
}

/// Price `accesses` row accesses.  With the locality buffer the stream
/// overlaps via SALP at steady state (back-to-back passes amortize the
/// pipeline fill); without it (`lb_accesses` < `accesses`) the extra
/// accesses are read-modify-write round trips to the cell array that cannot
/// pipeline as deeply — each pays a global-bus turnaround on top of the beat.
fn row_traffic(
    accesses: u64,
    lb_accesses: u64,
    t: &TimingParams,
    salp: &SalpScheduler,
    f: &Features,
) -> (f64, u64) {
    if f.locality_buffer {
        (salp.steady_stream_ns(lb_accesses), lb_accesses)
    } else {
        const RMW_TURNAROUND_NS: f64 = 4.0;
        let ns = t.t_rcd_ns + accesses as f64 * (t.t_cas_ns + RMW_TURNAROUND_NS);
        (ns, accesses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ddr5_5200_timing, Features};

    fn setup() -> (TimingParams, SalpScheduler) {
        let t = ddr5_5200_timing();
        (t, SalpScheduler::new(t, 128))
    }

    #[test]
    fn row_accesses_linear_vs_quadratic() {
        // Table 5: O(n) with LB, O(n²) without.
        assert_eq!(mul_row_accesses(8, true), 32);
        assert_eq!(mul_row_accesses(16, true), 64);
        assert_eq!(mul_row_accesses(8, false), 88);
        assert_eq!(mul_row_accesses(16, false), 304);
        // Doubling n doubles LB accesses but ~4x the no-LB accesses.
        let r = mul_row_accesses(16, false) as f64 / mul_row_accesses(8, false) as f64;
        assert!(r > 3.0, "no-LB growth must be superlinear, got {r}");
    }

    #[test]
    fn lb_ablation_slows_multiplies_several_fold() {
        let (t, salp) = setup();
        let with_lb = instr_latency(InstrClass::Mul, Precision::Int8, &t, &salp, &Features::ALL);
        let no_lb =
            instr_latency(InstrClass::Mul, Precision::Int8, &t, &salp, &Features::NO_PR_BU_LB);
        let ratio = no_lb.total_ns() / with_lb.total_ns();
        // Paper Fig. 12: removing LB costs ~7.5–8x on multiply-dominated
        // (prefill) workloads.
        assert!((4.0..12.0).contains(&ratio), "LB ablation ratio {ratio}");
    }

    #[test]
    fn latency_scales_roughly_linearly_with_precision() {
        // Paper Fig. 14: int8→int4 ≈ 2x, int8→int2 ≈ 3.5–3.8x.
        let (t, salp) = setup();
        let f = Features::ALL;
        let l8 = instr_latency(InstrClass::MulRed, Precision::Int8, &t, &salp, &f).total_ns();
        let l4 = instr_latency(InstrClass::MulRed, Precision::Int4, &t, &salp, &f).total_ns();
        let l2 = instr_latency(InstrClass::MulRed, Precision::Int2, &t, &salp, &f).total_ns();
        assert!((1.5..3.0).contains(&(l8 / l4)), "int8/int4 = {}", l8 / l4);
        assert!((2.5..5.0).contains(&(l8 / l2)), "int8/int2 = {}", l8 / l2);
        assert!(l8 / l2 < 4.0 * 1.2, "sub-linear due to fixed reduction overhead");
    }

    #[test]
    fn mulred_only_adds_drain_when_pr_present() {
        let (t, salp) = setup();
        let with_pr =
            instr_latency(InstrClass::MulRed, Precision::Int8, &t, &salp, &Features::ALL);
        let no_pr =
            instr_latency(InstrClass::MulRed, Precision::Int8, &t, &salp, &Features::NO_PR);
        assert!(with_pr.reduce_ns > 0.0);
        assert_eq!(no_pr.reduce_ns, 0.0);
    }

    #[test]
    fn add_parallel_is_cheap() {
        let (t, salp) = setup();
        let ap = instr_latency(InstrClass::AddParallel, Precision::Int8, &t, &salp, &Features::ALL);
        let mul = instr_latency(InstrClass::Mul, Precision::Int8, &t, &salp, &Features::ALL);
        assert!(ap.total_ns() < mul.total_ns() / 2.0);
    }

    #[test]
    fn int8_mul_pass_is_row_stream_bound_at_68ns() {
        // Calibration sanity: with LB the multiply is bound by the 4n-beat
        // row stream (32 × 2.125 ns = 68 ns), the PE pipeline hides under
        // it, and the whole system lands on Table 4's 986.9 TOPS.
        let (t, salp) = setup();
        let l = instr_latency(InstrClass::Mul, Precision::Int8, &t, &salp, &Features::ALL);
        assert!(l.row_ns >= l.pe_ns, "pe={} row={}", l.pe_ns, l.row_ns);
        assert!((l.total_ns() - 68.0).abs() < 1e-9, "{}", l.total_ns());
    }

    #[test]
    fn mul_pass_scales_near_linearly_with_precision() {
        // Fig. 1's green curve: per-pass latency ∝ 4n row beats.
        let (t, salp) = setup();
        let l8 = instr_latency(InstrClass::Mul, Precision::Int8, &t, &salp, &Features::ALL);
        let l4 = instr_latency(InstrClass::Mul, Precision::Int4, &t, &salp, &Features::ALL);
        assert!((l8.total_ns() / l4.total_ns() - 2.0).abs() < 0.05);
    }
}
