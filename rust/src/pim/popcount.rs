//! Popcount reduction unit (paper §3.4, Fig. 5b).
//!
//! The vertical layout exposes one bit of every operand per cycle, so a
//! popcount over the bit-slice, shifted by the slice's significance and
//! accumulated — `sum += popcount(bitslice_i) · 2^i` — reduces a whole
//! column group with one pass over the product's bit-planes.  The same
//! accumulator doubles as the fast int32 bit-parallel adder behind
//! `pim_add_parallel`.

/// One popcount reduction unit: popcount module + shift + accumulator.
#[derive(Debug, Clone)]
pub struct PopcountUnit {
    /// Columns consumed per cycle (paper: 1024 per bank).
    width: u32,
    /// Accumulator register (int64 here; hardware is int32 with the
    /// software model guaranteeing no overflow per reduction group).
    acc: i64,
    /// Cycles spent (for the timing model cross-check).
    cycles: u64,
}

impl PopcountUnit {
    pub fn new(width: u32) -> Self {
        PopcountUnit { width, acc: 0, cycles: 0 }
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    pub fn clear(&mut self) {
        self.acc = 0;
    }

    pub fn sum(&self) -> i64 {
        self.acc
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Consume one bit-slice (packed words, `valid` columns) of
    /// significance `i`: `acc += popcount(slice) << i`.
    pub fn consume_slice(&mut self, slice: &[u64], valid: u32, significance: u32) {
        debug_assert!(valid <= self.width);
        let ones = popcount_masked(slice, valid);
        self.acc += (ones as i64) << significance;
        self.cycles += 1;
    }

    /// Signed variant: subtract instead of add (used for the
    /// negative-product pass of signed reductions).
    pub fn consume_slice_neg(&mut self, slice: &[u64], valid: u32, significance: u32) {
        let ones = popcount_masked(slice, valid);
        self.acc -= (ones as i64) << significance;
        self.cycles += 1;
    }

    /// Masked variant (hot path): `acc ±= popcount(slice & mask) << sig`
    /// without materializing the masked plane.
    pub fn consume_masked(&mut self, slice: &[u64], mask: &[u64], significance: u32, negative: bool) {
        let ones: u64 = slice.iter().zip(mask).map(|(s, m)| (s & m).count_ones() as u64).sum();
        if negative {
            self.acc -= (ones as i64) << significance;
        } else {
            self.acc += (ones as i64) << significance;
        }
        self.cycles += 1;
    }

    /// `pim_add_parallel`: bit-parallel add through the accumulator.
    pub fn add_parallel(&mut self, a: i64, b: i64) -> i64 {
        self.cycles += 1;
        a.wrapping_add(b)
    }
}

/// Popcount of the first `valid` bits of a packed slice.
fn popcount_masked(slice: &[u64], valid: u32) -> u64 {
    let full = (valid / 64) as usize;
    let mut ones: u64 = slice[..full].iter().map(|w| w.count_ones() as u64).sum();
    let rem = valid % 64;
    if rem != 0 {
        ones += (slice[full] & ((1u64 << rem) - 1)).count_ones() as u64;
    }
    ones
}

/// Reduce a product given as bit-planes over `valid` columns:
/// `Σ_cols Σ_i plane_i[col] · 2^i` — the full `pim_mul_red` reduction.
pub fn popcount_reduce_slices(planes: &[Vec<u64>], valid: u32) -> i64 {
    let mut unit = PopcountUnit::new(valid);
    for (i, plane) in planes.iter().enumerate() {
        unit.consume_slice(plane, valid, i as u32);
    }
    unit.sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_popcount() {
        assert_eq!(popcount_masked(&[u64::MAX, u64::MAX], 128), 128);
        assert_eq!(popcount_masked(&[u64::MAX, u64::MAX], 70), 70);
        assert_eq!(popcount_masked(&[u64::MAX, 0], 64), 64);
        assert_eq!(popcount_masked(&[0b1011, 0], 3), 2); // bit 3 masked off
    }

    #[test]
    fn reduction_equals_scalar_sum() {
        // 100 values, 16-bit planes.
        let vals: Vec<u64> = (0..100).map(|i| (i * i * 7 + 13) % 65536).collect();
        let width = 128u32;
        let planes = crate::pim::bitplane::to_planes(&vals, 16, width);
        let got = popcount_reduce_slices(&planes, 100);
        let want: i64 = vals.iter().map(|&v| v as i64).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn significance_shifts() {
        let mut u = PopcountUnit::new(64);
        u.consume_slice(&[0b11], 64, 0); // 2·1
        u.consume_slice(&[0b1], 64, 3); // 1·8
        assert_eq!(u.sum(), 10);
        assert_eq!(u.cycles(), 2);
    }

    #[test]
    fn negative_pass() {
        let mut u = PopcountUnit::new(64);
        u.consume_slice(&[0b111], 64, 2); // +12
        u.consume_slice_neg(&[0b1], 64, 4); // −16
        assert_eq!(u.sum(), -4);
    }

    #[test]
    fn parallel_add() {
        let mut u = PopcountUnit::new(64);
        assert_eq!(u.add_parallel(1 << 30, 12345), (1 << 30) + 12345);
        assert_eq!(u.add_parallel(-5, 3), -2);
    }

    #[test]
    fn clear_resets_accumulator_only() {
        let mut u = PopcountUnit::new(64);
        u.consume_slice(&[u64::MAX], 64, 0);
        let c = u.cycles();
        u.clear();
        assert_eq!(u.sum(), 0);
        assert_eq!(u.cycles(), c);
    }
}
