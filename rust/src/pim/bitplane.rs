//! Bit-plane packing: the word-packed transposed representation shared by
//! the locality buffer, popcount unit and functional executor (one `u64`
//! word = 64 columns; plane *i* holds bit *i* of every column's operand).

/// Pack per-lane values into `bits` bit-planes over `width` columns
/// (lane *l*'s bit *i* → `planes[i]` bit *l*).
///
/// Hot path: uses the 64×64 butterfly transpose per word column (the same
/// hardware trick the §2.2 transpose unit implements) instead of
/// bit-by-bit packing.
pub fn to_planes(values: &[u64], bits: usize, width: u32) -> Vec<Vec<u64>> {
    assert!(values.len() <= width as usize, "more values than columns");
    let words = (width as usize).div_ceil(64);
    let mut planes = vec![vec![0u64; words]; bits];
    let mask = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut block = [0u64; 64];
    for wi in 0..words {
        block.fill(0);
        let base = wi * 64;
        for lane in 0..64 {
            if let Some(&v) = values.get(base + lane) {
                block[lane] = v & mask;
            }
        }
        super::transpose::transpose64(&mut block);
        for (i, plane) in planes.iter_mut().enumerate() {
            plane[wi] = block[i];
        }
    }
    planes
}

/// Unpack the first `count` lanes of a set of bit-planes back to values.
pub fn from_planes(planes: &[Vec<u64>], count: usize) -> Vec<u64> {
    (0..count)
        .map(|lane| {
            planes
                .iter()
                .enumerate()
                .fold(0u64, |acc, (i, p)| acc | (((p[lane / 64] >> (lane % 64)) & 1) << i))
        })
        .collect()
}

/// Lane-mask with the low `valid` bits set, as packed words.
pub fn lane_mask(valid: u32, width: u32) -> Vec<u64> {
    let words = (width as usize).div_ceil(64);
    let mut mask = vec![0u64; words];
    for w in 0..words {
        let lo = (w * 64) as u32;
        if valid >= lo + 64 {
            mask[w] = u64::MAX;
        } else if valid > lo {
            mask[w] = (1u64 << (valid - lo)) - 1;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let vals: Vec<u64> = (0..100).map(|i| (i * 37) % 256).collect();
        let planes = to_planes(&vals, 8, 128);
        assert_eq!(planes.len(), 8);
        assert_eq!(from_planes(&planes, 100), vals);
    }

    #[test]
    fn lane_mask_shapes() {
        assert_eq!(lane_mask(64, 64), vec![u64::MAX]);
        assert_eq!(lane_mask(3, 64), vec![0b111]);
        assert_eq!(lane_mask(70, 128), vec![u64::MAX, 0b111111]);
        assert_eq!(lane_mask(0, 128), vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "more values than columns")]
    fn overflow_panics() {
        to_planes(&[0; 65], 1, 64);
    }
}
