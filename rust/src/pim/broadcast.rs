//! Broadcasting units (paper §3.5, Fig. 5c).
//!
//! Dynamic operands (activations, intermediate tiles) must be replicated to
//! every bank/column that participates in parallel computation.  Without
//! hardware support the host writes every copy over the external channel —
//! `#copies × bytes` of off-chip traffic.  RACAM adds demux-based broadcast
//! units at the bank and column level, so the host sends one copy and the
//! replication happens on DRAM's internal fabric.


/// Off-chip vs. internal traffic produced by one replicated transfer.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BroadcastTraffic {
    /// Bytes crossing the host↔DRAM channel (the expensive path).
    pub external_bytes: u64,
    /// Bytes moved on internal buses by the broadcast demuxes (cheap).
    pub internal_bytes: u64,
    /// Replication factor actually applied.
    pub copies: u64,
}

/// Functional + traffic model of the bank/column broadcast network.
#[derive(Debug, Clone)]
pub struct BroadcastUnit {
    /// Hardware present at the bank level?
    pub bank_level: bool,
    /// Hardware present at the column level?
    pub col_level: bool,
    /// Bank-level demux input width, bits.
    pub bank_bits: u32,
    /// Column-level fan-out.
    pub col_fanout: u32,
    enabled_bank: bool,
    enabled_col: bool,
}

impl BroadcastUnit {
    pub fn new(bank_bits: u32, col_fanout: u32) -> Self {
        BroadcastUnit {
            bank_level: true,
            col_level: true,
            bank_bits,
            col_fanout,
            enabled_bank: false,
            enabled_col: false,
        }
    }

    /// An ablated system without broadcast hardware (paper Fig. 12 "-BU").
    pub fn absent() -> Self {
        BroadcastUnit {
            bank_level: false,
            col_level: false,
            bank_bits: 0,
            col_fanout: 0,
            enabled_bank: false,
            enabled_col: false,
        }
    }

    /// `broadcast_enable` (Table 1): select which demux levels replicate.
    pub fn enable(&mut self, bank_bc: bool, col_bc: bool) {
        self.enabled_bank = bank_bc && self.bank_level;
        self.enabled_col = col_bc && self.col_level;
    }

    /// `broadcast_disable`.
    pub fn disable(&mut self) {
        self.enabled_bank = false;
        self.enabled_col = false;
    }

    pub fn bank_enabled(&self) -> bool {
        self.enabled_bank
    }

    pub fn col_enabled(&self) -> bool {
        self.enabled_col
    }

    /// Functional bank broadcast: one input word fans out to the banks
    /// selected by `bank_select` (bitmask), mirroring Fig. 5c's demux.
    pub fn broadcast_to_banks(&self, word: u64, bank_select: u16, banks: &mut [Option<u64>]) {
        assert!(banks.len() <= 16);
        for (i, slot) in banks.iter_mut().enumerate() {
            if self.enabled_bank && (bank_select >> i) & 1 == 1 {
                *slot = Some(word);
            }
        }
    }

    /// Traffic for replicating `bytes` of a dynamic operand to `bank_copies`
    /// banks × `col_copies` column groups.
    ///
    /// With the unit enabled at a level, that level's replication moves to
    /// the internal fabric; without it, every copy crosses the channel.
    pub fn replicate_traffic(&self, bytes: u64, bank_copies: u64, col_copies: u64) -> BroadcastTraffic {
        let bank_ext = if self.bank_level { 1 } else { bank_copies.max(1) };
        let col_ext = if self.col_level { 1 } else { col_copies.max(1) };
        let total = bank_copies.max(1) * col_copies.max(1);
        let external = bytes * bank_ext * col_ext;
        BroadcastTraffic {
            external_bytes: external,
            internal_bytes: bytes * total - external.min(bytes * total),
            copies: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_hardware_external_traffic_is_single_copy() {
        let bu = BroadcastUnit::new(64, 64);
        let t = bu.replicate_traffic(1000, 16, 4);
        assert_eq!(t.external_bytes, 1000);
        assert_eq!(t.copies, 64);
        assert_eq!(t.internal_bytes, 64 * 1000 - 1000);
    }

    #[test]
    fn without_hardware_host_writes_every_copy() {
        let bu = BroadcastUnit::absent();
        let t = bu.replicate_traffic(1000, 16, 4);
        assert_eq!(t.external_bytes, 64 * 1000); // #Banks × Bytes_A of §1
        assert_eq!(t.internal_bytes, 0);
    }

    #[test]
    fn partial_hardware() {
        // Bank-level demux only: column copies still cross the channel.
        let mut bu = BroadcastUnit::new(64, 0);
        bu.col_level = false;
        let t = bu.replicate_traffic(100, 8, 4);
        assert_eq!(t.external_bytes, 400);
    }

    #[test]
    fn functional_bank_demux_respects_select_mask() {
        let mut bu = BroadcastUnit::new(64, 64);
        bu.enable(true, false);
        let mut banks = vec![None; 16];
        bu.broadcast_to_banks(0xABCD, 0b1010_0000_0000_0101, &mut banks);
        assert_eq!(banks[0], Some(0xABCD));
        assert_eq!(banks[2], Some(0xABCD));
        assert_eq!(banks[1], None);
        assert_eq!(banks[15], Some(0xABCD));
    }

    #[test]
    fn disabled_unit_does_not_write() {
        let bu = BroadcastUnit::new(64, 64); // never enabled
        let mut banks = vec![None; 4];
        bu.broadcast_to_banks(1, 0xF, &mut banks);
        assert!(banks.iter().all(Option::is_none));
    }

    #[test]
    fn enable_disable_toggle() {
        let mut bu = BroadcastUnit::new(64, 64);
        bu.enable(true, true);
        assert!(bu.bank_enabled() && bu.col_enabled());
        bu.disable();
        assert!(!bu.bank_enabled() && !bu.col_enabled());
        // Absent hardware cannot be enabled.
        let mut none = BroadcastUnit::absent();
        none.enable(true, true);
        assert!(!none.bank_enabled());
    }
}
