//! RACAM's added peripheral units (paper §3): bit-serial PEs, locality
//! buffers, popcount reduction units, broadcast units, the extended PIM ISA
//! latency model, the per-device FSM, and the *functional* block executor
//! that actually computes GEMM tiles bit-by-bit (the correctness ground
//! truth the analytical model and the PJRT oracle are checked against).

pub mod bitplane;
mod broadcast;
mod exec;
mod exec_krows;
mod fsm;
pub mod isa;
mod locality_buffer;
mod pe;
mod popcount;
pub mod trace;
mod transpose;

pub use broadcast::{BroadcastTraffic, BroadcastUnit};
pub use transpose::{transpose64, TransposeUnit};
pub use exec::{gemm_reference, BlockExecutor, ExecStats};
pub use exec_krows::KRowsExecutor;
pub use fsm::{DeviceFsm, FsmError, FsmState, MicroOp};
pub use isa::{InstrClass, InstrLatency};
pub use locality_buffer::{LocalityBuffer, MultiplyTrace};
pub use pe::{PeArray, PeWord};
pub use popcount::{popcount_reduce_slices, PopcountUnit};
