//! Locality buffer and the reuse-aware bit-serial multiplication schedule
//! (paper §3.3, Fig. 6).
//!
//! The buffer holds, per bank, `2n+1` rows: the `n` multiplicand bit-planes
//! (loaded from DRAM **once**), the currently-streamed multiplier bit-plane,
//! and the `n`-bit-deep in-flight result window.  Completed result bits are
//! populated back to the array immediately, so every operand bit crosses the
//! DRAM interface exactly once — `4n` row accesses per multiply instead of
//! the O(n²) of reuse-free PUD designs (Table 5).

use super::pe::PeArray;

/// Exact row-traffic accounting of one SIMD multiply pass — the quantities
/// behind Fig. 1 and the O(n) claim.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MultiplyTrace {
    /// Multiplicand bit-plane loads from the array (one per operand bit).
    pub op1_loads: u64,
    /// Multiplier bit-plane loads (one per bit, streamed).
    pub op2_loads: u64,
    /// Result bit-plane writebacks (one per product bit).
    pub result_writebacks: u64,
    /// PE cycles consumed (serial-add steps + carry drains).
    pub pe_cycles: u64,
    /// Peak locality-buffer rows occupied (must stay ≤ configured rows).
    pub peak_rows: u32,
}

impl MultiplyTrace {
    pub fn total_row_accesses(&self) -> u64 {
        self.op1_loads + self.op2_loads + self.result_writebacks
    }
}

/// Functional locality buffer for one bank: `rows × width` bits, word-packed.
#[derive(Debug, Clone)]
pub struct LocalityBuffer {
    rows: u32,
    width: u32,
    words: usize,
    data: Vec<Vec<u64>>,
}

impl LocalityBuffer {
    pub fn new(rows: u32, width: u32) -> Self {
        let words = (width as usize).div_ceil(64);
        LocalityBuffer { rows, width, words, data: vec![vec![0u64; words]; rows as usize] }
    }

    pub fn rows(&self) -> u32 {
        self.rows
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    pub fn load_row(&mut self, row: u32, plane: &[u64]) {
        assert!(row < self.rows, "locality buffer row {row} out of range");
        assert_eq!(plane.len(), self.words);
        self.data[row as usize].copy_from_slice(plane);
    }

    pub fn row(&self, row: u32) -> &[u64] {
        &self.data[row as usize]
    }

    /// Reuse-aware SIMD multiply (Fig. 6): `product = op1 × op2`, unsigned,
    /// lane-wise over `width` columns.
    ///
    /// `op1`/`op2` are `n` bit-planes each (LSB first, one bit per column);
    /// returns `2n` product bit-planes plus the exact [`MultiplyTrace`].
    /// The schedule is the paper's: op1 planes enter the buffer once, each
    /// op2 plane streams through once, and each completed product plane is
    /// written back the moment no further update can touch it.
    pub fn multiply(&mut self, pes: &mut PeArray, op1: &[Vec<u64>], op2: &[Vec<u64>]) -> (Vec<Vec<u64>>, MultiplyTrace) {
        let mut product = vec![vec![0u64; self.words]; 2 * op1.len()];
        let trace = self.multiply_into(pes, op1, op2, &mut product);
        (product, trace)
    }

    /// Allocation-free variant of [`Self::multiply`] for the simulator's
    /// hot loop: `product` must hold `2n` planes, which are zeroed and
    /// filled in place.
    pub fn multiply_into(
        &mut self,
        pes: &mut PeArray,
        op1: &[Vec<u64>],
        op2: &[Vec<u64>],
        product: &mut [Vec<u64>],
    ) -> MultiplyTrace {
        let n = op1.len();
        assert_eq!(op2.len(), n, "operands must share precision");
        assert!(n >= 1);
        assert!(
             2 * n as u32 + 1 <= self.rows,
            "precision {n} needs {} locality-buffer rows, have {}",
            2 * n + 1,
            self.rows
        );
        assert_eq!(pes.width(), self.width);

        let mut trace = MultiplyTrace { peak_rows: 2 * n as u32 + 1, ..Default::default() };

        // ❶ Load the multiplicand bit-planes into buffer rows 0..n — the
        //    only time op1 crosses the DRAM interface.
        for (i, plane) in op1.iter().enumerate() {
            self.load_row(i as u32, plane);
            trace.op1_loads += 1;
        }

        assert!(product.len() >= 2 * n, "product scratch needs 2n planes");
        for plane in product.iter_mut().take(2 * n) {
            debug_assert_eq!(plane.len(), self.words);
            plane.fill(0);
        }
        let op2_row = n as u32; // row reserved for the streamed multiplier bit

        // ❷..❹ For each multiplier bit j: stream it in, serially add op1
        //       into the result window [j, j+n), drain the carry to j+n,
        //       and immediately populate result bit j back to DRAM.
        let mut out = vec![0u64; self.words];
        for j in 0..n {
            self.load_row(op2_row, &op2[j]);
            trace.op2_loads += 1;

            pes.clear();
            for i in 0..n {
                // op1 bit-plane i and the streamed op2 plane are resident
                // buffer rows; borrow them in place (hot path — no copies).
                let (a, b) = (&self.data[i], &self.data[op2_row as usize]);
                pes.step_plane(a, b, &product[j + i], &mut out);
                product[j + i].copy_from_slice(&out);
                trace.pe_cycles += 1;
            }
            let b = &self.data[op2_row as usize];
            pes.carry_out_plane(b, &mut out);
            // Bits ≥ j+n are still zero, so the carry lands cleanly.
            for (w, o) in product[j + n].iter_mut().zip(&out) {
                *w |= o;
            }
            trace.pe_cycles += 1;

            // Result bit j can no longer change: populate back to DRAM.
            trace.result_writebacks += 1;
        }

        // ❺ Remaining high product bits stream out once each.
        trace.result_writebacks += n as u64;

        debug_assert_eq!(trace.total_row_accesses(), 4 * n as u64);
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::bitplane::{from_planes, to_planes};

    fn run_mult(xs: &[u64], ys: &[u64], n: usize) -> (Vec<u64>, MultiplyTrace) {
        let width = 128u32;
        let mut lb = LocalityBuffer::new(17, width);
        let mut pes = PeArray::new(width);
        let op1 = to_planes(xs, n, width);
        let op2 = to_planes(ys, n, width);
        let (prod, trace) = lb.multiply(&mut pes, &op1, &op2);
        (from_planes(&prod, xs.len()), trace)
    }

    #[test]
    fn int4_exhaustive() {
        // All 256 int4 pairs, 128 lanes at a time.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for x in 0..16u64 {
            for y in 0..16u64 {
                xs.push(x);
                ys.push(y);
            }
        }
        for chunk in 0..2 {
            let lo = chunk * 128;
            let (got, _) = run_mult(&xs[lo..lo + 128], &ys[lo..lo + 128], 4);
            for i in 0..128 {
                assert_eq!(got[i], xs[lo + i] * ys[lo + i], "{}x{}", xs[lo + i], ys[lo + i]);
            }
        }
    }

    #[test]
    fn int8_sampled() {
        let xs: Vec<u64> = (0..128).map(|i| (i * 37 + 11) % 256).collect();
        let ys: Vec<u64> = (0..128).map(|i| (i * 101 + 3) % 256).collect();
        let (got, trace) = run_mult(&xs, &ys, 8);
        for i in 0..128 {
            assert_eq!(got[i], xs[i] * ys[i]);
        }
        // The O(n) property: exactly 4n row accesses for n-bit multiply.
        assert_eq!(trace.total_row_accesses(), 32);
        assert_eq!(trace.op1_loads, 8);
        assert_eq!(trace.op2_loads, 8);
        assert_eq!(trace.result_writebacks, 16);
    }

    #[test]
    fn row_accesses_scale_linearly() {
        let xs = vec![3u64; 64];
        let ys = vec![5u64; 64];
        let mut prev = 0;
        for n in [2usize, 4, 8] {
            let (_, trace) = run_mult(&xs, &ys, n);
            assert_eq!(trace.total_row_accesses(), 4 * n as u64);
            assert!(trace.total_row_accesses() > prev);
            prev = trace.total_row_accesses();
        }
    }

    #[test]
    fn buffer_occupancy_is_2n_plus_1() {
        let (_, trace) = run_mult(&[7], &[9], 8);
        assert_eq!(trace.peak_rows, 17); // why the paper picks 17 rows
    }

    #[test]
    #[should_panic(expected = "locality-buffer rows")]
    fn rejects_precision_beyond_buffer() {
        let mut lb = LocalityBuffer::new(9, 64); // supports only int4
        let mut pes = PeArray::new(64);
        let op = to_planes(&[1], 8, 64);
        lb.multiply(&mut pes, &op, &op);
    }

    #[test]
    fn zero_times_anything_is_zero() {
        let xs = vec![0u64; 128];
        let ys: Vec<u64> = (0..128).collect();
        let (got, _) = run_mult(&xs, &ys, 8);
        assert!(got.iter().all(|&v| v == 0));
    }

    #[test]
    fn max_operands() {
        let (got, _) = run_mult(&[255, 255], &[255, 1], 8);
        assert_eq!(got[0], 255 * 255);
        assert_eq!(got[1], 255);
    }
}
