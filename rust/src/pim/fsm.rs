//! Per-device finite state machine (paper §3.1): decodes extended PIM
//! commands arriving on the command/address bus and expands compute
//! commands into micro-op sequences for the PEs, locality buffer, popcount
//! units and subarrays.  One FSM per device, shared by all its banks.

use crate::dram::{DramCommand, PimOpcode};

/// FSM operating mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsmState {
    /// Normal DRAM command decoding.
    Normal,
    /// PIM mode: incoming commands decode through this FSM.
    Pim,
}

/// Micro-operations the FSM issues to the peripheral units.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MicroOp {
    /// Stream one operand bit-plane from a subarray row into a buffer row.
    LoadPlane { buf_row: u8 },
    /// One SIMD PE cycle (serial-add step).
    PeStep,
    /// Drain PE carries into the result window.
    CarryOut,
    /// Populate one completed result bit-plane back to the array.
    WritePlane,
    /// Popcount one bit-slice into the accumulator.
    PopcountSlice { significance: u8 },
    /// Bit-parallel accumulator add.
    ParallelAdd,
    /// Write the horizontal reduction result row.
    WriteHorizontal,
    /// Configure the MRS / broadcast datapath.
    SetModeRegister { bits: u8 },
}

/// Errors surfaced by the FSM (commands illegal in the current mode).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsmError {
    /// PIM compute command received while not in PIM mode.
    NotInPimMode(PimOpcode),
    /// Standard access while PIM mode owns the arrays.
    StandardAccessInPimMode,
    /// Precision field outside the supported range.
    BadPrecision(u8),
}

impl std::fmt::Display for FsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsmError::NotInPimMode(op) => write!(f, "{op:?} requires pim_enable first"),
            FsmError::StandardAccessInPimMode => {
                write!(f, "standard DRAM access while PIM mode is active")
            }
            FsmError::BadPrecision(p) => write!(f, "unsupported precision field {p}"),
        }
    }
}

impl std::error::Error for FsmError {}

/// The device FSM.
#[derive(Debug, Clone)]
pub struct DeviceFsm {
    state: FsmState,
    broadcast_bank: bool,
    broadcast_col: bool,
    /// Maximum precision with full reuse (from the locality buffer size).
    max_prec_bits: u8,
}

impl DeviceFsm {
    pub fn new(max_prec_bits: u8) -> Self {
        DeviceFsm { state: FsmState::Normal, broadcast_bank: false, broadcast_col: false, max_prec_bits }
    }

    pub fn state(&self) -> FsmState {
        self.state
    }

    pub fn broadcast(&self) -> (bool, bool) {
        (self.broadcast_bank, self.broadcast_col)
    }

    /// Decode one command; on success returns the micro-op expansion (empty
    /// for pure mode changes).
    pub fn dispatch(&mut self, cmd: &DramCommand) -> Result<Vec<MicroOp>, FsmError> {
        use DramCommand::*;
        match *cmd {
            PimEnable => {
                self.state = FsmState::Pim;
                Ok(vec![MicroOp::SetModeRegister { bits: 1 }])
            }
            PimDisable => {
                self.state = FsmState::Normal;
                self.broadcast_bank = false;
                self.broadcast_col = false;
                Ok(vec![MicroOp::SetModeRegister { bits: 0 }])
            }
            BroadcastEnable { bank_bc, col_bc } => {
                self.broadcast_bank = bank_bc;
                self.broadcast_col = col_bc;
                Ok(vec![MicroOp::SetModeRegister { bits: (bank_bc as u8) | (col_bc as u8) << 1 }])
            }
            BroadcastDisable => {
                self.broadcast_bank = false;
                self.broadcast_col = false;
                Ok(vec![MicroOp::SetModeRegister { bits: 0 }])
            }
            PimAdd { prec, .. } => {
                self.require_pim(PimOpcode::PimAdd)?;
                let n = self.check_prec(prec)? as usize;
                let mut ops = Vec::new();
                // Stream both operands' planes, add serially, write back.
                for i in 0..n {
                    ops.push(MicroOp::LoadPlane { buf_row: i as u8 });
                    ops.push(MicroOp::LoadPlane { buf_row: (n + i) as u8 });
                    ops.push(MicroOp::PeStep);
                    ops.push(MicroOp::WritePlane);
                }
                ops.push(MicroOp::CarryOut);
                ops.push(MicroOp::WritePlane);
                Ok(ops)
            }
            PimMul { prec, .. } => {
                self.require_pim(PimOpcode::PimMul)?;
                let n = self.check_prec(prec)? as usize;
                Ok(Self::expand_mul(n))
            }
            PimMulRed { prec, .. } => {
                self.require_pim(PimOpcode::PimMulRed)?;
                let n = self.check_prec(prec)? as usize;
                let mut ops = Self::expand_mul(n);
                for s in 0..(2 * n) {
                    ops.push(MicroOp::PopcountSlice { significance: s as u8 });
                }
                ops.push(MicroOp::ParallelAdd);
                ops.push(MicroOp::WriteHorizontal);
                Ok(ops)
            }
            PimAddParallel { .. } => {
                self.require_pim(PimOpcode::PimAddParallel)?;
                Ok(vec![MicroOp::ParallelAdd, MicroOp::WriteHorizontal])
            }
            Act { .. } | Pre { .. } | Rd { .. } | Wr { .. } => {
                if self.state == FsmState::Pim {
                    Err(FsmError::StandardAccessInPimMode)
                } else {
                    Ok(vec![])
                }
            }
        }
    }

    /// Fig. 6 multiply schedule as micro-ops.
    fn expand_mul(n: usize) -> Vec<MicroOp> {
        let mut ops = Vec::with_capacity(n * (n + 3) + 2 * n);
        for i in 0..n {
            ops.push(MicroOp::LoadPlane { buf_row: i as u8 }); // op1 once
        }
        for _j in 0..n {
            ops.push(MicroOp::LoadPlane { buf_row: n as u8 }); // op2 bit j
            for _i in 0..n {
                ops.push(MicroOp::PeStep);
            }
            ops.push(MicroOp::CarryOut);
            ops.push(MicroOp::WritePlane); // completed bit j
        }
        for _ in 0..n {
            ops.push(MicroOp::WritePlane); // high product bits
        }
        ops
    }

    fn require_pim(&self, op: PimOpcode) -> Result<(), FsmError> {
        if self.state == FsmState::Pim {
            Ok(())
        } else {
            Err(FsmError::NotInPimMode(op))
        }
    }

    fn check_prec(&self, prec: u8) -> Result<u8, FsmError> {
        if prec >= 1 && prec <= self.max_prec_bits {
            Ok(prec)
        } else {
            Err(FsmError::BadPrecision(prec))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::DramCommand::*;

    fn fsm() -> DeviceFsm {
        DeviceFsm::new(8)
    }

    #[test]
    fn compute_requires_pim_mode() {
        let mut f = fsm();
        let err = f.dispatch(&PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 8 }).unwrap_err();
        assert_eq!(err, FsmError::NotInPimMode(PimOpcode::PimMul));
        f.dispatch(&PimEnable).unwrap();
        assert!(f.dispatch(&PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 8 }).is_ok());
    }

    #[test]
    fn standard_access_blocked_in_pim_mode() {
        let mut f = fsm();
        f.dispatch(&PimEnable).unwrap();
        assert_eq!(
            f.dispatch(&Act { bank: 0, row: 0 }).unwrap_err(),
            FsmError::StandardAccessInPimMode
        );
        f.dispatch(&PimDisable).unwrap();
        assert!(f.dispatch(&Act { bank: 0, row: 0 }).is_ok());
    }

    #[test]
    fn mul_expansion_row_traffic_is_4n() {
        let mut f = fsm();
        f.dispatch(&PimEnable).unwrap();
        for n in [2u8, 4, 8] {
            let ops = f.dispatch(&PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: n }).unwrap();
            let loads = ops.iter().filter(|o| matches!(o, MicroOp::LoadPlane { .. })).count();
            let writes = ops.iter().filter(|o| matches!(o, MicroOp::WritePlane)).count();
            assert_eq!(loads + writes, 4 * n as usize, "O(n) schedule for n={n}");
            let pe = ops.iter().filter(|o| matches!(o, MicroOp::PeStep)).count();
            assert_eq!(pe, (n as usize).pow(2));
        }
    }

    #[test]
    fn mulred_appends_reduction() {
        let mut f = fsm();
        f.dispatch(&PimEnable).unwrap();
        let ops = f.dispatch(&PimMulRed { r_dst: 0, r_src1: 1, r_src2: 2, prec: 4 }).unwrap();
        let pops = ops.iter().filter(|o| matches!(o, MicroOp::PopcountSlice { .. })).count();
        assert_eq!(pops, 8); // 2n slices
        assert!(ops.contains(&MicroOp::WriteHorizontal));
    }

    #[test]
    fn precision_bounds_enforced() {
        let mut f = fsm();
        f.dispatch(&PimEnable).unwrap();
        assert_eq!(
            f.dispatch(&PimMul { r_dst: 0, r_src1: 1, r_src2: 2, prec: 9 }).unwrap_err(),
            FsmError::BadPrecision(9)
        );
        assert_eq!(
            f.dispatch(&PimAdd { r_dst: 0, r_src1: 1, r_src2: 2, prec: 0 }).unwrap_err(),
            FsmError::BadPrecision(0)
        );
    }

    #[test]
    fn broadcast_state_cleared_on_pim_disable() {
        let mut f = fsm();
        f.dispatch(&PimEnable).unwrap();
        f.dispatch(&BroadcastEnable { bank_bc: true, col_bc: true }).unwrap();
        assert_eq!(f.broadcast(), (true, true));
        f.dispatch(&PimDisable).unwrap();
        assert_eq!(f.broadcast(), (false, false));
    }
}
