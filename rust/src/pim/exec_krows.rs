//! Functional executor for the *K-on-rows* block mappings (§4.2): columns
//! hold output tuples, K iterates temporally, and partial sums accumulate
//! **vertically** per column through bit-serial `pim_add` — no popcount
//! unit involved.  This validates the other half of the block-mapping
//! space (the `{R: K…, C: MN…}` family the search falls back to when the
//! reduction units are ablated).
//!
//! Signed arithmetic: per K step the product magnitudes are zero-extended
//! to 32 planes and lanes with a negative product are two's-complement
//! negated in place (invert + serial add of 1), then the 32-plane vector
//! adds into the per-column accumulator — all through the same PE array.

use super::bitplane::{from_planes, lane_mask, to_planes};
use super::locality_buffer::LocalityBuffer;
use super::pe::PeArray;
use crate::config::{HwConfig, Precision};

/// Accumulator precision (the paper's int32 outputs).
const ACC_BITS: usize = 32;

/// Serial add of two 32-plane vectors, lane-wise: `acc += addend`
/// (wrapping at 32 bits, like the hardware).
fn serial_add_planes(pes: &mut PeArray, acc: &mut [Vec<u64>], addend: &[Vec<u64>], words: usize) {
    let ones = vec![u64::MAX; words];
    let mut out = vec![0u64; words];
    pes.clear();
    for i in 0..ACC_BITS {
        let zero;
        let a: &[u64] = if i < addend.len() {
            &addend[i]
        } else {
            zero = vec![0u64; words];
            &zero
        };
        pes.step_plane(a, &ones, &acc[i], &mut out);
        acc[i].copy_from_slice(&out);
    }
    // Carry beyond bit 31 wraps (int32 semantics).
}

/// Two's-complement negate the lanes selected by `mask`, in place.
fn negate_lanes(pes: &mut PeArray, planes: &mut [Vec<u64>], mask: &[u64], words: usize) {
    // Invert selected lanes…
    for plane in planes.iter_mut() {
        for (w, m) in plane.iter_mut().zip(mask) {
            *w ^= m;
        }
    }
    // …then add 1 to them (serial add of a vector whose plane 0 = mask).
    let ones = vec![u64::MAX; words];
    let zero = vec![0u64; words];
    let mut out = vec![0u64; words];
    pes.clear();
    for (i, plane) in planes.iter_mut().enumerate() {
        let a: &[u64] = if i == 0 { mask } else { &zero };
        pes.step_plane(a, &ones, plane, &mut out);
        plane.copy_from_slice(&out);
    }
}

/// K-on-rows functional GEMM: `O[M,N] = I[M,K] · W[K,N]`, signed `prec`
/// operands, outputs accumulated vertically per column.
pub struct KRowsExecutor {
    width: u32,
    words: usize,
    lb: LocalityBuffer,
    pes: PeArray,
}

impl KRowsExecutor {
    pub fn new(hw: &HwConfig) -> Self {
        let width = hw.periph.pes_per_bank;
        KRowsExecutor {
            width,
            words: (width as usize).div_ceil(64),
            lb: LocalityBuffer::new(hw.periph.locality_buffer_rows, width),
            pes: PeArray::new(width),
        }
    }

    /// Number of `pim_mul` + `pim_add` pass pairs executed.
    pub fn gemm(
        &mut self,
        i_mat: &[i64],
        w_mat: &[i64],
        m: usize,
        k: usize,
        n: usize,
        prec: Precision,
    ) -> (Vec<i64>, u64) {
        assert_eq!(i_mat.len(), m * k);
        assert_eq!(w_mat.len(), k * n);
        let bits = prec.bits() as usize;
        let width = self.width as usize;
        let out_cols = m * n;
        let mut out = vec![0i64; out_cols];
        let mut passes = 0u64;

        // Column chunks of output tuples (lane c ↔ output (m, n)).
        let mut c0 = 0;
        while c0 < out_cols {
            let cc = (out_cols - c0).min(width);
            let valid = lane_mask(cc as u32, self.width);
            // Vertical int32 accumulator planes for this chunk.
            let mut acc: Vec<Vec<u64>> = vec![vec![0u64; self.words]; ACC_BITS];

            for kk in 0..k {
                // Lane operands for this K step.
                let mut mag_i = Vec::with_capacity(cc);
                let mut mag_w = Vec::with_capacity(cc);
                let mut neg = vec![0u64; self.words];
                for lane in 0..cc {
                    let (mi, ni) = ((c0 + lane) / n, (c0 + lane) % n);
                    let a = i_mat[mi * k + kk];
                    let b = w_mat[kk * n + ni];
                    mag_i.push(a.unsigned_abs());
                    mag_w.push(b.unsigned_abs());
                    if (a < 0) ^ (b < 0) && a != 0 && b != 0 {
                        neg[lane / 64] |= 1 << (lane % 64);
                    }
                }
                // pim_mul: product magnitudes (2·bits planes)…
                let op1 = to_planes(&mag_i, bits, self.width);
                let op2 = to_planes(&mag_w, bits, self.width);
                let (mut prod, _) = self.lb.multiply(&mut self.pes, &op1, &op2);
                passes += 1;
                // …zero-extend to 32 planes, two's-complement the negative
                // lanes, and pim_add into the vertical accumulator.
                prod.resize(ACC_BITS, vec![0u64; self.words]);
                let neg_masked: Vec<u64> = neg.iter().zip(&valid).map(|(a, b)| a & b).collect();
                negate_lanes(&mut self.pes, &mut prod, &neg_masked, self.words);
                serial_add_planes(&mut self.pes, &mut acc, &prod, self.words);
                passes += 1;
            }

            // Collect (vertical readout + two's-complement interpretation).
            for (lane, v) in from_planes(&acc, cc).into_iter().enumerate() {
                let raw = v as u32;
                out[c0 + lane] = raw as i32 as i64;
            }
            c0 += cc;
        }
        (out, passes)
    }
}

#[cfg(test)]
mod tests {
    use super::super::exec::gemm_reference;
    use super::*;
    use crate::config::racam_tiny;

    fn lcg(seed: &mut u64) -> i64 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (*seed >> 33) as i64
    }

    fn rand_mat(len: usize, bound: i64, seed: &mut u64) -> Vec<i64> {
        (0..len).map(|_| lcg(seed).rem_euclid(2 * bound) - bound).collect()
    }

    #[test]
    fn k_rows_matches_reference_int8() {
        let mut seed = 77;
        let (m, k, n) = (5, 40, 7);
        let i_mat = rand_mat(m * k, 128, &mut seed);
        let w_mat = rand_mat(k * n, 128, &mut seed);
        let mut ex = KRowsExecutor::new(&racam_tiny());
        let (got, passes) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        assert_eq!(got, gemm_reference(&i_mat, &w_mat, m, k, n));
        // K-on-rows: one mul+add pass pair per K step per column chunk.
        assert_eq!(passes, 2 * k as u64);
    }

    #[test]
    fn k_rows_matches_k_cols_executor() {
        // Both block-mapping families must compute identical results.
        let mut seed = 3;
        let (m, k, n) = (3, 65, 4);
        let i_mat = rand_mat(m * k, 128, &mut seed);
        let w_mat = rand_mat(k * n, 128, &mut seed);
        let mut rows = KRowsExecutor::new(&racam_tiny());
        let mut cols = super::super::exec::BlockExecutor::new(&racam_tiny());
        let (a, _) = rows.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        let (b, _) = cols.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        assert_eq!(a, b);
    }

    #[test]
    fn column_chunking_when_outputs_exceed_width() {
        // racam_tiny width = 128; 12×12 = 144 outputs forces 2 chunks.
        let mut seed = 11;
        let (m, k, n) = (12, 16, 12);
        let i_mat = rand_mat(m * k, 64, &mut seed);
        let w_mat = rand_mat(k * n, 64, &mut seed);
        let mut ex = KRowsExecutor::new(&racam_tiny());
        let (got, passes) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        assert_eq!(got, gemm_reference(&i_mat, &w_mat, m, k, n));
        assert_eq!(passes, 2 * 2 * k as u64); // 2 chunks × k steps × (mul+add)
    }

    #[test]
    fn all_negative_and_int4() {
        let i_mat = vec![-7i64; 2 * 9];
        let w_mat = vec![-5i64; 9 * 2];
        let mut ex = KRowsExecutor::new(&racam_tiny());
        let (got, _) = ex.gemm(&i_mat, &w_mat, 2, 9, 2, Precision::Int4);
        assert_eq!(got, gemm_reference(&i_mat, &w_mat, 2, 9, 2));
    }

    #[test]
    fn int32_wraparound_semantics() {
        // Accumulation wraps at 32 bits like the hardware accumulator rows;
        // stay in range here and just confirm big positive sums survive.
        let (m, k, n) = (1, 300, 1);
        let i_mat = vec![127i64; k];
        let w_mat = vec![127i64; k];
        let mut ex = KRowsExecutor::new(&racam_tiny());
        let (got, _) = ex.gemm(&i_mat, &w_mat, m, k, n, Precision::Int8);
        assert_eq!(got[0], 127 * 127 * 300);
    }
}
