//! Transpose unit (paper §2.2): bit-serial computation needs operands in a
//! *vertically transposed* layout — bit *i* of every element aligned on row
//! *i* across subarray columns.  Static weights are pre-transposed offline;
//! dynamic operands go through this unit at the memory controller on their
//! way in, and horizontal results can be read back directly.
//!
//! The functional core is a word-level 64×64 bit-matrix transpose
//! (Hacker's-Delight style butterfly), which is also what makes the
//! simulator's packing fast; the timing model charges one bus beat per
//! 64-bit word in + one per word out.

/// Transpose a 64×64 bit matrix held as 64 u64 rows, LSB-first convention:
/// bit j of `a[i]` moves to bit i of `a[j]`.  In-place, log₂64 butterfly
/// steps of masked delta-swaps (Hacker's-Delight transpose adapted to the
/// LSB-first column order the bit-plane layout uses).
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    // Mask selecting bit positions whose `j` bit is SET (the upper half of
    // each 2j-wide group).
    let mut m: u64 = 0xFFFF_FFFF_0000_0000;
    while j != 0 {
        let mut k = 0;
        while k < 64 {
            let t = (a[k] ^ (a[k + j] << j)) & m;
            a[k] ^= t;
            a[k + j] ^= t >> j;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m >> j.max(1);
    }
}

/// The transpose unit: converts between element-major values and the
/// vertical bit-plane layout, counting bus beats for the timing model.
#[derive(Debug, Clone, Default)]
pub struct TransposeUnit {
    /// 64-bit words consumed + produced (one bus beat each).
    pub beats: u64,
}

impl TransposeUnit {
    pub fn new() -> Self {
        TransposeUnit::default()
    }

    /// Vertical-ize: `values[lane]`'s low `bits` become bit-planes
    /// (plane i holds bit i of every lane), 64 lanes per word column.
    pub fn to_vertical(&mut self, values: &[u64], bits: usize) -> Vec<Vec<u64>> {
        let words = values.len().div_ceil(64);
        let mut planes = vec![vec![0u64; words]; bits];
        for wi in 0..words {
            let mut block = [0u64; 64];
            for lane in 0..64 {
                if let Some(&v) = values.get(wi * 64 + lane) {
                    // Row `lane` holds the lane's value; after transpose,
                    // row i holds bit i of every lane.
                    block[lane] = v;
                }
            }
            transpose64(&mut block);
            for (i, plane) in planes.iter_mut().enumerate() {
                plane[wi] = block[i];
            }
            self.beats += 64 + bits as u64;
        }
        planes
    }

    /// Horizontal-ize: invert [`Self::to_vertical`].
    pub fn to_horizontal(&mut self, planes: &[Vec<u64>], count: usize) -> Vec<u64> {
        let words = count.div_ceil(64);
        let mut out = vec![0u64; count];
        for wi in 0..words {
            let mut block = [0u64; 64];
            for (i, plane) in planes.iter().enumerate() {
                block[i] = plane[wi];
            }
            transpose64(&mut block);
            for lane in 0..64 {
                let idx = wi * 64 + lane;
                if idx < count {
                    out[idx] = block[lane];
                }
            }
            self.beats += planes.len() as u64 + 64;
        }
        out
    }

    /// Transpose latency in ns at `bus_beat_ns` per 64-bit word.
    pub fn elapsed_ns(&self, bus_beat_ns: f64) -> f64 {
        self.beats as f64 * bus_beat_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::bitplane;

    #[test]
    fn transpose64_involution_and_correctness() {
        let mut a = [0u64; 64];
        for (i, v) in a.iter_mut().enumerate() {
            *v = (i as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xABCD;
        }
        let orig = a;
        transpose64(&mut a);
        // Element (i, j) moved to (j, i).
        for i in 0..64 {
            for j in 0..64 {
                let src = (orig[i] >> j) & 1;
                let dst = (a[j] >> i) & 1;
                assert_eq!(src, dst, "({i},{j})");
            }
        }
        transpose64(&mut a);
        assert_eq!(a, orig, "transpose must be an involution");
    }

    #[test]
    fn vertical_roundtrip_matches_bitplane_packing() {
        let vals: Vec<u64> = (0..150).map(|i| (i * 37 + 5) % 256).collect();
        let mut tu = TransposeUnit::new();
        let planes = tu.to_vertical(&vals, 8);
        // Same layout as the (slower) reference packer.
        let reference = bitplane::to_planes(&vals, 8, 192);
        assert_eq!(planes, reference);
        let back = tu.to_horizontal(&planes, 150);
        assert_eq!(back, vals);
        assert!(tu.beats > 0);
    }

    #[test]
    fn beat_accounting() {
        let vals = vec![7u64; 64];
        let mut tu = TransposeUnit::new();
        tu.to_vertical(&vals, 8);
        assert_eq!(tu.beats, 64 + 8);
        assert!((tu.elapsed_ns(2.0) - 144.0).abs() < 1e-9);
    }

    #[test]
    fn partial_last_word() {
        let vals: Vec<u64> = (0..7).collect();
        let mut tu = TransposeUnit::new();
        let planes = tu.to_vertical(&vals, 3);
        let back = tu.to_horizontal(&planes, 7);
        assert_eq!(back, vals);
    }
}
