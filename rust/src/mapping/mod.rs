//! The RACAM workload-mapping framework (paper §4).
//!
//! A GEMM `O[M,N] = I[M,K] × W[K,N]` is mapped onto the DRAM hierarchy in
//! two stages:
//!
//! 1. **Hierarchical mapping** — each parallelism level (Channel, Rank,
//!    Device, Bank, Array/Block) is assigned one matmul dimension, which is
//!    tiled across that level (§4.1).  Dimensions mapped to `N` replicate
//!    the input `I` (broadcast); dimensions mapped to `K` produce partial
//!    outputs (reduction).
//! 2. **Block mapping** — within a block, the dimensions are split between
//!    the row axis and the column axis (§4.2), determining the data layout
//!    and whether the fused `pim_mul_red` column reduction applies.
//!
//! The framework enumerates the full space (3⁵ hierarchical × 6 block
//! mappings = 1458 candidates for GEMM, 2⁵ × 6 = 192 for GEMV — the paper
//! reports "1,548", which we read as a digit transposition of 1458 since
//! the GEMV count matches exactly), evaluates each with the analytical
//! software + hardware models (§4.4), and returns the latency-optimal one.
//!
//! Search and caching live in [`MappingService`]: a shared, thread-safe
//! pricing service with a **best-first** search — candidates stream from
//! the lazy generator ([`lazy_mappings`]), enter a min-heap keyed by the
//! analytic compute-only [`lower_bound`], and full evaluations pop in
//! bound order, so the incumbent tightens maximally fast and the frontier
//! is cut the moment the cheapest remaining bound reaches it.  The winner
//! stays bit-identical to the serial exhaustive reference (the strict-`<`
//! tie-breaking contract; invariants, bound derivation and the warm-store
//! lifecycle are written up in `docs/mapping.md`).  A concurrent
//! once-per-shape cache lets every serving shard, baseline comparison and
//! experiment amortize the same table, and [`store`] persists that table
//! across runs and *processes* (§7 warm start): atomic writes plus a
//! commutative best-entry-per-key merge, attached to a service via
//! [`MappingService::set_warm_path`].

mod engine;
mod model_hw;
mod model_sw;
mod service;
mod space;
pub mod store;

pub use engine::MappingEngine;
pub use model_hw::{HwModel, PassCosts};
pub use model_sw::{evaluate, lower_bound, Evaluation, LevelUsage};
pub use service::{MappingService, SearchResult};
pub use space::{
    enumerate_mappings, lazy_mappings, BlockMapping, Dim, DimSet, HierMapping, Level, Mapping,
    MappingCandidates, LEVELS,
};
