//! Software model (paper §4.4): applies hierarchical + temporal tiling for
//! a given mapping, schedules across DRAM hierarchies, and accumulates the
//! per-tile compute and I/O latencies returned by the hardware model into
//! the total kernel latency — the objective the mapping engine minimizes.

use super::model_hw::HwModel;
use super::space::{Dim, Level, Mapping};
use crate::config::MatmulShape;

/// Per-level parallel-unit usage (for the Fig. 16 utilization report).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelUsage {
    /// Units actually carrying work, per level (C, R, D, B, A order).
    pub used: [u64; 5],
    /// Units available, per level.
    pub avail: [u64; 5],
}

impl LevelUsage {
    pub fn fraction(&self, level: Level) -> f64 {
        let i = level.index();
        self.used[i] as f64 / self.avail[i] as f64
    }

    /// Fraction of compute-parallel banks in use (excludes the A level,
    /// whose blocks share a bank's PE array).
    pub fn bank_fraction(&self) -> f64 {
        (0..4).map(|i| self.used[i] as f64 / self.avail[i] as f64).product()
    }
}

/// Result of evaluating one mapping candidate.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub mapping: Mapping,
    /// Block/bank tile after hierarchical splitting: (Mt, Kt, Nt).
    pub tile: (u64, u64, u64),
    /// PIM compute latency, ns (the "PIM Latency" of Fig. 17).
    pub compute_ns: f64,
    /// Input layout/broadcast latency, ns.
    pub io_in_ns: f64,
    /// Output collection latency, ns.
    pub io_out_ns: f64,
    /// Host-side reduction latency, ns (part of I/O in Fig. 17).
    pub host_reduce_ns: f64,
    /// External (host↔DRAM channel) input traffic, bytes.
    pub io_in_bytes: u64,
    /// External output traffic, bytes.
    pub io_out_bytes: u64,
    /// Total SIMD passes issued across the system.
    pub passes: f64,
    /// Total DRAM row accesses for operand streaming.
    pub row_accesses: f64,
    /// PE utilization: ideal time at peak MAC rate / achieved compute time,
    /// scaled by the fraction of banks in use.
    pub pe_util: f64,
    pub usage: LevelUsage,
}

impl Evaluation {
    /// Total kernel latency: input layout, then compute, then collection
    /// (+host reduction) — the additive decomposition of Fig. 17.
    pub fn total_ns(&self) -> f64 {
        self.compute_ns + self.io_ns()
    }

    /// Total I/O latency (the orange bars of Fig. 17).
    pub fn io_ns(&self) -> f64 {
        self.io_in_ns + self.io_out_ns + self.host_reduce_ns
    }
}

/// Fixed per-kernel command overhead (pim_enable/disable, MRS writes, and
/// the SALP pipeline fill of the first pass), ns.
const KERNEL_OVERHEAD_NS: f64 = 50.0;
/// Transpose-on-collection penalty for vertically-laid-out outputs.
const VERTICAL_COLLECT_PENALTY: f64 = 1.25;

/// Evaluate one mapping of `shape` on `hw`.  Returns `None` only for
/// degenerate shapes (zero-sized dims).
///
/// When the rank level carries a *replicated* dimension (N for the input,
/// or M for a dynamic weight), every additional rank costs another copy on
/// the shared channel bus; the scheduler is free to restrict how many
/// ranks it actually spreads over (idle ranks simply hold no tile), so the
/// evaluation sweeps the rank replication degree and keeps the best —
/// this is part of the temporal-tiling freedom of §4.3.
pub fn evaluate(shape: &MatmulShape, mapping: &Mapping, hw: &HwModel) -> Option<Evaluation> {
    let counts = hw.level_counts();
    let rank_dim = mapping.hier.assign[1];
    let sweep_rank = rank_dim == Dim::N || (rank_dim == Dim::M && !shape.weight_static);
    if !sweep_rank {
        return evaluate_with_counts(shape, mapping, hw, counts);
    }
    let mut best: Option<Evaluation> = None;
    let mut r = 1u64;
    loop {
        let mut c = counts;
        c[1] = r.min(counts[1]);
        if let Some(e) = evaluate_with_counts(shape, mapping, hw, c) {
            if best.as_ref().map_or(true, |b| e.total_ns() < b.total_ns()) {
                best = Some(e);
            }
        }
        if r >= counts[1] {
            break;
        }
        r *= 2;
    }
    best
}

/// The compute side of one mapping at explicit level counts: hierarchical
/// tiling (§4.1) + the block compute model (§4.2).  Shared by the full
/// evaluation and the search's pruning lower bound, so the two can never
/// drift apart.
struct ComputeSide {
    tile: (u64, u64, u64),
    usage: LevelUsage,
    banks_used: u64,
    blocks_per_bank_used: u64,
    block_passes: f64,
    compute_ns: f64,
    k_on_cols: bool,
}

fn compute_side(
    shape: &MatmulShape,
    mapping: &Mapping,
    hw: &HwModel,
    counts: [u64; 5],
) -> Option<ComputeSide> {
    if shape.m == 0 || shape.k == 0 || shape.n == 0 {
        return None;
    }
    let assign = mapping.hier.assign;
    let f = hw.features();

    // ❶ Hierarchical tiling (§4.1): split each dim by the product of its
    //    levels' counts; compute per-level used units greedily outer→inner.
    let dim_size = |d: Dim| match d {
        Dim::M => shape.m,
        Dim::N => shape.n,
        Dim::K => shape.k,
    };
    let mut split = [1u64; 3];
    for (l, d) in assign.iter().enumerate() {
        split[*d as usize] = split[*d as usize].saturating_mul(counts[l]);
    }
    let tile = |d: Dim| dim_size(d).div_ceil(split[d as usize]);
    let (tile_m, tile_k, tile_n) = (tile(Dim::M), tile(Dim::K), tile(Dim::N));

    let mut rem = [
        shape.m.div_ceil(tile_m), // units needed along M
        shape.n.div_ceil(tile_n),
        shape.k.div_ceil(tile_k),
    ];
    let rem_idx = |d: Dim| match d {
        Dim::M => 0usize,
        Dim::N => 1,
        Dim::K => 2,
    };
    let mut used = [1u64; 5];
    for (l, d) in assign.iter().enumerate() {
        let r = &mut rem[rem_idx(*d)];
        used[l] = counts[l].min((*r).max(1));
        *r = (*r).div_ceil(used[l]);
    }
    let usage = LevelUsage { used, avail: counts };
    let banks_used: u64 = used[..4].iter().product();
    let blocks_per_bank_used = used[4];

    // ❷ Block compute model (§4.2): the block-mapping decides the
    //    instruction mix.
    let w = hw.block_width();
    let costs = hw.pass_costs(shape.prec);
    let k_on_cols = mapping.block.k_on_cols();

    let (block_passes, block_ns) = if k_on_cols {
        // Fused multiply + popcount column reduction: one output tuple per
        // pass, K chunked by the PE width; chunks fold together through
        // pim_add_parallel.
        let chunks = tile_k.div_ceil(w);
        let out_tuples = tile_m * tile_n;
        let passes = out_tuples as f64 * chunks as f64;
        if f.popcount_reduction {
            // Successive K-chunks of one output keep accumulating in the
            // reduction unit's register, so the drain + horizontal
            // writeback is paid once per output, not per pass.
            let drain = costs.mulred_ns - costs.mul_ns;
            let ns = passes * costs.mul_ns + out_tuples as f64 * drain;
            (passes, ns)
        } else {
            // No PR unit: cross-column reduction falls back to log₂(width)
            // SIMDRAM-style shifted bit-serial adds in the array — the
            // Fig. 12 "-PR" cost the paper describes as exporting the
            // reduction out of the dedicated unit.
            let tree = (w.min(tile_k).max(2) as f64).log2().ceil();
            let ns = passes * costs.mul_ns + out_tuples as f64 * tree * costs.add_ns;
            (passes, ns)
        }
    } else {
        // K along rows: per-column accumulation via pim_mul + pim_add; the
        // columns carry output tuples, remaining output dims iterate on
        // the row axis.
        let col_dims = mapping.block.col_dims;
        let out_cols: u64 = col_dims
            .iter()
            .map(|d| match d {
                Dim::M => tile_m,
                Dim::N => tile_n,
                Dim::K => 1,
            })
            .product();
        let row_out: u64 = mapping
            .block
            .row_dims()
            .iter()
            .map(|d| match d {
                Dim::M => tile_m,
                Dim::N => tile_n,
                Dim::K => 1,
            })
            .product();
        let col_chunks = out_cols.div_ceil(w);
        let passes = tile_k as f64 * col_chunks as f64 * row_out as f64;
        let ns = passes * (costs.mul_ns + costs.add_ns);
        (passes, ns)
    };

    // Blocks within a bank share its PE array → serialize (§3.3).
    let compute_ns = block_ns * blocks_per_bank_used as f64 + KERNEL_OVERHEAD_NS;

    Some(ComputeSide {
        tile: (tile_m, tile_k, tile_n),
        usage,
        banks_used,
        blocks_per_bank_used,
        block_passes,
        compute_ns,
        k_on_cols,
    })
}

/// A cheap analytic **lower bound** on the total latency any
/// [`evaluate`] of this mapping can return: the §4.2 block compute cost
/// at the *full* level counts, with all I/O dropped.
///
/// Validity: (a) the total is compute + I/O, and I/O is non-negative;
/// (b) under the rank-replication sweep of [`evaluate`], growing the rank
/// count only shrinks tile sizes (`div_ceil` is non-increasing in its
/// divisor) and shifts work to parallel units, so the compute cost at the
/// full rank count — which the sweep always includes as its final point —
/// is the smallest compute cost of any sweep point.  The bound is
/// therefore `<=` every candidate total, so the search can prune a
/// candidate whose bound already reaches the incumbent under strict-`<`
/// tie-breaking without ever changing the winner (pinned by the
/// `lower_bound_never_exceeds_evaluation` oracle test).  The best-first
/// search additionally uses it as the frontier's priority key: popping
/// candidates in bound order is what makes the incumbent tighten
/// maximally fast (see `docs/mapping.md` for the derivation).
///
/// Returns `None` exactly when [`evaluate`] does (degenerate shapes).
pub fn lower_bound(shape: &MatmulShape, mapping: &Mapping, hw: &HwModel) -> Option<f64> {
    compute_side(shape, mapping, hw, hw.level_counts()).map(|c| c.compute_ns)
}

fn evaluate_with_counts(
    shape: &MatmulShape,
    mapping: &Mapping,
    hw: &HwModel,
    counts: [u64; 5],
) -> Option<Evaluation> {
    let ComputeSide {
        tile: (tile_m, tile_k, tile_n),
        usage,
        banks_used,
        blocks_per_bank_used,
        block_passes,
        compute_ns,
        k_on_cols,
    } = compute_side(shape, mapping, hw, counts)?;
    let assign = mapping.hier.assign;
    let f = hw.features();
    let used = usage.used;
    let w = hw.block_width();
    let costs = hw.pass_costs(shape.prec);
    let total_passes = block_passes * blocks_per_bank_used as f64 * banks_used as f64;
    let row_accesses = total_passes * costs.mul_row_accesses as f64;

    // ❸ I/O model (§4.4): input layout/broadcast + output collection.
    let bw = hw.channel_bw_bytes_per_ns();
    // Internal fabric advantage for resident-operand relayout (global
    // bitlines + broadcast demuxes run well above the external channel).
    const INTERNAL_BW_FACTOR: f64 = 4.0;
    let ch_dim = assign[0];
    let used_c = used[0];

    // One dynamic operand: `partition` are the dims indexing it, `dup` the
    // dim whose spatial copies replicate it.  Within a block the operand is
    // written once and *reused temporally* across the other dims' slots
    // (§4.3), so only spatial copies cost traffic.
    let dyn_io = |bytes: u64, partition: [Dim; 2], dup: Dim| -> (f64, u64) {
        // Share of the operand a single channel receives.
        let per_channel =
            if partition.contains(&ch_dim) { bytes as f64 / used_c as f64 } else { bytes as f64 };
        // Rank-level replication serializes on the shared channel bus.
        let rank_mult = if assign[1] == dup { used[1] } else { 1 };
        // Device/bank/array spatial replication rides the internal demux
        // network when broadcast units exist; otherwise the host writes
        // every copy over the channel.
        let low_dup: u64 = (2..5).map(|l| if assign[l] == dup { used[l] } else { 1 }).product();
        let ext_mult = if f.broadcast_unit { 1 } else { low_dup };
        let per_channel_bytes = per_channel * rank_mult as f64 * ext_mult as f64;
        if shape.input_resident && f.broadcast_unit {
            // Already in PIM DRAM: relayout entirely on the internal fabric.
            (per_channel_bytes / (bw * INTERNAL_BW_FACTOR), 0)
        } else if shape.input_resident {
            // Resident but no broadcast hardware: the host reads the data
            // out and writes every copy back (2× the channel crossings).
            (2.0 * per_channel_bytes / bw, (2.0 * per_channel_bytes * used_c as f64) as u64)
        } else {
            (per_channel_bytes / bw, (per_channel_bytes * used_c as f64) as u64)
        }
    };

    let mut io_in_ns = 0.0;
    let mut io_in_bytes = 0u64;
    {
        let (ns, bytes) = dyn_io(shape.input_bytes(), [Dim::M, Dim::K], Dim::N);
        io_in_ns += ns;
        io_in_bytes += bytes;
    }
    if !shape.weight_static {
        let (ns, bytes) = dyn_io(shape.weight_bytes(), [Dim::K, Dim::N], Dim::M);
        io_in_ns += ns;
        io_in_bytes += bytes;
    }

    // Output collection: partial outputs per K-mapped level above A must
    // be fetched and reduced by the host; A-level partials fold in-bank via
    // pim_add_parallel (needs the PR unit's accumulator).
    let mut partials: u64 = (0..4).map(|l| if assign[l] == Dim::K { used[l] } else { 1 }).product();
    let mut bank_addpar_ns = 0.0;
    if assign[4] == Dim::K {
        if f.popcount_reduction {
            bank_addpar_ns = used[4].saturating_sub(1) as f64 * costs.addpar_ns;
        } else {
            partials = partials.saturating_mul(used[4]);
        }
    }

    let (out_bytes_total, host_reduce_ns) = if partials > 1 {
        // Host fetches every partial, reduces, and writes the result back
        // to DRAM for the next kernel.
        let base = shape.output_bytes() * (partials + 1);
        let reduce = (partials - 1) as f64 * (shape.m * shape.n) as f64 * hw.host_add_ns();
        let penalty = if k_on_cols { 1.0 } else { VERTICAL_COLLECT_PENALTY };
        ((base as f64 * penalty) as u64, reduce)
    } else {
        // Fully reduced in-DRAM: the output stays resident where the next
        // kernel consumes it (the paper's Fig. 16 I/O shares confirm
        // outputs are not collected per kernel).
        (0, 0.0)
    };
    // Channels drain their shares in parallel unless K lives on channels
    // (then every channel returns a full-size partial).
    let out_per_channel =
        if ch_dim == Dim::K { out_bytes_total as f64 } else { out_bytes_total as f64 / used_c as f64 };
    let io_out_ns = out_per_channel / bw + bank_addpar_ns;

    // ❹ Utilization: achieved vs. peak MAC throughput.
    let total_pes = hw.parallel_banks() as f64 * w as f64;
    let ideal_ns = shape.macs() as f64 * hw.ideal_mac_ns(shape.prec) / total_pes;
    let pe_util = (ideal_ns / compute_ns.max(f64::MIN_POSITIVE)).min(1.0);

    Some(Evaluation {
        mapping: *mapping,
        tile: (tile_m, tile_k, tile_n),
        compute_ns,
        io_in_ns,
        io_out_ns,
        host_reduce_ns,
        io_in_bytes,
        io_out_bytes: out_bytes_total,
        passes: total_passes,
        row_accesses,
        // `compute_ns` already pays for idle columns (passes cover the full
        // PE width) and idle banks (ideal_ns assumes all of them), so
        // `pe_util` needs no extra occupancy factor.
        pe_util,
        usage,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, Features, MatmulShape, Precision};
    use crate::mapping::space::{enumerate_mappings, BlockMapping, DimSet, HierMapping};

    fn hw() -> HwModel {
        HwModel::new(&racam_paper())
    }

    fn best(shape: &MatmulShape, hw: &HwModel) -> Evaluation {
        enumerate_mappings(shape)
            .iter()
            .filter_map(|m| evaluate(shape, m, hw))
            .min_by(|a, b| a.total_ns().total_cmp(&b.total_ns()))
            .unwrap()
    }

    #[test]
    fn all_gemm_mappings_evaluate() {
        let s = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
        let hw = hw();
        let evals: Vec<_> =
            enumerate_mappings(&s).iter().filter_map(|m| evaluate(&s, m, &hw)).collect();
        assert_eq!(evals.len(), 1458);
        for e in &evals {
            assert!(e.total_ns().is_finite() && e.total_ns() > 0.0, "{}", e.mapping);
            assert!(e.pe_util >= 0.0 && e.pe_util <= 1.0);
        }
    }

    #[test]
    fn mapping_spread_is_large() {
        // Paper Fig. 15: max/min ≈ 510x for 1024×12288×12288.
        let s = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
        let hw = hw();
        let totals: Vec<f64> = enumerate_mappings(&s)
            .iter()
            .filter_map(|m| evaluate(&s, m, &hw))
            .map(|e| e.total_ns())
            .collect();
        let max = totals.iter().cloned().fold(0.0, f64::max);
        let min = totals.iter().cloned().fold(f64::INFINITY, f64::min);
        let spread = max / min;
        assert!(spread > 50.0, "mapping spread only {spread:.1}x");
    }

    #[test]
    fn best_gemm_mapping_uses_column_reduction() {
        // Paper Fig. 15: reduction-friendly block mappings (K on columns)
        // dominate because they exploit the popcount unit.
        let s = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
        let e = best(&s, &hw());
        assert!(e.mapping.block.k_on_cols(), "winner was {}", e.mapping);
    }

    #[test]
    fn larger_gemm_has_higher_utilization() {
        // Paper Fig. 16a: PE utilization grows with GEMM size.
        let hw = hw();
        let small = best(&MatmulShape::new(2048, 2048, 2048, Precision::Int8), &hw);
        let large = best(&MatmulShape::new(8192, 8192, 8192, Precision::Int8), &hw);
        assert!(large.pe_util > small.pe_util, "{} vs {}", large.pe_util, small.pe_util);
    }

    #[test]
    fn gemv_utilization_is_low() {
        // Paper Fig. 16b: ~7% for 1×2048×2048.
        let e = best(&MatmulShape::new(1, 2048, 2048, Precision::Int8), &hw());
        assert!(e.pe_util < 0.25, "GEMV util {}", e.pe_util);
    }

    #[test]
    fn static_weights_cost_no_input_io() {
        let hw = hw();
        let mut s = MatmulShape::new(512, 4096, 4096, Precision::Int8);
        let m = enumerate_mappings(&s)[0];
        let with_static = evaluate(&s, &m, &hw).unwrap();
        s.weight_static = false;
        let with_dynamic = evaluate(&s, &m, &hw).unwrap();
        assert!(with_dynamic.io_in_bytes > with_static.io_in_bytes);
    }

    #[test]
    fn broadcast_ablation_increases_external_input_traffic() {
        let s = MatmulShape::new(1, 12288, 12288, Precision::Int8);
        let hw_full = hw();
        let hw_nobu = hw_full.with_features(Features { broadcast_unit: false, ..Features::ALL });
        let b_full = best(&s, &hw_full);
        let b_nobu = best(&s, &hw_nobu);
        assert!(
            b_nobu.total_ns() > b_full.total_ns(),
            "no-BU {} vs full {}",
            b_nobu.total_ns(),
            b_full.total_ns()
        );
    }

    #[test]
    fn k_on_high_levels_requires_host_reduction() {
        let s = MatmulShape::new(64, 8192, 64, Precision::Int8);
        let hw = hw();
        // Force K onto ranks: partial outputs × used ranks.
        let m = Mapping {
            hier: HierMapping { assign: [Dim::M, Dim::K, Dim::N, Dim::M, Dim::K] },
            block: BlockMapping::new(DimSet::of(&[Dim::K])),
        };
        let e = evaluate(&s, &m, &hw).unwrap();
        assert!(e.host_reduce_ns > 0.0);
        assert!(e.io_out_bytes > s.output_bytes());
    }

    #[test]
    fn degenerate_shape_returns_none() {
        let s = MatmulShape::new(0, 4, 4, Precision::Int8);
        let m = enumerate_mappings(&MatmulShape::new(1, 4, 4, Precision::Int8))[0];
        assert!(evaluate(&s, &m, &hw()).is_none());
        assert!(lower_bound(&s, &m, &hw()).is_none());
    }

    #[test]
    fn lower_bound_never_exceeds_evaluation() {
        // The pruning oracle: for every mapping of a diverse set of shapes
        // (GEMM, GEMV, odd sizes, dynamic weights, low precision, ablated
        // hardware), the analytic bound must sit at or below the full
        // evaluation — otherwise pruning could discard the true winner.
        let mut shapes = vec![
            MatmulShape::new(1024, 12288, 12288, Precision::Int8),
            MatmulShape::new(1, 2048, 2048, Precision::Int8),
            MatmulShape::new(7, 130, 514, Precision::Int8),
            MatmulShape::new(256, 1024, 512, Precision::Int4),
            MatmulShape::new(3, 65, 1, Precision::Int8),
        ];
        let mut dynamic = MatmulShape::new(64, 4096, 64, Precision::Int8);
        dynamic.weight_static = false;
        shapes.push(dynamic);
        let hw_full = hw();
        let hw_nopr =
            hw_full.with_features(Features { popcount_reduction: false, ..Features::ALL });
        for hw in [&hw_full, &hw_nopr] {
            for s in &shapes {
                for m in enumerate_mappings(s) {
                    let (Some(bound), Some(eval)) =
                        (lower_bound(s, &m, hw), evaluate(s, &m, hw))
                    else {
                        panic!("{}: bound/eval disagree on evaluability ({})", s.label(), m)
                    };
                    // 1e-12 *relative*: three orders of magnitude tighter
                    // than the search's PRUNE_SLACK margin, so the slack
                    // provably covers any float wobble this oracle allows.
                    assert!(
                        bound <= eval.total_ns() * (1.0 + 1e-12),
                        "{} {}: bound {bound} exceeds total {}",
                        s.label(),
                        m,
                        eval.total_ns()
                    );
                }
            }
        }
    }

    #[test]
    fn lower_bound_is_tight_without_io() {
        // For a mapping with no rank sweep and no I/O (static weights,
        // fully reduced in-DRAM), the bound equals the compute share of
        // the evaluation exactly.
        let s = MatmulShape::new(512, 4096, 4096, Precision::Int8);
        let hw = hw();
        for m in enumerate_mappings(&s) {
            let bound = lower_bound(&s, &m, &hw).unwrap();
            let eval = evaluate(&s, &m, &hw).unwrap();
            assert!(bound <= eval.total_ns() * (1.0 + 1e-12), "{m}");
            let rank_dim = m.hier.assign[1];
            if rank_dim != Dim::N && !(rank_dim == Dim::M && !s.weight_static) {
                // No sweep: the bound is exactly the compute term.
                assert_eq!(bound.to_bits(), eval.compute_ns.to_bits(), "{m}");
            }
        }
    }
}
