//! Mapping-table persistence (paper §7: "mappings for different token
//! lengths can be precomputed or cached at runtime, effectively eliminating
//! repeated search cost").  Searched results serialize to JSON; loading
//! re-evaluates each stored mapping on the current hardware model (cheap —
//! one evaluation instead of a full space search) so cached entries stay
//! consistent with the config.
//!
//! These are the warm-start hooks of [`MappingService`]: export/import act
//! on the shared cache, so one saved table pre-warms every shard and
//! baseline comparison that shares the service
//! ([`MappingService::warm_start`] / [`MappingService::persist`] are thin
//! wrappers over [`load_file`] / [`save_file`]).

use super::model_sw::evaluate;
use super::service::{MappingService, SearchResult};
use super::space::{BlockMapping, Dim, DimSet, HierMapping, Mapping};
use crate::config::json::{self, Value};
use crate::config::{MatmulShape, Precision};
use crate::Result;

fn dim_from_letter(c: char) -> Option<Dim> {
    match c {
        'M' => Some(Dim::M),
        'N' => Some(Dim::N),
        'K' => Some(Dim::K),
        _ => None,
    }
}

/// Serialize one mapping as `"MNKMN|K"`: five hierarchical dim letters
/// (C, R, D, B, A order) + the block mapping's column dims.
pub fn mapping_to_string(m: &Mapping) -> String {
    let hier: String = m.hier.assign.iter().map(|d| d.letter()).collect();
    format!("{hier}|{}", m.block.col_dims.letters())
}

/// Parse the [`mapping_to_string`] format.
pub fn mapping_from_string(s: &str) -> Result<Mapping> {
    let (hier, cols) = s.split_once('|').ok_or_else(|| anyhow::anyhow!("missing '|' in '{s}'"))?;
    anyhow::ensure!(hier.len() == 5, "hier part must have 5 letters, got '{hier}'");
    let mut assign = [Dim::M; 5];
    for (i, c) in hier.chars().enumerate() {
        assign[i] = dim_from_letter(c).ok_or_else(|| anyhow::anyhow!("bad dim '{c}'"))?;
    }
    let mut col_dims = DimSet::EMPTY;
    for c in cols.chars() {
        col_dims = col_dims.with(dim_from_letter(c).ok_or_else(|| anyhow::anyhow!("bad dim '{c}'"))?);
    }
    anyhow::ensure!(!col_dims.is_empty() && !col_dims.complement().is_empty(), "invalid block mapping '{cols}'");
    Ok(Mapping { hier: HierMapping { assign }, block: BlockMapping::new(col_dims) })
}

fn shape_to_value(s: &MatmulShape) -> Value {
    Value::obj(vec![
        ("m", Value::Num(s.m as f64)),
        ("k", Value::Num(s.k as f64)),
        ("n", Value::Num(s.n as f64)),
        ("bits", Value::Num(s.prec.bits() as f64)),
        ("weight_static", Value::Bool(s.weight_static)),
        ("input_resident", Value::Bool(s.input_resident)),
    ])
}

fn shape_from_value(v: &Value) -> Result<MatmulShape> {
    let bits = v.get("bits")?.as_u32()?;
    Ok(MatmulShape {
        m: v.get("m")?.as_f64()? as u64,
        k: v.get("k")?.as_f64()? as u64,
        n: v.get("n")?.as_f64()? as u64,
        prec: Precision::from_bits(bits)
            .ok_or_else(|| anyhow::anyhow!("bad precision {bits}"))?,
        weight_static: v.get("weight_static")?.as_bool()?,
        input_resident: v.get("input_resident")?.as_bool()?,
    })
}

/// Export a service's cached search results.
pub fn export(service: &MappingService) -> Value {
    let entries: Vec<Value> = service
        .cache_entries()
        .iter()
        .map(|(shape, r)| {
            Value::obj(vec![
                ("shape", shape_to_value(shape)),
                ("mapping", Value::Str(mapping_to_string(&r.best.mapping))),
                ("candidates", Value::Num(r.candidates as f64)),
                ("pruned", Value::Num(r.pruned as f64)),
                ("worst_ns", Value::Num(r.worst_ns)),
            ])
        })
        .collect();
    Value::obj(vec![("version", Value::Num(1.0)), ("entries", Value::Arr(entries))])
}

/// Import previously exported results into the service's shared cache,
/// re-evaluating each stored mapping on the service's hardware model.
/// Returns the number of entries imported.
pub fn import(service: &MappingService, v: &Value) -> Result<usize> {
    anyhow::ensure!(v.get("version")?.as_f64()? == 1.0, "unknown mapping-store version");
    let Value::Arr(entries) = v.get("entries")? else {
        anyhow::bail!("entries must be an array")
    };
    let mut imported = 0;
    for e in entries {
        let shape = shape_from_value(e.get("shape")?)?;
        let mapping = mapping_from_string(e.get("mapping")?.as_str()?)?;
        let Some(eval) = evaluate(&shape, &mapping, service.hw()) else {
            continue;
        };
        let result = SearchResult {
            best: eval,
            candidates: e.get("candidates")?.as_f64()? as usize,
            // Absent in tables written before pruning existed.
            pruned: e.get("pruned").and_then(|p| p.as_f64()).map_or(0, |p| p as usize),
            worst_ns: e.get("worst_ns")?.as_f64()?,
        };
        service.cache_insert(shape, result);
        imported += 1;
    }
    Ok(imported)
}

/// Write `text` to `path` atomically: write a same-directory temp file,
/// then rename it over the target.  A crash mid-write leaves the old
/// table intact (the rename is atomic on POSIX filesystems); the temp
/// name carries the pid so concurrent processes never collide on it.
pub(crate) fn write_atomic(path: &std::path::Path, text: &str) -> Result<()> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow::anyhow!("store path has no file name: {}", path.display()))?;
    let tmp_name = format!(".{name}.tmp.{}", std::process::id());
    let tmp = match path.parent().filter(|d| !d.as_os_str().is_empty()) {
        Some(dir) => dir.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    std::fs::write(&tmp, text)?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    Ok(())
}

/// Save the service's cache to a file (atomic: temp file + rename, so a
/// concurrent reader never observes a half-written table).
pub fn save_file(service: &MappingService, path: &std::path::Path) -> Result<()> {
    write_atomic(path, &export(service).pretty())
}

/// Load a cache file into the service.
pub fn load_file(service: &MappingService, path: &std::path::Path) -> Result<usize> {
    let text = std::fs::read_to_string(path)?;
    let v = json::parse(&text).map_err(anyhow::Error::from)?;
    import(service, &v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::racam_paper;

    fn service() -> MappingService {
        MappingService::for_config(&racam_paper())
    }

    #[test]
    fn mapping_string_roundtrip() {
        let shape = MatmulShape::new(64, 64, 64, Precision::Int8);
        for m in super::super::space::enumerate_mappings(&shape) {
            let s = mapping_to_string(&m);
            assert_eq!(mapping_from_string(&s).unwrap(), m, "{s}");
        }
    }

    #[test]
    fn export_import_restores_cached_latencies() {
        let a = service();
        let shapes = [
            MatmulShape::new(1, 4096, 4096, Precision::Int8),
            MatmulShape::new(1024, 12288, 12288, Precision::Int8),
            MatmulShape::new(64, 64, 64, Precision::Int4),
        ];
        for s in &shapes {
            a.search_cached(s);
        }
        let exported = export(&a);

        let b = service();
        let n = import(&b, &exported).unwrap();
        assert_eq!(n, shapes.len());
        // Pruning accounting survives the round-trip.
        for (shape, restored) in b.cache_entries() {
            let fresh = a.search_cached(&shape).unwrap();
            assert_eq!(restored.pruned, fresh.pruned, "{}", shape.label());
            assert_eq!(restored.candidates, fresh.candidates, "{}", shape.label());
        }
        for s in &shapes {
            let misses_before = b.misses();
            let from_cache = b.search_cached(s).unwrap();
            assert_eq!(b.misses(), misses_before, "import must pre-warm the cache");
            let fresh = a.search_cached(s).unwrap();
            assert!(
                (from_cache.best.total_ns() - fresh.best.total_ns()).abs() < 1e-6,
                "{}: cached {} vs fresh {}",
                s.label(),
                from_cache.best.total_ns(),
                fresh.best.total_ns()
            );
        }
    }

    #[test]
    fn file_roundtrip() {
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let path = std::env::temp_dir().join("racam_mapping_store_test.json");
        a.persist(&path).unwrap();
        let b = service();
        assert_eq!(b.warm_start(&path).unwrap(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failed_mid_write_leaves_old_table_readable() {
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let dir = std::env::temp_dir();
        let path = dir.join("racam_store_atomic_test.json");
        save_file(&a, &path).unwrap();

        // Simulate a crashed writer: a temp-style file holding a
        // truncated table sits next to the target, never renamed.
        let tmp = dir.join(format!(".racam_store_atomic_test.json.tmp.{}", std::process::id()));
        std::fs::write(&tmp, r#"{"version": 1, "entr"#).unwrap();

        // The published table is untouched and still loads.
        let b = service();
        assert_eq!(load_file(&b, &path).unwrap(), 1);

        // A subsequent save overwrites the stale temp and the final file
        // still parses.
        save_file(&a, &path).unwrap();
        let c = service();
        assert_eq!(load_file(&c, &path).unwrap(), 1);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn imports_tables_written_before_pruning_existed() {
        // A v1 entry without the "pruned" field (pre-pruning exports)
        // still loads; the count defaults to 0.
        let text = r#"{"version": 1, "entries": [{
            "shape": {"m": 1, "k": 2048, "n": 2048, "bits": 8,
                      "weight_static": true, "input_resident": true},
            "mapping": "MNKMN|K",
            "candidates": 192,
            "worst_ns": 123.0}]}"#;
        let s = service();
        assert_eq!(import(&s, &json::parse(text).unwrap()).unwrap(), 1);
        let (_, r) = s.cache_entries().pop().unwrap();
        assert_eq!(r.pruned, 0);
        assert_eq!(r.candidates, 192);
    }

    #[test]
    fn rejects_garbage() {
        assert!(mapping_from_string("XYZ").is_err());
        assert!(mapping_from_string("MMMMM|").is_err());
        assert!(mapping_from_string("MMMM|K").is_err());
        assert!(mapping_from_string("MMMMM|MNK").is_err()); // rows empty
    }
}
