//! Mapping-table persistence (paper §7: "mappings for different token
//! lengths can be precomputed or cached at runtime, effectively eliminating
//! repeated search cost").  Searched results serialize to JSON; loading
//! re-evaluates each stored mapping on the current hardware model (cheap —
//! one evaluation instead of a full space search) so cached entries stay
//! consistent with the config.
//!
//! These are the warm-start hooks of [`MappingService`]: export/import act
//! on the shared cache, so one saved table pre-warms every shard and
//! baseline comparison that shares the service
//! ([`MappingService::warm_start`] / [`MappingService::persist`] are thin
//! wrappers over [`load_file`] / [`save_file`]).
//!
//! ## Shared cross-process warm store
//!
//! Entries are keyed by **shape + DRAM channel count**: a mapping searched
//! on a 3-channel shard is not a valid answer for an 8-channel one, so
//! [`import`] skips entries whose channel count disagrees with the
//! service's hardware (legacy tables without the field are accepted on any
//! hardware, the pre-keying behavior).  Tables serialize in a canonical
//! order (sorted by key), writes are atomic (temp file + rename), and
//! [`merge`] folds two tables by keeping the best entry per key — a
//! commutative, idempotent fold, so any number of processes can persist
//! into one file in any order without clobbering each other.  That is the
//! [`MappingService::set_warm_path`] lifecycle: load at construction,
//! merge-back on the last drop.

use super::model_sw::evaluate;
use super::service::{MappingService, SearchResult};
use super::space::{BlockMapping, Dim, DimSet, HierMapping, Mapping};
use crate::config::json::{self, Value};
use crate::config::{MatmulShape, Precision};
use crate::Result;
use std::collections::BTreeMap;

fn dim_from_letter(c: char) -> Option<Dim> {
    match c {
        'M' => Some(Dim::M),
        'N' => Some(Dim::N),
        'K' => Some(Dim::K),
        _ => None,
    }
}

/// Serialize one mapping as `"MNKMN|K"`: five hierarchical dim letters
/// (C, R, D, B, A order) + the block mapping's column dims.
pub fn mapping_to_string(m: &Mapping) -> String {
    let hier: String = m.hier.assign.iter().map(|d| d.letter()).collect();
    format!("{hier}|{}", m.block.col_dims.letters())
}

/// Parse the [`mapping_to_string`] format.
pub fn mapping_from_string(s: &str) -> Result<Mapping> {
    let (hier, cols) = s.split_once('|').ok_or_else(|| anyhow::anyhow!("missing '|' in '{s}'"))?;
    anyhow::ensure!(hier.len() == 5, "hier part must have 5 letters, got '{hier}'");
    let mut assign = [Dim::M; 5];
    for (i, c) in hier.chars().enumerate() {
        assign[i] = dim_from_letter(c).ok_or_else(|| anyhow::anyhow!("bad dim '{c}'"))?;
    }
    let mut col_dims = DimSet::EMPTY;
    for c in cols.chars() {
        col_dims = col_dims.with(dim_from_letter(c).ok_or_else(|| anyhow::anyhow!("bad dim '{c}'"))?);
    }
    anyhow::ensure!(!col_dims.is_empty() && !col_dims.complement().is_empty(), "invalid block mapping '{cols}'");
    Ok(Mapping { hier: HierMapping { assign }, block: BlockMapping::new(col_dims) })
}

fn shape_to_value(s: &MatmulShape) -> Value {
    Value::obj(vec![
        ("m", Value::Num(s.m as f64)),
        ("k", Value::Num(s.k as f64)),
        ("n", Value::Num(s.n as f64)),
        ("bits", Value::Num(s.prec.bits() as f64)),
        ("weight_static", Value::Bool(s.weight_static)),
        ("input_resident", Value::Bool(s.input_resident)),
    ])
}

fn shape_from_value(v: &Value) -> Result<MatmulShape> {
    let bits = v.get("bits")?.as_u32()?;
    Ok(MatmulShape {
        m: v.get("m")?.as_f64()? as u64,
        k: v.get("k")?.as_f64()? as u64,
        n: v.get("n")?.as_f64()? as u64,
        prec: Precision::from_bits(bits)
            .ok_or_else(|| anyhow::anyhow!("bad precision {bits}"))?,
        weight_static: v.get("weight_static")?.as_bool()?,
        input_resident: v.get("input_resident")?.as_bool()?,
    })
}

/// One parsed store entry.  `channels` is the DRAM channel count of the
/// hardware the entry was searched on (`None` in legacy tables written
/// before the key existed — accepted on any hardware).
#[derive(Debug, Clone)]
pub struct StoreEntry {
    pub shape: MatmulShape,
    pub channels: Option<u32>,
    pub mapping: String,
    /// Best total latency on the hardware the entry was searched on
    /// (`INFINITY` in legacy tables — merge then prefers fresh entries;
    /// import re-evaluates on the importing hardware either way).
    pub total_ns: f64,
    pub candidates: usize,
    pub pruned: usize,
    pub bound_calls: usize,
    pub frontier_peak: usize,
    pub worst_ns: f64,
}

impl StoreEntry {
    fn from_cached(shape: &MatmulShape, r: &SearchResult, channels: u32) -> StoreEntry {
        StoreEntry {
            shape: *shape,
            channels: Some(channels),
            mapping: mapping_to_string(&r.best.mapping),
            total_ns: r.best.total_ns(),
            candidates: r.candidates,
            pruned: r.pruned,
            bound_calls: r.bound_calls,
            frontier_peak: r.frontier_peak,
            worst_ns: r.worst_ns,
        }
    }

    /// Canonical table key: shape fields + channel count (`None` sorts
    /// first).  One entry per key survives a [`merge`].
    #[allow(clippy::type_complexity)]
    fn key(&self) -> (u64, u64, u64, u32, bool, bool, Option<u32>) {
        let s = &self.shape;
        (s.m, s.k, s.n, s.prec.bits(), s.weight_static, s.input_resident, self.channels)
    }

    /// Deterministic total order used to pick the surviving entry among
    /// key duplicates: lower latency first, then every remaining field
    /// lexicographically, so the choice is independent of merge order.
    fn cmp_quality(&self, other: &StoreEntry) -> std::cmp::Ordering {
        self.total_ns
            .total_cmp(&other.total_ns)
            .then(self.candidates.cmp(&other.candidates))
            .then(self.pruned.cmp(&other.pruned))
            .then(self.bound_calls.cmp(&other.bound_calls))
            .then(self.frontier_peak.cmp(&other.frontier_peak))
            .then(self.worst_ns.total_cmp(&other.worst_ns))
            .then_with(|| self.mapping.cmp(&other.mapping))
    }
}

fn entry_to_value(e: &StoreEntry) -> Value {
    let mut fields = vec![
        ("shape", shape_to_value(&e.shape)),
        ("mapping", Value::Str(e.mapping.clone())),
        ("total_ns", Value::Num(e.total_ns)),
        ("candidates", Value::Num(e.candidates as f64)),
        ("pruned", Value::Num(e.pruned as f64)),
        ("bound_calls", Value::Num(e.bound_calls as f64)),
        ("frontier_peak", Value::Num(e.frontier_peak as f64)),
        ("worst_ns", Value::Num(e.worst_ns)),
    ];
    if let Some(c) = e.channels {
        fields.insert(1, ("channels", Value::Num(c as f64)));
    }
    Value::obj(fields)
}

fn entry_from_value(e: &Value) -> Result<StoreEntry> {
    Ok(StoreEntry {
        shape: shape_from_value(e.get("shape")?)?,
        // Absent in tables written before the channel key existed.
        channels: e.get("channels").and_then(|c| c.as_u32()).ok(),
        mapping: {
            let m = e.get("mapping")?.as_str()?.to_string();
            mapping_from_string(&m)?; // validate eagerly
            m
        },
        total_ns: e.get("total_ns").and_then(|t| t.as_f64()).unwrap_or(f64::INFINITY),
        candidates: e.get("candidates")?.as_f64()? as usize,
        // Absent in tables written before pruning existed.
        pruned: e.get("pruned").and_then(|p| p.as_f64()).map_or(0, |p| p as usize),
        bound_calls: e.get("bound_calls").and_then(|b| b.as_f64()).map_or(0, |b| b as usize),
        frontier_peak: e.get("frontier_peak").and_then(|f| f.as_f64()).map_or(0, |f| f as usize),
        worst_ns: e.get("worst_ns")?.as_f64()?,
    })
}

/// Serialize entries as a v1 table in **canonical order** (sorted by
/// key): byte-identical tables for equal entry sets, which is what makes
/// [`merge`] idempotent down to the serialized text.
fn entries_to_value(mut entries: Vec<StoreEntry>) -> Value {
    entries.sort_by(|a, b| a.key().cmp(&b.key()).then_with(|| a.cmp_quality(b)));
    Value::obj(vec![
        ("version", Value::Num(1.0)),
        ("entries", Value::Arr(entries.iter().map(entry_to_value).collect())),
    ])
}

fn parse_entries(v: &Value) -> Result<Vec<StoreEntry>> {
    anyhow::ensure!(v.get("version")?.as_f64()? == 1.0, "unknown mapping-store version");
    let Value::Arr(entries) = v.get("entries")? else {
        anyhow::bail!("entries must be an array")
    };
    entries.iter().map(entry_from_value).collect()
}

/// Export a service's cached search results (canonically ordered, keyed
/// by shape + the service's channel count).
pub fn export(service: &MappingService) -> Value {
    let channels = service.hw().hw.dram.channels;
    let entries = service
        .cache_entries()
        .iter()
        .map(|(shape, r)| StoreEntry::from_cached(shape, r, channels))
        .collect();
    entries_to_value(entries)
}

/// Import previously exported results into the service's shared cache,
/// re-evaluating each stored mapping on the service's hardware model.
/// Entries searched on a different channel count are skipped — their
/// winner is not this hardware's winner.  Returns the number of entries
/// imported.
pub fn import(service: &MappingService, v: &Value) -> Result<usize> {
    let channels = service.hw().hw.dram.channels;
    let mut imported = 0;
    for e in parse_entries(v)? {
        if e.channels.is_some_and(|c| c != channels) {
            continue;
        }
        let mapping = mapping_from_string(&e.mapping)?;
        let Some(eval) = evaluate(&e.shape, &mapping, service.hw()) else {
            continue;
        };
        let result = SearchResult {
            best: eval,
            candidates: e.candidates,
            pruned: e.pruned,
            bound_calls: e.bound_calls,
            frontier_peak: e.frontier_peak,
            worst_ns: e.worst_ns,
        };
        service.cache_insert(e.shape, result);
        imported += 1;
    }
    Ok(imported)
}

/// Fold duplicate-key entries down to the best entry per key.
fn merge_entries(entries: Vec<StoreEntry>) -> Vec<StoreEntry> {
    let mut by_key: BTreeMap<_, StoreEntry> = BTreeMap::new();
    for e in entries {
        match by_key.entry(e.key()) {
            std::collections::btree_map::Entry::Vacant(slot) => {
                slot.insert(e);
            }
            std::collections::btree_map::Entry::Occupied(mut slot) => {
                if e.cmp_quality(slot.get()) == std::cmp::Ordering::Less {
                    slot.insert(e);
                }
            }
        }
    }
    by_key.into_values().collect()
}

/// Merge two mapping tables: the union of their keys, keeping the best
/// entry per (shape, channels) key.  Commutative and idempotent (the
/// survivor is the minimum of a deterministic total order and the output
/// is canonically sorted), so concurrent processes can fold tables in any
/// order and arrive at the same bytes.
pub fn merge(a: &Value, b: &Value) -> Result<Value> {
    let mut entries = parse_entries(a)?;
    entries.extend(parse_entries(b)?);
    Ok(entries_to_value(merge_entries(entries)))
}

/// Merge a service's cached results into the table at `path` (read-merge-
/// write with an atomic replace): the on-disk union of what this process
/// searched and what any other process persisted since we loaded.  An
/// unreadable existing table is treated as empty; a *corrupt* one (reads
/// fine, fails to parse) is quarantined to `<path>.corrupt` with a
/// once-per-process warning, then the persist proceeds with the cached
/// entries alone — corruption never blocks the persist and never
/// silently shadows good data.  Returns the number of entries written.
pub(crate) fn merge_entries_into_file(
    path: &std::path::Path,
    channels: u32,
    cached: &[(MatmulShape, SearchResult)],
) -> Result<usize> {
    let mut entries: Vec<StoreEntry> = cached
        .iter()
        .map(|(shape, r)| StoreEntry::from_cached(shape, r, channels))
        .collect();
    if let Ok(text) = std::fs::read_to_string(path) {
        match json::parse(&text).map_err(anyhow::Error::from).and_then(|v| parse_entries(&v)) {
            Ok(existing) => entries.extend(existing),
            Err(e) => quarantine(path, &e.to_string()),
        }
    }
    let merged = merge_entries(entries);
    let n = merged.len();
    write_atomic(path, &entries_to_value(merged).pretty())?;
    Ok(n)
}

/// Move a corrupt table aside as `<path>.corrupt` (best effort — if the
/// rename fails the file stays put and keeps being treated as empty) and
/// warn once per process.  Quarantining instead of deleting keeps the
/// bytes around for a post-mortem; quarantining instead of erroring keeps
/// a half-written table left by a crashed writer from wedging every
/// subsequent run — the store simply starts cold.
fn quarantine(path: &std::path::Path, why: &str) {
    let mut target = path.as_os_str().to_owned();
    target.push(".corrupt");
    let renamed = std::fs::rename(path, std::path::Path::new(&target)).is_ok();
    warn_once(&format!(
        "racam: mapping store {} is corrupt ({why}); {}, starting cold",
        path.display(),
        if renamed { "quarantined to *.corrupt" } else { "leaving it in place" },
    ));
}

/// Print the first corruption warning of the process to stderr and drop
/// the rest — a sweep over many shards sharing one bad table should not
/// repeat the identical line N times.
fn warn_once(msg: &str) {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    if !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!("{msg}");
    }
}

/// Write `text` to `path` atomically: write a same-directory temp file,
/// then rename it over the target.  A crash mid-write leaves the old
/// table intact (the rename is atomic on POSIX filesystems); the temp
/// name carries the pid so concurrent processes never collide on it.
pub(crate) fn write_atomic(path: &std::path::Path, text: &str) -> Result<()> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow::anyhow!("store path has no file name: {}", path.display()))?;
    let tmp_name = format!(".{name}.tmp.{}", std::process::id());
    let tmp = match path.parent().filter(|d| !d.as_os_str().is_empty()) {
        Some(dir) => dir.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    std::fs::write(&tmp, text)?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e.into());
    }
    Ok(())
}

/// Save the service's cache to a file (atomic: temp file + rename, so a
/// concurrent reader never observes a half-written table).
pub fn save_file(service: &MappingService, path: &std::path::Path) -> Result<()> {
    write_atomic(path, &export(service).pretty())
}

/// Load a cache file into the service.  A missing or unreadable file is
/// still an error (the caller asked for *this* file); a file that reads
/// but is **corrupt** — truncated write, bad JSON, wrong schema —
/// degrades gracefully instead: it is quarantined to `<path>.corrupt`
/// with a once-per-process warning and the load reports 0 entries, so
/// the service starts cold rather than failing the run.
pub fn load_file(service: &MappingService, path: &std::path::Path) -> Result<usize> {
    let text = std::fs::read_to_string(path)?;
    let loaded = json::parse(&text)
        .map_err(anyhow::Error::from)
        .and_then(|v| import(service, &v));
    match loaded {
        Ok(n) => Ok(n),
        Err(e) => {
            quarantine(path, &e.to_string());
            Ok(0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::racam_paper;

    fn service() -> MappingService {
        MappingService::for_config(&racam_paper())
    }

    #[test]
    fn mapping_string_roundtrip() {
        let shape = MatmulShape::new(64, 64, 64, Precision::Int8);
        for m in super::super::space::enumerate_mappings(&shape) {
            let s = mapping_to_string(&m);
            assert_eq!(mapping_from_string(&s).unwrap(), m, "{s}");
        }
    }

    #[test]
    fn export_import_restores_cached_latencies() {
        let a = service();
        let shapes = [
            MatmulShape::new(1, 4096, 4096, Precision::Int8),
            MatmulShape::new(1024, 12288, 12288, Precision::Int8),
            MatmulShape::new(64, 64, 64, Precision::Int4),
        ];
        for s in &shapes {
            a.search_cached(s);
        }
        let exported = export(&a);

        let b = service();
        let n = import(&b, &exported).unwrap();
        assert_eq!(n, shapes.len());
        // Pruning accounting survives the round-trip.
        for (shape, restored) in b.cache_entries() {
            let fresh = a.search_cached(&shape).unwrap();
            assert_eq!(restored.pruned, fresh.pruned, "{}", shape.label());
            assert_eq!(restored.candidates, fresh.candidates, "{}", shape.label());
        }
        for s in &shapes {
            let misses_before = b.misses();
            let from_cache = b.search_cached(s).unwrap();
            assert_eq!(b.misses(), misses_before, "import must pre-warm the cache");
            let fresh = a.search_cached(s).unwrap();
            assert!(
                (from_cache.best.total_ns() - fresh.best.total_ns()).abs() < 1e-6,
                "{}: cached {} vs fresh {}",
                s.label(),
                from_cache.best.total_ns(),
                fresh.best.total_ns()
            );
        }
    }

    #[test]
    fn file_roundtrip() {
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let path = std::env::temp_dir().join("racam_mapping_store_test.json");
        a.persist(&path).unwrap();
        let b = service();
        assert_eq!(b.warm_start(&path).unwrap(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn failed_mid_write_leaves_old_table_readable() {
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let dir = std::env::temp_dir();
        let path = dir.join("racam_store_atomic_test.json");
        save_file(&a, &path).unwrap();

        // Simulate a crashed writer: a temp-style file holding a
        // truncated table sits next to the target, never renamed.
        let tmp = dir.join(format!(".racam_store_atomic_test.json.tmp.{}", std::process::id()));
        std::fs::write(&tmp, r#"{"version": 1, "entr"#).unwrap();

        // The published table is untouched and still loads.
        let b = service();
        assert_eq!(load_file(&b, &path).unwrap(), 1);

        // A subsequent save overwrites the stale temp and the final file
        // still parses.
        save_file(&a, &path).unwrap();
        let c = service();
        assert_eq!(load_file(&c, &path).unwrap(), 1);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn imports_tables_written_before_pruning_existed() {
        // A v1 entry without the "pruned" field (pre-pruning exports)
        // still loads; the count defaults to 0.
        let text = r#"{"version": 1, "entries": [{
            "shape": {"m": 1, "k": 2048, "n": 2048, "bits": 8,
                      "weight_static": true, "input_resident": true},
            "mapping": "MNKMN|K",
            "candidates": 192,
            "worst_ns": 123.0}]}"#;
        let s = service();
        assert_eq!(import(&s, &json::parse(text).unwrap()).unwrap(), 1);
        let (_, r) = s.cache_entries().pop().unwrap();
        assert_eq!(r.pruned, 0);
        assert_eq!(r.candidates, 192);
    }

    #[test]
    fn merge_is_commutative_and_idempotent() {
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        a.search_cached(&MatmulShape::new(256, 1024, 512, Precision::Int8));
        let b = service();
        b.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8)); // overlaps a
        b.search_cached(&MatmulShape::new(64, 64, 64, Precision::Int4));
        let (ea, eb) = (export(&a), export(&b));

        let ab = merge(&ea, &eb).unwrap();
        let ba = merge(&eb, &ea).unwrap();
        assert_eq!(ab.pretty(), ba.pretty(), "merge must be commutative");
        assert_eq!(merge(&ea, &ea).unwrap().pretty(), ea.pretty(), "merge must be idempotent");
        assert_eq!(merge(&ab, &eb).unwrap().pretty(), ab.pretty(), "absorbing a merged input");

        // The union imports all three distinct shapes.
        let c = service();
        assert_eq!(import(&c, &ab).unwrap(), 3);
    }

    #[test]
    fn import_skips_entries_from_a_different_channel_count() {
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let mut exported = export(&a);
        // Rewrite the entry's channel key to a count this service's
        // hardware does not have: the winner was searched on different
        // hardware, so import must not poison the cache with it.
        let Value::Obj(top) = &mut exported else { panic!("export must be an object") };
        let Value::Arr(list) = top.get_mut("entries").unwrap() else {
            panic!("entries must be an array")
        };
        let Value::Obj(entry) = &mut list[0] else { panic!("entry must be an object") };
        entry.insert("channels".into(), Value::Num(3.0));
        let b = service();
        assert_eq!(import(&b, &exported).unwrap(), 0);
        assert_eq!(b.cache_len(), 0);
    }

    #[test]
    fn merge_keeps_distinct_channel_entries_side_by_side() {
        // The same shape searched on 8 and on 3 channels are different
        // answers; a merged table carries both.
        let shape = MatmulShape::new(1, 2048, 2048, Precision::Int8);
        let a = service();
        a.search_cached(&shape);
        let mut three_ch = racam_paper();
        three_ch.dram.channels = 3;
        let b = MappingService::for_config(&three_ch);
        b.search_cached(&shape);
        let merged = merge(&export(&a), &export(&b)).unwrap();
        let Value::Arr(ref list) = *merged.get("entries").unwrap() else {
            panic!("entries must be an array")
        };
        assert_eq!(list.len(), 2);
        // Each side re-imports exactly its own entry.
        let a2 = service();
        assert_eq!(import(&a2, &merged).unwrap(), 1);
        let b2 = MappingService::for_config(&three_ch);
        assert_eq!(import(&b2, &merged).unwrap(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(mapping_from_string("XYZ").is_err());
        assert!(mapping_from_string("MMMMM|").is_err());
        assert!(mapping_from_string("MMMM|K").is_err());
        assert!(mapping_from_string("MMMMM|MNK").is_err()); // rows empty
    }

    #[test]
    fn truncated_table_quarantines_and_loads_cold() {
        // A writer that died mid-write (without the atomic rename — e.g. a
        // copy from another machine) leaves truncated JSON at the real
        // path.  Loading must not fail the run: the file is quarantined to
        // `<path>.corrupt` and the service starts cold.
        let dir = std::env::temp_dir();
        let path = dir.join("racam_store_truncated_test.json");
        let corrupt = dir.join("racam_store_truncated_test.json.corrupt");
        std::fs::remove_file(&corrupt).ok();
        std::fs::write(&path, r#"{"version": 1, "entries": [{"shape": {"m": 1"#).unwrap();
        let s = service();
        assert_eq!(load_file(&s, &path).unwrap(), 0, "corrupt table loads as empty");
        assert_eq!(s.cache_len(), 0);
        assert!(corrupt.exists(), "the corrupt bytes are kept for post-mortem");
        assert!(!path.exists(), "the bad file is moved aside, not left to re-trip");
        // A missing file is still a real error — the caller asked for it.
        assert!(load_file(&s, &path).is_err());
        std::fs::remove_file(&corrupt).ok();
    }

    #[test]
    fn wrong_schema_quarantines_too() {
        // Parses as JSON but is not a v1 table (a crashed writer of some
        // other tool, say): same graceful degradation as truncated bytes.
        let dir = std::env::temp_dir();
        let path = dir.join("racam_store_schema_test.json");
        let corrupt = dir.join("racam_store_schema_test.json.corrupt");
        std::fs::remove_file(&corrupt).ok();
        std::fs::write(&path, r#"{"version": 99, "entries": []}"#).unwrap();
        let s = service();
        assert_eq!(load_file(&s, &path).unwrap(), 0);
        assert!(corrupt.exists());
        std::fs::remove_file(&corrupt).ok();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn merge_into_corrupt_target_persists_cache_and_quarantines() {
        // Persisting over a corrupt table must neither fail nor fold the
        // garbage in: the cached entries are written whole and the corrupt
        // bytes are moved aside.
        let dir = std::env::temp_dir();
        let path = dir.join("racam_store_merge_corrupt_test.json");
        let corrupt = dir.join("racam_store_merge_corrupt_test.json.corrupt");
        std::fs::remove_file(&corrupt).ok();
        std::fs::write(&path, "not json at all").unwrap();
        let a = service();
        a.search_cached(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let entries = a.cache_entries();
        let n = merge_entries_into_file(&path, racam_paper().dram.channels, &entries).unwrap();
        assert_eq!(n, 1, "the cache persists despite the corrupt target");
        assert!(corrupt.exists(), "the corrupt target is quarantined");
        // The rewritten table is valid and round-trips.
        let b = service();
        assert_eq!(load_file(&b, &path).unwrap(), 1);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&corrupt).ok();
    }
}
