//! Hardware model (paper §4.4): architecture description + compute model +
//! I/O model.  Given a tile and its mapping, the compute model prices the
//! block-level PIM instruction stream; the I/O model prices host↔DRAM
//! interactions (input broadcast, output collection, host-side reduction).

use crate::config::{Features, HwConfig, Precision};
use crate::dram::{Geometry, SalpScheduler};
use crate::pim::isa::{instr_latency, InstrClass};

/// Pre-computed per-pass instruction costs for one (precision, features)
/// point — the hot path of mapping search evaluates thousands of mappings,
/// so these are computed once per search.
#[derive(Debug, Clone, Copy)]
pub struct PassCosts {
    /// One `pim_mul_red` SIMD pass (multiply + fused column reduction).
    pub mulred_ns: f64,
    /// One `pim_mul` SIMD pass.
    pub mul_ns: f64,
    /// One `pim_add` SIMD pass (bit-serial accumulate).
    pub add_ns: f64,
    /// One `pim_add_parallel` (int32 accumulator add).
    pub addpar_ns: f64,
    /// Row accesses per `pim_mul` pass (Fig. 1 accounting).
    pub mul_row_accesses: u64,
}

/// The §4.4 hardware model: architectural description (geometry),
/// compute model (PIM instruction latencies) and I/O model (effective
/// bandwidths).
#[derive(Debug, Clone)]
pub struct HwModel {
    pub hw: HwConfig,
    pub geo: Geometry,
    /// Pre-computed per-precision pass costs (int2/int4/int8/int16 order) —
    /// the mapping search evaluates thousands of candidates, so instruction
    /// latencies are derived once per model, not once per evaluation.
    costs: [PassCosts; 4],
}

impl HwModel {
    pub fn new(hw: &HwConfig) -> Self {
        let geo = Geometry::new(hw.dram, hw.periph.pes_per_bank);
        let salp = if hw.features.locality_buffer {
            SalpScheduler::new(hw.timing, hw.dram.subarrays)
        } else {
            SalpScheduler::disabled(hw.timing, hw.dram.subarrays)
        };
        let compute = |prec: Precision| -> PassCosts {
            let t = &hw.timing;
            let f = &hw.features;
            let mulred = instr_latency(InstrClass::MulRed, prec, t, &salp, f);
            let mul = instr_latency(InstrClass::Mul, prec, t, &salp, f);
            let add = instr_latency(InstrClass::Add, prec, t, &salp, f);
            let addpar = instr_latency(InstrClass::AddParallel, prec, t, &salp, f);
            PassCosts {
                mulred_ns: mulred.total_ns(),
                mul_ns: mul.total_ns(),
                add_ns: add.total_ns(),
                addpar_ns: addpar.total_ns(),
                mul_row_accesses: mul.row_accesses,
            }
        };
        let costs = [
            compute(Precision::Int2),
            compute(Precision::Int4),
            compute(Precision::Int8),
            compute(Precision::Int16),
        ];
        HwModel { hw: hw.clone(), geo, costs }
    }

    /// Same hardware with a different feature set (ablation studies).
    pub fn with_features(&self, f: Features) -> HwModel {
        let mut hw = self.hw.clone();
        hw.features = f;
        HwModel::new(&hw)
    }

    pub fn features(&self) -> &Features {
        &self.hw.features
    }

    /// Parallelism level counts in [`super::LEVELS`] order
    /// (C, R, D, B, A) — A is blocks per bank.
    pub fn level_counts(&self) -> [u64; 5] {
        let d = &self.hw.dram;
        [
            d.channels as u64,
            d.ranks as u64,
            d.devices as u64,
            d.banks as u64,
            self.geo.blocks_per_bank() as u64,
        ]
    }

    /// Block width in columns (= PE count per bank).
    pub fn block_width(&self) -> u64 {
        self.hw.periph.pes_per_bank as u64
    }

    /// Compute-parallel units: banks across the system (blocks within a
    /// bank share its PE array and execute serially, §3.3/SALP).
    pub fn parallel_banks(&self) -> u64 {
        self.hw.dram.total_banks()
    }

    /// Per-pass instruction costs at `prec` (pre-computed at construction).
    pub fn pass_costs(&self, prec: Precision) -> PassCosts {
        self.costs[match prec {
            Precision::Int2 => 0,
            Precision::Int4 => 1,
            Precision::Int8 => 2,
            Precision::Int16 => 3,
        }]
    }

    /// Effective per-channel host↔DRAM bandwidth, bytes/ns.
    pub fn channel_bw_bytes_per_ns(&self) -> f64 {
        self.hw.dram.channel_bw_bytes() * self.hw.timing.channel_efficiency / 1e9
    }

    /// Ideal MAC time at `prec` (ns per MAC per PE) — the utilization
    /// denominator (peak: every PE retires one MAC per multiply pass).
    pub fn ideal_mac_ns(&self, prec: Precision) -> f64 {
        self.hw.mul_pass_ns(prec)
    }

    /// Host-side add cost, ns per element.
    pub fn host_add_ns(&self) -> f64 {
        self.hw.timing.host_add_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, Features, Precision};

    #[test]
    fn level_counts_paper() {
        let m = HwModel::new(&racam_paper());
        assert_eq!(m.level_counts(), [8, 32, 8, 16, 2048]);
        assert_eq!(m.block_width(), 1024);
        assert_eq!(m.parallel_banks(), 32768);
    }

    #[test]
    fn pass_costs_reflect_features() {
        let m = HwModel::new(&racam_paper());
        let full = m.pass_costs(Precision::Int8);
        let no_lb = m.with_features(Features::NO_PR_BU_LB).pass_costs(Precision::Int8);
        assert!(no_lb.mul_ns > 3.0 * full.mul_ns);
        assert!(no_lb.mul_row_accesses > full.mul_row_accesses);
    }

    #[test]
    fn bandwidth_is_efficiency_scaled() {
        let m = HwModel::new(&racam_paper());
        let raw = m.hw.dram.channel_bw_bytes() / 1e9;
        assert!(m.channel_bw_bytes_per_ns() < raw);
        assert!(m.channel_bw_bytes_per_ns() > 0.5 * raw);
    }

    #[test]
    fn ideal_mac_matches_tops_calibration() {
        let m = HwModel::new(&racam_paper());
        let macs_per_sec = m.parallel_banks() as f64 * m.block_width() as f64
            / (m.ideal_mac_ns(Precision::Int8) * 1e-9);
        let tops = 2.0 * macs_per_sec / 1e12;
        assert!((tops - 986.9).abs() < 1.0, "{tops}");
    }
}
