//! The §4.4 mapping search as a **shared, thread-safe service**.
//!
//! [`MappingService`] is the crate's single kernel-pricing authority.  It
//! owns the hardware model and a concurrent per-shape result cache shared
//! by every clone — serving shards, baseline comparisons, and experiments
//! all price against the same table, so each kernel shape is searched
//! exactly once system-wide (the paper's §7 amortization, made global).
//!
//! The search paths exposed:
//!
//! * [`MappingService::search_serial`] — the single-threaded exhaustive
//!   reference walk over the enumerated space (first strictly-lower-
//!   latency candidate wins, i.e. the earliest candidate among latency
//!   ties);
//! * [`MappingService::search`] / [`MappingService::search_best_first`]
//!   — the **best-first** search (the serving default): candidates
//!   stream from the lazy generator ([`super::space::lazy_mappings`]),
//!   each is admitted to a min-heap keyed by its analytic lower bound
//!   ([`super::model_sw::lower_bound`] — the compute cost with I/O
//!   dropped), and full evaluations pop in *bound order* so the
//!   incumbent tightens maximally fast; the moment the cheapest
//!   remaining bound reaches the incumbent, the whole frontier is
//!   pruned in one cut.  The winner is the minimum by `(total_ns,
//!   enumeration index)` — bit-for-bit the serial exhaustive winner
//!   (tie-breaking contract in `docs/mapping.md`);
//! * [`MappingService::search_enumeration_pruned`] — the prior parallel
//!   bound-pruned scan in enumeration order, kept as the `exp map`
//!   comparison baseline: workers chunk the candidate list, skip
//!   candidates whose bound already reaches their chunk's incumbent,
//!   and reduce the per-chunk winners **in chunk order with a strict
//!   `<`**, so its winner is also the serial reference's;
//! * [`MappingService::search_exhaustive`] — the parallel search without
//!   pruning (identical `candidates`/`worst_ns` to the serial reference;
//!   use it when the whole-space spread is the result, as in Fig. 15);
//! * [`MappingService::search_serial_pruned`] — the single-threaded
//!   enumeration-order pruned walk, the oracle for the chunked path.
//!
//! Concurrent [`MappingService::search_cached`] calls for the same shape
//! coalesce on a per-shape once-cell: the first caller runs the search,
//! later callers (including ones racing on other threads) block on the
//! cell and reuse the result, so the miss counter for a repeated shape is
//! exactly 1 no matter how many shards ask.
//!
//! ## Warm store
//!
//! [`MappingService::set_warm_path`] attaches a persistent mapping table
//! (see [`super::store`]): existing entries load into the cache
//! immediately (counted by [`MappingService::warm_loads`]), and when the
//! last clone of the service drops, the cache is **merged** back into
//! the file — atomic temp-file + rename, best entry per
//! (shape, channels) key — so concurrent processes fold their tables
//! instead of clobbering each other and a repeated run never re-searches
//! a shape.

use super::model_hw::HwModel;
use super::model_sw::{evaluate, lower_bound, Evaluation};
use super::space::{enumerate_mappings, lazy_mappings, Mapping};
use crate::config::{HwConfig, MatmulShape};
use std::cmp::Reverse;
use std::collections::hash_map::Entry;
use std::collections::{BinaryHeap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Outcome of a mapping-space search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// The latency-optimal mapping's evaluation.
    pub best: Evaluation,
    /// Candidates fully evaluated.
    pub candidates: usize,
    /// Candidates skipped because their analytic lower bound
    /// ([`super::model_sw::lower_bound`]) already reached the incumbent —
    /// they could not win under strict-`<` tie-breaking, so the winner is
    /// unchanged.  Zero for exhaustive searches.
    pub pruned: usize,
    /// [`super::model_sw::lower_bound`] invocations the search performed
    /// (one per candidate admitted or pruned on the best-first path; one
    /// per incumbent check on the enumeration-order pruned paths).  Zero
    /// for exhaustive searches.
    pub bound_calls: usize,
    /// High-water mark of the best-first frontier heap — how much of the
    /// space was simultaneously admitted but not yet evaluated.  Zero for
    /// the scan-based paths.
    pub frontier_peak: usize,
    /// Worst *evaluated* candidate latency (for the Fig. 15 spread).  A
    /// pruned search skips exactly the high-latency candidates, so use an
    /// exhaustive search when the spread itself is the result.
    pub worst_ns: f64,
}

impl SearchResult {
    /// Max-to-min latency ratio across the space (Fig. 15 reports 510.85×).
    /// Meaningful on exhaustive results; a pruned search under-reports it.
    pub fn spread(&self) -> f64 {
        self.worst_ns / self.best.total_ns()
    }

    /// Candidates the search looked at, evaluated or pruned (the full
    /// enumerated space minus degenerate candidates).
    pub fn examined(&self) -> usize {
        self.candidates + self.pruned
    }
}

/// Minimum candidates per worker before the parallel search pays for the
/// thread spawns; below this the serial path is used.
const MIN_CANDIDATES_PER_WORKER: usize = 48;

/// Relative slack applied to the incumbent before pruning on the analytic
/// lower bound: a candidate is skipped only when `bound >= incumbent *
/// PRUNE_SLACK`.  The bound's validity argument is real-valued; its float
/// evaluation runs through a different expression tree than the full
/// sweep, so the slack absorbs any ulp-level non-monotonicity — the
/// `lower_bound_never_exceeds_evaluation` oracle pins the bound within
/// 1e-12 relative, three orders of magnitude inside this margin, so a
/// candidate that could still beat the incumbent under strict `<` is
/// never pruned.
const PRUNE_SLACK: f64 = 1.0 + 1e-9;

/// Searches currently running across all services in the process.  Worker
/// counts divide by this so N shards cold-searching distinct shapes share
/// the machine instead of spawning N × cores threads.
static ACTIVE_SEARCHES: AtomicU64 = AtomicU64::new(0);

/// RAII decrement for [`ACTIVE_SEARCHES`].
struct SearchSlot;

impl SearchSlot {
    fn acquire() -> (Self, u64) {
        let active = ACTIVE_SEARCHES.fetch_add(1, Ordering::Relaxed) + 1;
        (SearchSlot, active)
    }
}

impl Drop for SearchSlot {
    fn drop(&mut self) {
        ACTIVE_SEARCHES.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Per-chunk partial search state.
struct Partial {
    best: Option<Evaluation>,
    worst_ns: f64,
    candidates: usize,
    pruned: usize,
    bound_calls: usize,
}

impl Partial {
    fn into_result(self) -> Option<SearchResult> {
        self.best.map(|best| SearchResult {
            best,
            candidates: self.candidates,
            pruned: self.pruned,
            bound_calls: self.bound_calls,
            frontier_peak: 0,
            worst_ns: self.worst_ns,
        })
    }
}

/// One admitted best-first candidate: min-heap key is the analytic lower
/// bound, ties broken toward the earlier enumeration index so equal-bound
/// candidates evaluate in enumeration order (deterministic pop order).
struct FrontierEntry {
    bound: f64,
    seq: usize,
    mapping: Mapping,
}

impl PartialEq for FrontierEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for FrontierEntry {}

impl PartialOrd for FrontierEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FrontierEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.bound.total_cmp(&other.bound).then(self.seq.cmp(&other.seq))
    }
}

struct Shared {
    hw: HwModel,
    /// Shape → once-cell holding the (possibly negative) search outcome.
    /// The map lock is held only to look up / create the cell; the search
    /// itself runs inside the cell's one-time initializer, so different
    /// shapes search concurrently while duplicate shapes coalesce.
    cache: Mutex<HashMap<MatmulShape, Arc<OnceLock<Option<SearchResult>>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Entries imported from the warm store (see
    /// [`MappingService::set_warm_path`]).
    warm_loads: AtomicU64,
    /// Attached warm-store file: the cache merges back into it when the
    /// last clone drops.
    warm_path: Mutex<Option<PathBuf>>,
}

impl Drop for Shared {
    fn drop(&mut self) {
        // Last clone gone: merge the cache into the warm store, if one is
        // attached.  `get_mut` needs no locking (we hold `&mut self`) and
        // the merge is atomic on disk; errors are swallowed — a drop path
        // must never panic, and losing a warm table only costs re-search.
        let Some(path) = self.warm_path.get_mut().ok().and_then(|p| p.take()) else {
            return;
        };
        let Ok(cache) = self.cache.get_mut() else { return };
        let entries: Vec<(MatmulShape, SearchResult)> = cache
            .iter()
            .filter_map(|(shape, cell)| cell.get().and_then(|o| o.clone()).map(|r| (*shape, r)))
            .collect();
        if entries.is_empty() {
            return;
        }
        let channels = self.hw.hw.dram.channels;
        let _ = super::store::merge_entries_into_file(&path, channels, &entries);
    }
}

/// Shared concurrent mapping service.  `Clone` is cheap and shares the
/// cache and counters (it is an `Arc` handle).
#[derive(Clone)]
pub struct MappingService {
    shared: Arc<Shared>,
}

impl MappingService {
    pub fn new(hw: HwModel) -> Self {
        MappingService {
            shared: Arc::new(Shared {
                hw,
                cache: Mutex::new(HashMap::new()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                warm_loads: AtomicU64::new(0),
                warm_path: Mutex::new(None),
            }),
        }
    }

    /// Service over a hardware configuration (builds the [`HwModel`]).
    pub fn for_config(hw: &HwConfig) -> Self {
        MappingService::new(HwModel::new(hw))
    }

    pub fn hw(&self) -> &HwModel {
        &self.shared.hw
    }

    /// Unique-shape search count (one per shape ever priced).
    pub fn misses(&self) -> u64 {
        self.shared.misses.load(Ordering::Relaxed)
    }

    /// Cache-served request count (includes callers that waited on an
    /// in-flight search for the same shape).
    pub fn hits(&self) -> u64 {
        self.shared.hits.load(Ordering::Relaxed)
    }

    /// Number of cached shapes (searched or imported).
    pub fn cache_len(&self) -> usize {
        self.shared.cache.lock().expect("mapping cache poisoned").len()
    }

    /// Serial *exhaustive* reference search: first strictly-lower-latency
    /// candidate wins.  Returns `None` when no candidate evaluates
    /// (degenerate shapes with a zero-sized dimension).
    pub fn search_serial(&self, shape: &MatmulShape) -> Option<SearchResult> {
        let mappings = enumerate_mappings(shape);
        Self::scan_chunk(shape, &mappings, &self.shared.hw, false).into_result()
    }

    /// Serial *pruned* search — the single-threaded oracle for the pruned
    /// parallel path.  Winner bit-for-bit identical to
    /// [`Self::search_serial`]; `candidates`/`pruned` report how much of
    /// the space the bound skipped.
    pub fn search_serial_pruned(&self, shape: &MatmulShape) -> Option<SearchResult> {
        let mappings = enumerate_mappings(shape);
        Self::scan_chunk(shape, &mappings, &self.shared.hw, true).into_result()
    }

    /// **Best-first** search — the serving default; see
    /// [`Self::search_best_first`].
    pub fn search(&self, shape: &MatmulShape) -> Option<SearchResult> {
        self.search_best_first(shape)
    }

    /// Best-first branch-and-bound over the lazily enumerated space.
    ///
    /// Two phases:
    ///
    /// 1. **Admission** — candidates stream from
    ///    [`super::space::lazy_mappings`] in enumeration order.  The first
    ///    evaluable candidate is evaluated immediately and seeds the
    ///    incumbent (the same seed the serial pruned walk uses); every
    ///    later candidate gets one [`lower_bound`] call and is either
    ///    pruned on the spot (bound already reaches the incumbent) or
    ///    pushed onto a min-heap keyed by `(bound, enumeration index)`.
    /// 2. **Pop** — entries pop in bound order and are fully evaluated,
    ///    tightening the incumbent as fast as the bound ordering allows.
    ///    Because the heap is a min-heap on the bound, the first popped
    ///    entry whose bound reaches the incumbent proves *every* remaining
    ///    entry dominated: the whole frontier is pruned in one cut.
    ///
    /// The incumbent is replaced only when a candidate's total is strictly
    /// lower, or exactly equal with an earlier enumeration index — i.e.
    /// the winner is the minimum by `(total_ns, enumeration index)`, which
    /// is precisely the candidate [`Self::search_serial`]'s first-strict-
    /// improvement walk keeps.  A pruned candidate's true total strictly
    /// exceeds the incumbent (the bound sits within 1e-12 relative of
    /// truth, [`PRUNE_SLACK`] allows 1e-9), so it can neither win nor tie:
    /// the winner is bit-for-bit the serial exhaustive reference's, in
    /// whatever order the heap evaluates.
    pub fn search_best_first(&self, shape: &MatmulShape) -> Option<SearchResult> {
        let hw = &self.shared.hw;
        let mut heap: BinaryHeap<Reverse<FrontierEntry>> = BinaryHeap::new();
        let mut best: Option<(Evaluation, usize)> = None;
        let mut candidates = 0usize;
        let mut pruned = 0usize;
        let mut bound_calls = 0usize;
        let mut frontier_peak = 0usize;
        let mut worst_ns = 0.0f64;

        for (seq, mapping) in lazy_mappings(shape).enumerate() {
            bound_calls += 1;
            let Some(bound) = lower_bound(shape, &mapping, hw) else {
                // Degenerate for the bound ⇔ degenerate for the full
                // evaluation (same `compute_side` gate) — not a candidate.
                continue;
            };
            let Some((incumbent, _)) = best.as_ref() else {
                // Seed the incumbent with the first evaluable candidate so
                // admission pruning starts immediately.
                if let Some(eval) = evaluate(shape, &mapping, hw) {
                    candidates += 1;
                    worst_ns = worst_ns.max(eval.total_ns());
                    best = Some((eval, seq));
                }
                continue;
            };
            if bound >= incumbent.total_ns() * PRUNE_SLACK {
                pruned += 1;
                continue;
            }
            heap.push(Reverse(FrontierEntry { bound, seq, mapping }));
            frontier_peak = frontier_peak.max(heap.len());
        }

        while let Some(Reverse(entry)) = heap.pop() {
            let (incumbent, _) = best.as_ref().expect("heap admission requires an incumbent");
            if entry.bound >= incumbent.total_ns() * PRUNE_SLACK {
                // Min-heap: every remaining bound is at least this one.
                pruned += 1 + heap.len();
                break;
            }
            if let Some(eval) = evaluate(shape, &entry.mapping, hw) {
                candidates += 1;
                let t = eval.total_ns();
                worst_ns = worst_ns.max(t);
                let (bt, bseq) = {
                    let (b, s) = best.as_ref().expect("incumbent set above");
                    (b.total_ns(), *s)
                };
                if t < bt || (t == bt && entry.seq < bseq) {
                    best = Some((eval, entry.seq));
                }
            }
        }

        best.map(|(best, _)| SearchResult {
            best,
            candidates,
            pruned,
            bound_calls,
            frontier_peak,
            worst_ns,
        })
    }

    /// Parallel enumeration-order **pruned** scan — the pre-best-first
    /// algorithm, kept as the `exp map` comparison baseline.  Each worker
    /// walks its enumeration-ordered chunk skipping candidates whose
    /// analytic lower bound ([`super::model_sw::lower_bound`]) already
    /// reaches the chunk's incumbent: such a candidate cannot win under
    /// the strict-`<` rule, so the winner is bit-for-bit identical to the
    /// serial exhaustive reference (the `candidates`/`worst_ns` counters
    /// cover only evaluated candidates — see [`SearchResult::pruned`]).
    pub fn search_enumeration_pruned(&self, shape: &MatmulShape) -> Option<SearchResult> {
        self.scan_parallel(shape, true)
    }

    /// Parallel **exhaustive** search: every candidate evaluated.  The
    /// winner, `candidates`, and `worst_ns` are bit-for-bit identical to
    /// [`Self::search_serial`] — candidate chunks preserve enumeration
    /// order and the chunk-ordered reduction keeps the earliest candidate
    /// among exact latency ties (independent of the worker count).  Use
    /// this when the spread across the whole space is itself the result
    /// (Fig. 15).
    pub fn search_exhaustive(&self, shape: &MatmulShape) -> Option<SearchResult> {
        self.scan_parallel(shape, false)
    }

    fn scan_parallel(&self, shape: &MatmulShape, prune: bool) -> Option<SearchResult> {
        let mappings = enumerate_mappings(shape);
        let (_slot, active) = SearchSlot::acquire();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        // Concurrent searches (e.g. shards cold-starting on distinct
        // shapes) split the cores between them rather than oversubscribing.
        let fair_cores = (cores as u64 / active.max(1)).max(1) as usize;
        let workers = fair_cores.min(mappings.len() / MIN_CANDIDATES_PER_WORKER);
        if workers <= 1 {
            return Self::scan_chunk(shape, &mappings, &self.shared.hw, prune).into_result();
        }

        let chunk_len = mappings.len().div_ceil(workers);
        let hw = &self.shared.hw;
        let mut partials: Vec<Partial> = Vec::with_capacity(workers);
        std::thread::scope(|s| {
            let handles: Vec<_> = mappings
                .chunks(chunk_len)
                .map(|chunk| s.spawn(move || Self::scan_chunk(shape, chunk, hw, prune)))
                .collect();
            for h in handles {
                partials.push(h.join().expect("mapping-search worker panicked"));
            }
        });

        // Chunk-ordered reduction with strict `<`: ties keep the earlier
        // chunk's winner, matching the serial scan exactly.
        let mut best: Option<Evaluation> = None;
        let mut worst_ns = 0.0f64;
        let mut candidates = 0usize;
        let mut pruned = 0usize;
        let mut bound_calls = 0usize;
        for p in partials {
            candidates += p.candidates;
            pruned += p.pruned;
            bound_calls += p.bound_calls;
            worst_ns = worst_ns.max(p.worst_ns);
            if let Some(e) = p.best {
                let better = match best.as_ref() {
                    None => true,
                    Some(b) => e.total_ns() < b.total_ns(),
                };
                if better {
                    best = Some(e);
                }
            }
        }
        best.map(|best| SearchResult {
            best,
            candidates,
            pruned,
            bound_calls,
            frontier_peak: 0,
            worst_ns,
        })
    }

    /// Evaluate one ordered slice of candidates (shared by the serial path
    /// and every parallel worker, so both sides run the same comparisons).
    /// With `prune` on, a candidate whose lower bound already reaches the
    /// incumbent is skipped without a full evaluation — it cannot beat the
    /// incumbent under strict `<`, so the chunk winner is unchanged.
    fn scan_chunk(
        shape: &MatmulShape,
        chunk: &[super::space::Mapping],
        hw: &HwModel,
        prune: bool,
    ) -> Partial {
        let mut best: Option<Evaluation> = None;
        let mut worst_ns = 0.0f64;
        let mut candidates = 0usize;
        let mut pruned = 0usize;
        let mut bound_calls = 0usize;
        for mapping in chunk {
            if prune {
                if let Some(b) = best.as_ref() {
                    bound_calls += 1;
                    match lower_bound(shape, mapping, hw) {
                        Some(bound) if bound >= b.total_ns() * PRUNE_SLACK => {
                            pruned += 1;
                            continue;
                        }
                        Some(_) => {}
                        // Degenerate for the bound ⇒ degenerate for the
                        // full evaluation too; fall through and let it
                        // return `None` (not counted either way).
                        None => {}
                    }
                }
            }
            if let Some(eval) = evaluate(shape, mapping, hw) {
                candidates += 1;
                let t = eval.total_ns();
                worst_ns = worst_ns.max(t);
                let better = match best.as_ref() {
                    None => true,
                    Some(b) => t < b.total_ns(),
                };
                if better {
                    best = Some(eval);
                }
            }
        }
        Partial { best, worst_ns, candidates, pruned, bound_calls }
    }

    /// Search with shared memoization.  Concurrent calls for the same
    /// shape run one search; everyone else waits on the once-cell and
    /// shares the result.
    pub fn search_cached(&self, shape: &MatmulShape) -> Option<SearchResult> {
        let (cell, fresh) = {
            let mut cache = self.shared.cache.lock().expect("mapping cache poisoned");
            match cache.entry(*shape) {
                Entry::Occupied(e) => (Arc::clone(e.get()), false),
                Entry::Vacant(v) => (Arc::clone(v.insert(Arc::new(OnceLock::new()))), true),
            }
        };
        if fresh {
            self.shared.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shared.hits.fetch_add(1, Ordering::Relaxed);
        }
        cell.get_or_init(|| self.search(shape)).clone()
    }

    /// Evaluate every candidate (the Fig. 15 scatter data).
    pub fn evaluate_all(&self, shape: &MatmulShape) -> Vec<Evaluation> {
        enumerate_mappings(shape)
            .iter()
            .filter_map(|m| evaluate(shape, m, &self.shared.hw))
            .collect()
    }

    /// Snapshot of the completed cache entries (for persistence, see
    /// [`super::store`]).  Entries whose search is still in flight are
    /// skipped; negative entries (unsearchable shapes) are skipped too.
    pub fn cache_entries(&self) -> Vec<(MatmulShape, SearchResult)> {
        self.shared
            .cache
            .lock()
            .expect("mapping cache poisoned")
            .iter()
            .filter_map(|(shape, cell)| {
                cell.get().and_then(|o| o.clone()).map(|r| (*shape, r))
            })
            .collect()
    }

    /// Insert a pre-computed result (mapping-table import / warm start).
    pub fn cache_insert(&self, shape: MatmulShape, result: SearchResult) {
        let cell = OnceLock::new();
        let _ = cell.set(Some(result));
        self.shared
            .cache
            .lock()
            .expect("mapping cache poisoned")
            .insert(shape, Arc::new(cell));
    }

    /// Warm-start the cache from a mapping-table file written by
    /// [`Self::persist`] (stored mappings are re-evaluated on this
    /// service's hardware model).  Returns the number of entries loaded.
    pub fn warm_start(&self, path: &Path) -> crate::Result<usize> {
        super::store::load_file(self, path)
    }

    /// Persist the cache to a mapping-table file (§7: "mappings … can be
    /// precomputed or cached at runtime").
    pub fn persist(&self, path: &Path) -> crate::Result<()> {
        super::store::save_file(self, path)
    }

    /// Attach a persistent warm store: load whatever table already exists
    /// at `path` into the cache now (a missing file is an empty table,
    /// not an error), and *merge* the cache back into the file when the
    /// last clone of this service drops.  Returns the number of entries
    /// loaded (also folded into [`Self::warm_loads`]).
    pub fn set_warm_path(&self, path: &Path) -> crate::Result<usize> {
        let loaded = if path.exists() { super::store::load_file(self, path)? } else { 0 };
        self.shared.warm_loads.fetch_add(loaded as u64, Ordering::Relaxed);
        *self.shared.warm_path.lock().expect("warm path poisoned") = Some(path.to_path_buf());
        Ok(loaded)
    }

    /// Build a service with a warm store attached ([`Self::set_warm_path`]).
    pub fn with_warm_path(hw: HwModel, path: &Path) -> crate::Result<Self> {
        let service = MappingService::new(hw);
        service.set_warm_path(path)?;
        Ok(service)
    }

    /// Entries imported from the warm store (0 when none is attached or
    /// the file was empty/new).
    pub fn warm_loads(&self) -> u64 {
        self.shared.warm_loads.load(Ordering::Relaxed)
    }

    /// The attached warm-store path, if any.
    pub fn warm_path(&self) -> Option<PathBuf> {
        self.shared.warm_path.lock().expect("warm path poisoned").clone()
    }

    /// True iff `other` is a clone of this service (same cache, counters,
    /// and warm store).  Lets aggregators deduplicate per-shard handles
    /// before summing counters.
    pub fn shares_cache_with(&self, other: &MappingService) -> bool {
        Arc::ptr_eq(&self.shared, &other.shared)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, Precision};

    fn service() -> MappingService {
        MappingService::for_config(&racam_paper())
    }

    fn gemm() -> MatmulShape {
        MatmulShape::new(1024, 4096, 4096, Precision::Int8)
    }

    fn gemv() -> MatmulShape {
        MatmulShape::new(1, 2048, 2048, Precision::Int8)
    }

    #[test]
    fn search_finds_a_best_mapping() {
        let s = service();
        let r = s.search(&gemm()).expect("GEMM always evaluates");
        // Pruned search: every candidate is either evaluated or provably
        // dominated; the split is reported.
        assert_eq!(r.examined(), 1458);
        assert!(r.pruned > 0, "the GEMM space must prune something");
        assert!(r.candidates + r.pruned == 1458);
        assert!(r.best.total_ns() > 0.0);
        // The whole-space spread needs the exhaustive path.
        let ex = s.search_exhaustive(&gemm()).unwrap();
        assert_eq!(ex.candidates, 1458);
        assert_eq!(ex.pruned, 0);
        assert!(ex.spread() > 1.0);
    }

    #[test]
    fn gemv_search_covers_192_candidates() {
        let s = service();
        let r = s.search(&gemv()).expect("GEMV always evaluates");
        assert_eq!(r.examined(), 192);
        let ex = s.search_exhaustive(&gemv()).unwrap();
        assert_eq!(ex.candidates, 192);
    }

    #[test]
    fn exhaustive_parallel_matches_serial_on_gemm_space() {
        // Acceptance: identical best mapping, counters and worst_ns on
        // the 1458-candidate GEMM space — bit-for-bit.
        let s = service();
        let par = s.search_exhaustive(&gemm()).unwrap();
        let ser = s.search_serial(&gemm()).unwrap();
        assert_eq!(par.best.mapping, ser.best.mapping);
        assert_eq!(par.best.total_ns().to_bits(), ser.best.total_ns().to_bits());
        assert_eq!(par.candidates, ser.candidates);
        assert_eq!(par.pruned, 0);
        assert_eq!(ser.pruned, 0);
        assert_eq!(par.worst_ns.to_bits(), ser.worst_ns.to_bits());
    }

    #[test]
    fn exhaustive_parallel_matches_serial_on_gemv_space() {
        // Acceptance: identical winner on the 192-candidate GEMV space.
        let s = service();
        let par = s.search_exhaustive(&gemv()).unwrap();
        let ser = s.search_serial(&gemv()).unwrap();
        assert_eq!(par.best.mapping, ser.best.mapping);
        assert_eq!(par.best.total_ns().to_bits(), ser.best.total_ns().to_bits());
        assert_eq!(par.candidates, 192);
        assert_eq!(ser.candidates, 192);
    }

    #[test]
    fn pruned_search_keeps_the_exhaustive_winner_bit_for_bit() {
        // The pruning acceptance: with the bound on (serial and parallel)
        // or off, the winner is the same candidate with the same bits.
        let s = service();
        for shape in [
            gemm(),
            gemv(),
            MatmulShape::new(7, 130, 514, Precision::Int8),
            MatmulShape::new(256, 1024, 512, Precision::Int4),
        ] {
            let reference = s.search_serial(&shape).unwrap();
            for pruned in [
                s.search(&shape).unwrap(),
                s.search_serial_pruned(&shape).unwrap(),
                s.search_enumeration_pruned(&shape).unwrap(),
            ] {
                assert_eq!(pruned.best.mapping, reference.best.mapping, "{}", shape.label());
                assert_eq!(
                    pruned.best.total_ns().to_bits(),
                    reference.best.total_ns().to_bits(),
                    "{}",
                    shape.label()
                );
                assert_eq!(pruned.examined(), reference.candidates, "{}", shape.label());
            }
        }
    }

    #[test]
    fn serial_pruning_skips_a_real_share_of_the_gemm_space() {
        // The point of the bound: with the >100x compute spread of the
        // GEMM space, a substantial share of candidates is provably
        // dominated before their rank sweep and I/O model ever run.  (The
        // serial walk carries one incumbent across the whole enumeration,
        // so it prunes at least as much as any chunk of the parallel
        // walk.)
        let s = service();
        let r = s.search_serial_pruned(&gemm()).unwrap();
        assert!(
            r.pruned * 10 > r.examined(),
            "only {} of {} candidates pruned",
            r.pruned,
            r.examined()
        );
    }

    #[test]
    fn best_is_really_minimal() {
        let s = service();
        let shape = MatmulShape::new(256, 1024, 512, Precision::Int8);
        let r = s.search(&shape).unwrap();
        for eval in s.evaluate_all(&shape) {
            assert!(r.best.total_ns() <= eval.total_ns() + 1e-9);
        }
    }

    #[test]
    fn cache_hits_on_repeated_shapes() {
        let s = service();
        let shape = MatmulShape::new(1, 4096, 4096, Precision::Int8);
        let a = s.search_cached(&shape).unwrap();
        let b = s.search_cached(&shape).unwrap();
        assert_eq!(s.hits(), 1);
        assert_eq!(s.misses(), 1);
        assert_eq!(a.best.total_ns(), b.best.total_ns());
    }

    #[test]
    fn different_precisions_cache_separately() {
        let s = service();
        s.search_cached(&MatmulShape::new(1, 1024, 1024, Precision::Int8));
        s.search_cached(&MatmulShape::new(1, 1024, 1024, Precision::Int4));
        assert_eq!(s.misses(), 2);
    }

    #[test]
    fn concurrent_callers_share_one_search() {
        // Acceptance: cache misses for a repeated shape across threads == 1.
        let s = service();
        let shape = gemv();
        let mut totals = Vec::new();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let svc = s.clone();
                    scope.spawn(move || svc.search_cached(&shape).unwrap().best.total_ns())
                })
                .collect();
            for h in handles {
                totals.push(h.join().unwrap());
            }
        });
        assert_eq!(s.misses(), 1, "repeated shape must be searched once");
        assert_eq!(s.hits(), 3);
        assert!(totals.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn degenerate_shape_returns_none_and_caches_negatively() {
        let s = service();
        let shape = MatmulShape::new(0, 64, 64, Precision::Int8);
        assert!(s.search(&shape).is_none());
        assert!(s.search_cached(&shape).is_none());
        assert!(s.search_cached(&shape).is_none());
        assert_eq!(s.misses(), 1);
        assert_eq!(s.hits(), 1);
    }

    #[test]
    fn clones_share_the_cache() {
        let s = service();
        let t = s.clone();
        s.search_cached(&gemv());
        t.search_cached(&gemv());
        assert_eq!(s.misses(), 1);
        assert_eq!(t.hits(), 1);
        assert_eq!(s.cache_len(), 1);
    }

    #[test]
    fn best_first_evaluates_fewer_candidates_than_enumeration_pruning() {
        // The point of bound ordering: evaluating in bound order tightens
        // the incumbent faster than enumeration order, so strictly fewer
        // full evaluations run on the GEMM space (the PR acceptance
        // headline; `exp map` reports the ratio).
        let s = service();
        let bf = s.search_best_first(&gemm()).unwrap();
        let ep = s.search_serial_pruned(&gemm()).unwrap();
        assert!(
            bf.candidates < ep.candidates,
            "best-first evaluated {} vs enumeration-order {}",
            bf.candidates,
            ep.candidates
        );
        // Accounting invariants: one bound per candidate, every candidate
        // either evaluated or pruned, and the frontier really existed.
        assert_eq!(bf.bound_calls, 1458);
        assert_eq!(bf.examined(), 1458);
        assert!(bf.frontier_peak > 0);
        assert!(bf.frontier_peak <= 1458);
        // The scan paths never build a frontier.
        assert_eq!(ep.frontier_peak, 0);
        assert!(ep.bound_calls > 0);
        // Exhaustive paths call no bounds at all.
        let ex = s.search_exhaustive(&gemm()).unwrap();
        assert_eq!((ex.bound_calls, ex.frontier_peak), (0, 0));
    }

    #[test]
    fn warm_path_persists_on_drop_and_reloads() {
        let path = std::env::temp_dir().join("racam_warm_path_drop_test.json");
        std::fs::remove_file(&path).ok();
        let shapes = [gemm(), gemv()];
        {
            let s = service();
            assert_eq!(s.set_warm_path(&path).unwrap(), 0, "no table yet");
            assert_eq!(s.warm_loads(), 0);
            assert_eq!(s.warm_path().as_deref(), Some(path.as_path()));
            let clone = s.clone();
            for shape in &shapes {
                s.search_cached(shape);
            }
            drop(s);
            // A surviving clone keeps the store alive — nothing written yet.
            assert!(!path.exists(), "persist must wait for the last clone");
            drop(clone);
        }
        assert!(path.exists(), "last clone dropped: table must be persisted");

        let warm = service();
        assert_eq!(warm.set_warm_path(&path).unwrap(), 2);
        assert_eq!(warm.warm_loads(), 2);
        for shape in &shapes {
            warm.search_cached(shape).unwrap();
        }
        assert_eq!(warm.misses(), 0, "warm store must pre-warm every shape");
        assert_eq!(warm.hits(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shares_cache_with_distinguishes_clones_from_siblings() {
        let s = service();
        let clone = s.clone();
        let sibling = service();
        assert!(s.shares_cache_with(&clone));
        assert!(!s.shares_cache_with(&sibling));
    }
}
