//! Mapping-space types and exhaustive enumeration (paper §4.1–§4.2).

use crate::config::MatmulShape;
use std::fmt;

/// A matmul dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    M,
    N,
    K,
}

impl Dim {
    pub const ALL: [Dim; 3] = [Dim::M, Dim::N, Dim::K];

    pub fn letter(&self) -> char {
        match self {
            Dim::M => 'M',
            Dim::N => 'N',
            Dim::K => 'K',
        }
    }
}

/// A parallelism level of the DRAM hierarchy (§4: C, R, D, B, A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Level {
    Channel,
    Rank,
    Device,
    Bank,
    /// Block/array level: vertical slices of subarrays (§4's "Blocks").
    Array,
}

impl Level {
    /// Position of this level in [`LEVELS`] order (C, R, D, B, A) — an
    /// exhaustive match, so it can neither drift from the const nor
    /// panic the way a `position(..).unwrap()` scan could.
    pub fn index(self) -> usize {
        match self {
            Level::Channel => 0,
            Level::Rank => 1,
            Level::Device => 2,
            Level::Bank => 3,
            Level::Array => 4,
        }
    }

    pub fn letter(&self) -> char {
        match self {
            Level::Channel => 'C',
            Level::Rank => 'R',
            Level::Device => 'D',
            Level::Bank => 'B',
            Level::Array => 'A',
        }
    }
}

/// Canonical level order used throughout (outermost → innermost).
pub const LEVELS: [Level; 5] = [Level::Channel, Level::Rank, Level::Device, Level::Bank, Level::Array];

/// A small set of dims (bitmask).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct DimSet(u8);

impl DimSet {
    pub const EMPTY: DimSet = DimSet(0);

    pub fn of(dims: &[Dim]) -> DimSet {
        let mut s = DimSet(0);
        for d in dims {
            s = s.with(*d);
        }
        s
    }

    pub fn with(self, d: Dim) -> DimSet {
        DimSet(self.0 | 1 << d as u8)
    }

    pub fn contains(&self, d: Dim) -> bool {
        self.0 & (1 << d as u8) != 0
    }

    pub fn complement(&self) -> DimSet {
        DimSet(!self.0 & 0b111)
    }

    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = Dim> + '_ {
        Dim::ALL.into_iter().filter(|d| self.contains(*d))
    }

    pub fn letters(&self) -> String {
        self.iter().map(|d| d.letter()).collect()
    }
}

/// Hierarchical mapping: one dim per level, in [`LEVELS`] order (§4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct HierMapping {
    pub assign: [Dim; 5],
}

impl HierMapping {
    pub fn dim_of(&self, level: Level) -> Dim {
        self.assign[level.index()]
    }

    /// Levels assigned to `d`, in canonical order.
    pub fn levels_of(&self, d: Dim) -> impl Iterator<Item = Level> + '_ {
        LEVELS.into_iter().zip(self.assign).filter_map(move |(l, a)| (a == d).then_some(l))
    }
}

impl fmt::Display for HierMapping {
    /// Paper Fig. 7 style: `{M: RB, N: CD, K: A}`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, d) in Dim::ALL.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}: ", d.letter())?;
            let mut any = false;
            for l in self.levels_of(*d) {
                write!(f, "{}", l.letter())?;
                any = true;
            }
            if !any {
                write!(f, "-")?;
            }
        }
        write!(f, "}}")
    }
}

/// Block mapping: which dims lie along the block's columns; the rest lie
/// along rows (§4.2).  `{R: MN, C: K}` means K along columns (reduced by
/// the popcount unit) and the M/N output tuples along rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockMapping {
    pub col_dims: DimSet,
}

impl BlockMapping {
    pub fn new(col_dims: DimSet) -> Self {
        assert!(!col_dims.is_empty() && !col_dims.complement().is_empty(), "both axes need a dim");
        BlockMapping { col_dims }
    }

    pub fn row_dims(&self) -> DimSet {
        self.col_dims.complement()
    }

    /// Column reduction (fused `pim_mul_red`) applies iff K is on columns.
    pub fn k_on_cols(&self) -> bool {
        self.col_dims.contains(Dim::K)
    }

    /// All 6 valid partitions of {M, N, K} into (rows, cols).
    pub fn all() -> Vec<BlockMapping> {
        (1u8..7)
            .map(|bits| BlockMapping { col_dims: DimSet(bits) })
            .collect()
    }

    /// Paper Fig. 15 style label, e.g. `RNCMK` = rows:N, cols:MK.
    pub fn label(&self) -> String {
        format!("R{}C{}", self.row_dims().letters(), self.col_dims.letters())
    }
}

/// A complete mapping candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mapping {
    pub hier: HierMapping,
    pub block: BlockMapping,
}

impl fmt::Display for Mapping {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} × {}", self.hier, self.block.label())
    }
}

/// Lazy candidate generator: yields exactly the sequence
/// [`enumerate_mappings`] materializes, in the same order, without
/// allocating the whole space.  The hierarchical assignment is an
/// odometer over the five levels (level 0 — Channel — is the slowest
/// digit) and the block mapping is the fastest digit, matching the
/// recursive enumeration the serial reference search was specified
/// against; the position in this sequence is the candidate's canonical
/// *enumeration index*, the tie-breaking key of every search path.
pub struct MappingCandidates {
    dims: &'static [Dim],
    /// Odometer digits: index into `dims` per hierarchy level.
    digits: [usize; 5],
    /// Next block-mapping bitmask, 1..=6 ([`BlockMapping::all`] order).
    block_bits: u8,
    remaining: usize,
}

/// Lazily enumerate the mapping space for a shape, in canonical order.
///
/// GEMV shapes (`m == 1`) exclude M from the hierarchical assignment —
/// there is nothing to tile — giving 2⁵ × 6 = 192 candidates; full GEMMs
/// give 3⁵ × 6 = 1458.
pub fn lazy_mappings(shape: &MatmulShape) -> MappingCandidates {
    let dims: &'static [Dim] = if shape.m == 1 { &[Dim::N, Dim::K] } else { &Dim::ALL };
    MappingCandidates {
        dims,
        digits: [0; 5],
        block_bits: 1,
        remaining: dims.len().pow(5) * 6,
    }
}

impl Iterator for MappingCandidates {
    type Item = Mapping;

    fn next(&mut self) -> Option<Mapping> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let mut assign = [Dim::M; 5];
        for (a, &digit) in assign.iter_mut().zip(self.digits.iter()) {
            *a = self.dims[digit];
        }
        let out = Mapping {
            hier: HierMapping { assign },
            block: BlockMapping { col_dims: DimSet(self.block_bits) },
        };
        // Advance: block mask first, then levels innermost to outermost.
        if self.block_bits < 6 {
            self.block_bits += 1;
        } else {
            self.block_bits = 1;
            for digit in self.digits.iter_mut().rev() {
                if *digit + 1 < self.dims.len() {
                    *digit += 1;
                    break;
                }
                *digit = 0;
            }
        }
        Some(out)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for MappingCandidates {}

/// Enumerate the full mapping space for a shape (the materialized form of
/// [`lazy_mappings`]; same candidates, same order).
pub fn enumerate_mappings(shape: &MatmulShape) -> Vec<Mapping> {
    lazy_mappings(shape).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    #[test]
    fn gemm_space_is_1458() {
        let s = MatmulShape::new(1024, 12288, 12288, Precision::Int8);
        assert_eq!(enumerate_mappings(&s).len(), 1458); // 3^5 × 6
    }

    #[test]
    fn gemv_space_is_192() {
        // Paper §7: "192 for GEMV".
        let s = MatmulShape::new(1, 2048, 2048, Precision::Int8);
        assert_eq!(enumerate_mappings(&s).len(), 192); // 2^5 × 6
    }

    #[test]
    fn lazy_generator_matches_recursive_enumeration_order() {
        // The enumeration index is the tie-breaking key of every search
        // path, so the lazy odometer must reproduce the recursive
        // reference enumeration *in order*, not just as a set.
        fn recursive(shape: &MatmulShape) -> Vec<Mapping> {
            let dims: &[Dim] = if shape.m == 1 { &[Dim::N, Dim::K] } else { &Dim::ALL };
            let blocks = BlockMapping::all();
            let mut out = Vec::new();
            let mut assign = [Dim::M; 5];
            fn rec(dims: &[Dim], assign: &mut [Dim; 5], i: usize, blocks: &[BlockMapping], out: &mut Vec<Mapping>) {
                if i == 5 {
                    for b in blocks {
                        out.push(Mapping { hier: HierMapping { assign: *assign }, block: *b });
                    }
                    return;
                }
                for d in dims {
                    assign[i] = *d;
                    rec(dims, assign, i + 1, blocks, out);
                }
            }
            rec(dims, &mut assign, 0, &blocks, &mut out);
            out
        }
        for shape in [
            MatmulShape::new(1024, 4096, 4096, Precision::Int8),
            MatmulShape::new(1, 2048, 2048, Precision::Int8),
        ] {
            let lazy: Vec<Mapping> = lazy_mappings(&shape).collect();
            assert_eq!(lazy, recursive(&shape));
            assert_eq!(enumerate_mappings(&shape), lazy);
        }
    }

    #[test]
    fn lazy_generator_reports_exact_length() {
        let gemm = MatmulShape::new(64, 64, 64, Precision::Int8);
        let mut it = lazy_mappings(&gemm);
        assert_eq!(it.len(), 1458);
        it.next();
        assert_eq!(it.len(), 1457);
        assert_eq!(it.count(), 1457);
        let gemv = MatmulShape::new(1, 64, 64, Precision::Int8);
        assert_eq!(lazy_mappings(&gemv).len(), 192);
    }

    #[test]
    fn mappings_are_unique() {
        let s = MatmulShape::new(64, 64, 64, Precision::Int8);
        let all = enumerate_mappings(&s);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), all.len());
    }

    #[test]
    fn block_mappings_partition_properly() {
        for b in BlockMapping::all() {
            assert!(!b.col_dims.is_empty());
            assert!(!b.row_dims().is_empty());
            for d in Dim::ALL {
                assert!(b.col_dims.contains(d) ^ b.row_dims().contains(d));
            }
        }
        assert_eq!(BlockMapping::all().len(), 6);
    }

    #[test]
    fn display_formats() {
        let h = HierMapping { assign: [Dim::N, Dim::M, Dim::N, Dim::M, Dim::K] };
        assert_eq!(h.to_string(), "{M: RB, N: CD, K: A}"); // paper Fig. 7
        let b = BlockMapping::new(DimSet::of(&[Dim::M, Dim::K]));
        assert_eq!(b.label(), "RNCMK"); // paper Fig. 15's winner
    }

    #[test]
    fn dimset_ops() {
        let s = DimSet::of(&[Dim::M, Dim::K]);
        assert!(s.contains(Dim::M) && s.contains(Dim::K) && !s.contains(Dim::N));
        assert_eq!(s.complement(), DimSet::of(&[Dim::N]));
        assert_eq!(s.letters(), "MK");
    }

    #[test]
    fn levels_of_respects_order() {
        let h = HierMapping { assign: [Dim::K, Dim::M, Dim::K, Dim::M, Dim::M] };
        let ks: Vec<Level> = h.levels_of(Dim::K).collect();
        assert_eq!(ks, vec![Level::Channel, Level::Device]);
        assert_eq!(h.dim_of(Level::Bank), Dim::M);
    }
}
