//! Mapping engine (paper §4.4 / Fig. 8): enumerates the mapping space,
//! instantiates and evaluates each candidate with the software + hardware
//! models, and keeps the lowest-latency one.  Optimal mappings are cached
//! per kernel shape — LLM layers reuse a handful of shapes, which is what
//! makes the paper's end-to-end search take seconds (§7).

use super::model_hw::HwModel;
use super::model_sw::{evaluate, Evaluation};
use super::space::enumerate_mappings;
use crate::config::MatmulShape;
use std::collections::HashMap;

/// Outcome of a mapping-space search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// The latency-optimal mapping's evaluation.
    pub best: Evaluation,
    /// Candidates examined.
    pub candidates: usize,
    /// Worst candidate latency (for the Fig. 15 spread).
    pub worst_ns: f64,
}

impl SearchResult {
    /// Max-to-min latency ratio across the space (Fig. 15 reports 510.85×).
    pub fn spread(&self) -> f64 {
        self.worst_ns / self.best.total_ns()
    }
}

/// The mapping engine: exhaustive search + per-shape cache.
pub struct MappingEngine {
    hw: HwModel,
    cache: HashMap<MatmulShape, SearchResult>,
    /// Cache hit/miss counters (searches can be pre-paid or amortized, §7).
    pub hits: u64,
    pub misses: u64,
}

impl MappingEngine {
    pub fn new(hw: HwModel) -> Self {
        MappingEngine { hw, cache: HashMap::new(), hits: 0, misses: 0 }
    }

    pub fn hw(&self) -> &HwModel {
        &self.hw
    }

    /// Exhaustively search the mapping space for `shape` (no cache).
    pub fn search(&self, shape: &MatmulShape) -> SearchResult {
        let mut best: Option<Evaluation> = None;
        let mut worst_ns = 0.0f64;
        let mut candidates = 0;
        for mapping in enumerate_mappings(shape) {
            if let Some(eval) = evaluate(shape, &mapping, &self.hw) {
                candidates += 1;
                let t = eval.total_ns();
                worst_ns = worst_ns.max(t);
                let better = best.as_ref().map_or(true, |b| t < b.total_ns());
                if better {
                    best = Some(eval);
                }
            }
        }
        SearchResult {
            best: best.expect("non-degenerate shapes always evaluate"),
            candidates,
            worst_ns,
        }
    }

    /// Search with memoization (LLM workloads reuse shapes across layers).
    pub fn search_cached(&mut self, shape: &MatmulShape) -> SearchResult {
        if let Some(hit) = self.cache.get(shape) {
            self.hits += 1;
            return hit.clone();
        }
        self.misses += 1;
        let r = self.search(shape);
        self.cache.insert(*shape, r.clone());
        r
    }

    /// Evaluate every candidate (the Fig. 15 scatter data).
    pub fn evaluate_all(&self, shape: &MatmulShape) -> Vec<Evaluation> {
        enumerate_mappings(shape).iter().filter_map(|m| evaluate(shape, m, &self.hw)).collect()
    }

    /// Iterate the cached search results (for persistence, see
    /// [`super::store`]).
    pub fn cache_entries(&self) -> impl Iterator<Item = (&MatmulShape, &SearchResult)> {
        self.cache.iter()
    }

    /// Insert a pre-computed result (mapping-table import).
    pub fn cache_insert(&mut self, shape: MatmulShape, result: SearchResult) {
        self.cache.insert(shape, result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, Precision};

    fn engine() -> MappingEngine {
        MappingEngine::new(HwModel::new(&racam_paper()))
    }

    #[test]
    fn search_finds_a_best_mapping() {
        let e = engine();
        let r = e.search(&MatmulShape::new(1024, 4096, 4096, Precision::Int8));
        assert_eq!(r.candidates, 1458);
        assert!(r.best.total_ns() > 0.0);
        assert!(r.spread() > 1.0);
    }

    #[test]
    fn best_is_really_minimal() {
        let e = engine();
        let shape = MatmulShape::new(256, 1024, 512, Precision::Int8);
        let r = e.search(&shape);
        for eval in e.evaluate_all(&shape) {
            assert!(r.best.total_ns() <= eval.total_ns() + 1e-9);
        }
    }

    #[test]
    fn cache_hits_on_repeated_shapes() {
        let mut e = engine();
        let shape = MatmulShape::new(1, 4096, 4096, Precision::Int8);
        let a = e.search_cached(&shape);
        let b = e.search_cached(&shape);
        assert_eq!(e.hits, 1);
        assert_eq!(e.misses, 1);
        assert_eq!(a.best.total_ns(), b.best.total_ns());
    }

    #[test]
    fn different_precisions_cache_separately() {
        let mut e = engine();
        e.search_cached(&MatmulShape::new(1, 1024, 1024, Precision::Int8));
        e.search_cached(&MatmulShape::new(1, 1024, 1024, Precision::Int4));
        assert_eq!(e.misses, 2);
    }

    #[test]
    fn gemv_search_covers_192_candidates() {
        let e = engine();
        let r = e.search(&MatmulShape::new(1, 2048, 2048, Precision::Int8));
        assert_eq!(r.candidates, 192);
    }
}
