//! Mapping engine (paper §4.4 / Fig. 8) — historical entry point.
//!
//! The original `MappingEngine` was a single-threaded searcher with a
//! private per-instance cache; it grew into the shared, thread-safe
//! [`MappingService`](super::MappingService) (parallel exhaustive search +
//! concurrent once-per-shape cache shared across clones).  The old name is
//! kept as an alias so long-standing call sites — benches, examples, the
//! CLI — keep reading naturally: `MappingEngine::new(HwModel::new(&hw))`
//! constructs a service that is simply not (yet) shared with anyone.

pub type MappingEngine = super::service::MappingService;
