//! Declarative fault schedules for the serving cluster (`docs/robustness.md`).
//!
//! A [`FaultSpec`] is a *seeded schedule of simulated-time events*: every
//! fault fires at a declared nanosecond on the simulated clock, never from
//! wall-clock randomness, so an identical spec + seed reproduces the exact
//! same degraded run bit-for-bit across engines and worker-pool sizes.
//! The taxonomy mirrors the failure modes of a channel-partitioned,
//! disaggregated deployment:
//!
//! * [`FaultEvent::ShardCrash`] — a shard dies permanently; its in-flight
//!   requests are evacuated and re-queued by the coordinator.
//! * [`FaultEvent::Brownout`] — a shard's compute slows by a factor over a
//!   window (thermal throttling, refresh storms).
//! * [`FaultEvent::LinkOutage`] / [`FaultEvent::LinkDegrade`] — the shared
//!   prefill→decode KV link drops or loses bandwidth over a window.
//! * [`FaultEvent::ChannelLoss`] — a shard group permanently loses DRAM
//!   channels; kernels are re-priced through the mapping service at the
//!   reduced channel count.
//!
//! [`RecoveryPolicy`] tunes how the coordinator reacts: the per-request
//! retry budget before a request is counted `failed`, the deterministic
//! exponential backoff for interrupted KV transfers, and the surviving-
//! capacity ceiling below which admission is shed outright.

use super::json::{self, Value};

/// Default per-request retry budget after a crash evacuation.
pub const DEFAULT_RETRY_BUDGET: u32 = 2;
/// Default base of the KV re-transfer exponential backoff (1 ms).
pub const DEFAULT_BACKOFF_BASE_NS: f64 = 1e6;
/// Default cap of the KV re-transfer exponential backoff (16 ms).
pub const DEFAULT_BACKOFF_CAP_NS: f64 = 16e6;

/// One scheduled fault on the simulated clock.  Times are f64 nanoseconds
/// (the serving clock's unit); windows are half-open `[start_ns, end_ns)`.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Shard `shard` dies permanently at `at_ns`: everything running,
    /// queued, or scheduled to arrive there is evacuated to the
    /// coordinator for re-dispatch.
    ShardCrash { shard: usize, at_ns: f64 },
    /// Shard `shard` runs `slowdown`× slower (≥ 1) while the simulated
    /// clock is inside the window.
    Brownout { shard: usize, start_ns: f64, end_ns: f64, slowdown: f64 },
    /// The KV link carries nothing inside the window; interrupted
    /// transfers re-send with capped exponential backoff.
    LinkOutage { start_ns: f64, end_ns: f64 },
    /// The KV link runs at `factor` (0 < factor ≤ 1) of its declared
    /// bandwidth inside the window.
    LinkDegrade { start_ns: f64, end_ns: f64, factor: f64 },
    /// Shard group `group` permanently loses `channels_lost` DRAM
    /// channels at `at_ns`; kernels re-price at the reduced count.
    ChannelLoss { group: String, at_ns: f64, channels_lost: u32 },
}

impl FaultEvent {
    /// Stable lowercase discriminator (the JSON `kind` field).
    pub fn kind_label(&self) -> &'static str {
        match self {
            FaultEvent::ShardCrash { .. } => "shard_crash",
            FaultEvent::Brownout { .. } => "brownout",
            FaultEvent::LinkOutage { .. } => "link_outage",
            FaultEvent::LinkDegrade { .. } => "link_degrade",
            FaultEvent::ChannelLoss { .. } => "channel_loss",
        }
    }

    /// The simulated time at which the fault first takes effect.
    pub fn onset_ns(&self) -> f64 {
        match *self {
            FaultEvent::ShardCrash { at_ns, .. } => at_ns,
            FaultEvent::Brownout { start_ns, .. } => start_ns,
            FaultEvent::LinkOutage { start_ns, .. } => start_ns,
            FaultEvent::LinkDegrade { start_ns, .. } => start_ns,
            FaultEvent::ChannelLoss { at_ns, .. } => at_ns,
        }
    }
}

/// How the coordinator reacts to faults (see `docs/robustness.md`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPolicy {
    /// Re-dispatch attempts per evacuated request before it is counted
    /// `failed` (0 ⇒ every evacuated request fails immediately).
    pub retry_budget: u32,
    /// Base of the deterministic exponential backoff charged in simulated
    /// time when a KV transfer is interrupted by a link outage: attempt
    /// *k* (1-based) waits `min(base · 2^(k-1), cap)` past the outage end.
    pub backoff_base_ns: f64,
    /// Backoff cap (see [`RecoveryPolicy::backoff_base_ns`]).
    pub backoff_cap_ns: f64,
    /// Degradation controller: when the fraction of fresh-prompt-eligible
    /// shards still alive drops *below* this ceiling, evacuated requests
    /// are shed at re-dispatch instead of retried (0.0 disables shedding).
    pub utilization_ceiling: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            retry_budget: DEFAULT_RETRY_BUDGET,
            backoff_base_ns: DEFAULT_BACKOFF_BASE_NS,
            backoff_cap_ns: DEFAULT_BACKOFF_CAP_NS,
            utilization_ceiling: 0.0,
        }
    }
}

impl RecoveryPolicy {
    /// The backoff charged after interrupted-transfer attempt `attempt`
    /// (1-based): `min(base · 2^(attempt-1), cap)`.
    pub fn backoff_ns(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(63);
        (self.backoff_base_ns * (1u64 << exp) as f64).min(self.backoff_cap_ns)
    }
}

/// A complete fault schedule + recovery policy, loadable from JSON
/// (`racam serve --faults FAULTS.json`).  The default spec is empty and
/// reproduces a fault-free run bit-for-bit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultSpec {
    /// Schedule seed: not consumed by injection itself (every event is
    /// explicit), but stamped into reports/benches so synthesized
    /// schedules (e.g. `exp faults`) are reproducible from their seed.
    pub seed: u64,
    /// The scheduled faults, in any order (injection sorts internally).
    pub events: Vec<FaultEvent>,
    /// How the coordinator recovers.
    pub recovery: RecoveryPolicy,
}

impl FaultSpec {
    /// True when the schedule injects nothing (the fault-free identity).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Validate the schedule; errors list every problem found.
    pub fn validate(&self) -> crate::Result<()> {
        let mut errs: Vec<String> = Vec::new();
        let mut crashed: Vec<usize> = Vec::new();
        let mut lost_groups: Vec<&str> = Vec::new();
        for (i, ev) in self.events.iter().enumerate() {
            let window = |errs: &mut Vec<String>, start: f64, end: f64| {
                if !(start >= 0.0 && end.is_finite() && start < end) {
                    errs.push(format!(
                        "event {i} ({}): window [{start}, {end}) must satisfy 0 <= start < end",
                        ev.kind_label()
                    ));
                }
            };
            match *ev {
                FaultEvent::ShardCrash { shard, at_ns } => {
                    if !(at_ns >= 0.0 && at_ns.is_finite()) {
                        errs.push(format!("event {i} (shard_crash): at_ns {at_ns} must be finite and >= 0"));
                    }
                    if crashed.contains(&shard) {
                        errs.push(format!("event {i}: shard {shard} crashes more than once"));
                    }
                    crashed.push(shard);
                }
                FaultEvent::Brownout { start_ns, end_ns, slowdown, .. } => {
                    window(&mut errs, start_ns, end_ns);
                    if !(slowdown >= 1.0 && slowdown.is_finite()) {
                        errs.push(format!("event {i} (brownout): slowdown {slowdown} must be >= 1"));
                    }
                }
                FaultEvent::LinkOutage { start_ns, end_ns } => window(&mut errs, start_ns, end_ns),
                FaultEvent::LinkDegrade { start_ns, end_ns, factor } => {
                    window(&mut errs, start_ns, end_ns);
                    if !(factor > 0.0 && factor <= 1.0) {
                        errs.push(format!("event {i} (link_degrade): factor {factor} must be in (0, 1]"));
                    }
                }
                FaultEvent::ChannelLoss { ref group, at_ns, channels_lost } => {
                    if !(at_ns >= 0.0 && at_ns.is_finite()) {
                        errs.push(format!("event {i} (channel_loss): at_ns {at_ns} must be finite and >= 0"));
                    }
                    if channels_lost == 0 {
                        errs.push(format!("event {i} (channel_loss): channels_lost must be >= 1"));
                    }
                    if lost_groups.contains(&group.as_str()) {
                        errs.push(format!("event {i}: group '{group}' loses channels more than once"));
                    }
                    lost_groups.push(group);
                }
            }
        }
        let r = &self.recovery;
        if !(r.backoff_base_ns > 0.0 && r.backoff_base_ns.is_finite()) {
            errs.push(format!("recovery.backoff_base_ns {} must be finite and > 0", r.backoff_base_ns));
        }
        if !(r.backoff_cap_ns >= r.backoff_base_ns && r.backoff_cap_ns.is_finite()) {
            errs.push(format!(
                "recovery.backoff_cap_ns {} must be finite and >= backoff_base_ns",
                r.backoff_cap_ns
            ));
        }
        if !(0.0..=1.0).contains(&r.utilization_ceiling) {
            errs.push(format!(
                "recovery.utilization_ceiling {} must be in [0, 1]",
                r.utilization_ceiling
            ));
        }
        if errs.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("invalid fault spec:\n  {}", errs.join("\n  "))
        }
    }

    pub fn from_json(s: &str) -> crate::Result<Self> {
        let v = json::parse(s).map_err(anyhow::Error::from)?;
        let spec = Self::from_value(&v).map_err(anyhow::Error::from)?;
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> String {
        self.to_value().pretty()
    }

    pub fn to_value(&self) -> Value {
        let events: Vec<Value> = self
            .events
            .iter()
            .map(|ev| {
                let mut fields = vec![("kind", Value::Str(ev.kind_label().into()))];
                match *ev {
                    FaultEvent::ShardCrash { shard, at_ns } => {
                        fields.push(("shard", Value::Num(shard as f64)));
                        fields.push(("at_ns", Value::Num(at_ns)));
                    }
                    FaultEvent::Brownout { shard, start_ns, end_ns, slowdown } => {
                        fields.push(("shard", Value::Num(shard as f64)));
                        fields.push(("start_ns", Value::Num(start_ns)));
                        fields.push(("end_ns", Value::Num(end_ns)));
                        fields.push(("slowdown", Value::Num(slowdown)));
                    }
                    FaultEvent::LinkOutage { start_ns, end_ns } => {
                        fields.push(("start_ns", Value::Num(start_ns)));
                        fields.push(("end_ns", Value::Num(end_ns)));
                    }
                    FaultEvent::LinkDegrade { start_ns, end_ns, factor } => {
                        fields.push(("start_ns", Value::Num(start_ns)));
                        fields.push(("end_ns", Value::Num(end_ns)));
                        fields.push(("factor", Value::Num(factor)));
                    }
                    FaultEvent::ChannelLoss { ref group, at_ns, channels_lost } => {
                        fields.push(("group", Value::Str(group.clone())));
                        fields.push(("at_ns", Value::Num(at_ns)));
                        fields.push(("channels_lost", Value::Num(channels_lost as f64)));
                    }
                }
                Value::obj(fields)
            })
            .collect();
        Value::obj(vec![
            ("seed", Value::Num(self.seed as f64)),
            ("events", Value::Arr(events)),
            (
                "recovery",
                Value::obj(vec![
                    ("retry_budget", Value::Num(self.recovery.retry_budget as f64)),
                    ("backoff_base_ns", Value::Num(self.recovery.backoff_base_ns)),
                    ("backoff_cap_ns", Value::Num(self.recovery.backoff_cap_ns)),
                    ("utilization_ceiling", Value::Num(self.recovery.utilization_ceiling)),
                ]),
            ),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self, json::JsonError> {
        let seed = match v.get("seed") {
            Ok(s) => s.as_f64()? as u64,
            Err(_) => 0,
        };
        let mut events = Vec::new();
        if let Ok(Value::Arr(evs)) = v.get("events") {
            for ev in evs {
                let kind = ev.get("kind")?.as_str()?;
                events.push(match kind {
                    "shard_crash" => FaultEvent::ShardCrash {
                        shard: ev.get("shard")?.as_u32()? as usize,
                        at_ns: ev.get("at_ns")?.as_f64()?,
                    },
                    "brownout" => FaultEvent::Brownout {
                        shard: ev.get("shard")?.as_u32()? as usize,
                        start_ns: ev.get("start_ns")?.as_f64()?,
                        end_ns: ev.get("end_ns")?.as_f64()?,
                        slowdown: ev.get("slowdown")?.as_f64()?,
                    },
                    "link_outage" => FaultEvent::LinkOutage {
                        start_ns: ev.get("start_ns")?.as_f64()?,
                        end_ns: ev.get("end_ns")?.as_f64()?,
                    },
                    "link_degrade" => FaultEvent::LinkDegrade {
                        start_ns: ev.get("start_ns")?.as_f64()?,
                        end_ns: ev.get("end_ns")?.as_f64()?,
                        factor: ev.get("factor")?.as_f64()?,
                    },
                    "channel_loss" => FaultEvent::ChannelLoss {
                        group: ev.get("group")?.as_str()?.to_string(),
                        at_ns: ev.get("at_ns")?.as_f64()?,
                        channels_lost: ev.get("channels_lost")?.as_u32()?,
                    },
                    other => {
                        return Err(json::JsonError(format!(
                            "unknown fault kind '{other}' (known: shard_crash, brownout, \
                             link_outage, link_degrade, channel_loss)"
                        )))
                    }
                });
            }
        }
        let recovery = match v.get("recovery") {
            Ok(r) => RecoveryPolicy {
                retry_budget: match r.get("retry_budget") {
                    Ok(b) => b.as_u32()?,
                    Err(_) => DEFAULT_RETRY_BUDGET,
                },
                backoff_base_ns: match r.get("backoff_base_ns") {
                    Ok(b) => b.as_f64()?,
                    Err(_) => DEFAULT_BACKOFF_BASE_NS,
                },
                backoff_cap_ns: match r.get("backoff_cap_ns") {
                    Ok(b) => b.as_f64()?,
                    Err(_) => DEFAULT_BACKOFF_CAP_NS,
                },
                utilization_ceiling: match r.get("utilization_ceiling") {
                    Ok(c) => c.as_f64()?,
                    Err(_) => 0.0,
                },
            },
            Err(_) => RecoveryPolicy::default(),
        };
        Ok(FaultSpec { seed, events, recovery })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FaultSpec {
        FaultSpec {
            seed: 42,
            events: vec![
                FaultEvent::ShardCrash { shard: 1, at_ns: 5e6 },
                FaultEvent::Brownout { shard: 0, start_ns: 1e6, end_ns: 3e6, slowdown: 2.0 },
                FaultEvent::LinkOutage { start_ns: 2e6, end_ns: 4e6 },
                FaultEvent::LinkDegrade { start_ns: 6e6, end_ns: 9e6, factor: 0.5 },
                FaultEvent::ChannelLoss { group: "decode".into(), at_ns: 7e6, channels_lost: 1 },
            ],
            recovery: RecoveryPolicy { retry_budget: 3, ..RecoveryPolicy::default() },
        }
    }

    #[test]
    fn default_spec_is_empty_and_valid() {
        let spec = FaultSpec::default();
        assert!(spec.is_empty());
        spec.validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let spec = sample();
        spec.validate().unwrap();
        let back = FaultSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let spec = FaultSpec::from_json("{}").unwrap();
        assert_eq!(spec, FaultSpec::default());
        let spec = FaultSpec::from_json(r#"{"recovery": {"retry_budget": 5}}"#).unwrap();
        assert_eq!(spec.recovery.retry_budget, 5);
        assert_eq!(spec.recovery.backoff_base_ns, DEFAULT_BACKOFF_BASE_NS);
    }

    #[test]
    fn validation_rejects_bad_windows_and_factors() {
        let bad = |ev: FaultEvent| {
            FaultSpec { events: vec![ev], ..FaultSpec::default() }.validate().is_err()
        };
        assert!(bad(FaultEvent::Brownout { shard: 0, start_ns: 3.0, end_ns: 1.0, slowdown: 2.0 }));
        assert!(bad(FaultEvent::Brownout { shard: 0, start_ns: 0.0, end_ns: 1.0, slowdown: 0.5 }));
        assert!(bad(FaultEvent::LinkOutage { start_ns: -1.0, end_ns: 1.0 }));
        assert!(bad(FaultEvent::LinkDegrade { start_ns: 0.0, end_ns: 1.0, factor: 0.0 }));
        assert!(bad(FaultEvent::LinkDegrade { start_ns: 0.0, end_ns: 1.0, factor: 1.5 }));
        assert!(bad(FaultEvent::ChannelLoss { group: "g".into(), at_ns: 0.0, channels_lost: 0 }));
    }

    #[test]
    fn validation_rejects_duplicate_crashes_and_losses() {
        let spec = FaultSpec {
            events: vec![
                FaultEvent::ShardCrash { shard: 1, at_ns: 1.0 },
                FaultEvent::ShardCrash { shard: 1, at_ns: 2.0 },
            ],
            ..FaultSpec::default()
        };
        assert!(spec.validate().is_err());
        let spec = FaultSpec {
            events: vec![
                FaultEvent::ChannelLoss { group: "g".into(), at_ns: 1.0, channels_lost: 1 },
                FaultEvent::ChannelLoss { group: "g".into(), at_ns: 2.0, channels_lost: 1 },
            ],
            ..FaultSpec::default()
        };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_recovery() {
        let mut spec = FaultSpec::default();
        spec.recovery.backoff_cap_ns = 0.0;
        assert!(spec.validate().is_err());
        spec = FaultSpec::default();
        spec.recovery.utilization_ceiling = 1.5;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let r = RecoveryPolicy::default();
        assert_eq!(r.backoff_ns(1), DEFAULT_BACKOFF_BASE_NS);
        assert_eq!(r.backoff_ns(2), 2.0 * DEFAULT_BACKOFF_BASE_NS);
        assert_eq!(r.backoff_ns(3), 4.0 * DEFAULT_BACKOFF_BASE_NS);
        assert_eq!(r.backoff_ns(10), DEFAULT_BACKOFF_CAP_NS);
        // No overflow at absurd attempt counts.
        assert_eq!(r.backoff_ns(u32::MAX), DEFAULT_BACKOFF_CAP_NS);
    }

    #[test]
    fn unknown_kind_errors() {
        assert!(FaultSpec::from_json(r#"{"events": [{"kind": "meteor"}]}"#).is_err());
    }
}
