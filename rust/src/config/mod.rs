//! Hardware and workload configuration (paper Table 2, Table 3, Table 4).
//!
//! Everything the simulator, mapping framework, and area model consume is
//! parameterized here.  Configurations are plain structs loadable from JSON
//! (`racam --config cfg.json ...`, via the in-tree [`json`] module) or built
//! from the presets in [`presets`].

mod cluster;
mod dram;
mod faults;
pub mod json;
mod periph;
mod presets;
mod serving;
mod timing;
mod traffic;
mod workload;

pub use cluster::{
    ClusterSpec, SchedulerKind, ShardGroup, ShardRole, DEFAULT_KV_LINK_GBPS,
};
pub use dram::DramConfig;
pub use faults::{
    FaultEvent, FaultSpec, RecoveryPolicy, DEFAULT_BACKOFF_BASE_NS, DEFAULT_BACKOFF_CAP_NS,
    DEFAULT_RETRY_BUDGET,
};
pub use periph::PeriphConfig;
pub use presets::*;
pub use serving::{EngineKind, HostExecutor, ServingPolicy, DEFAULT_PREFILL_CHUNK};
pub use timing::TimingParams;
pub use traffic::{ArrivalProcess, LengthDist, TrafficSpec};
pub use workload::{LlmSpec, MatmulShape, Precision, Scenario, Stage};


/// Feature toggles for the three RACAM enhancements, used by the ablation
/// study (paper Fig. 12 / Fig. 17).  All `true` is the complete design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Features {
    /// Locality buffers: O(n) row accesses per n-bit multiply instead of O(n²).
    pub locality_buffer: bool,
    /// Popcount reduction units: in-bank cross-column reduction.
    pub popcount_reduction: bool,
    /// Broadcast units: in-DRAM replication of dynamic operands.
    pub broadcast_unit: bool,
}

impl Features {
    pub const ALL: Features = Features {
        locality_buffer: true,
        popcount_reduction: true,
        broadcast_unit: true,
    };
    /// Paper Fig. 12 ablation points, in the order the figure presents them.
    pub const NO_PR: Features = Features { popcount_reduction: false, ..Features::ALL };
    pub const NO_PR_BU: Features =
        Features { popcount_reduction: false, broadcast_unit: false, ..Features::ALL };
    pub const NO_PR_BU_LB: Features = Features {
        locality_buffer: false,
        popcount_reduction: false,
        broadcast_unit: false,
    };

    pub fn label(&self) -> String {
        match (self.popcount_reduction, self.broadcast_unit, self.locality_buffer) {
            (true, true, true) => "Complete".into(),
            (false, true, true) => "-PR".into(),
            (false, false, true) => "-PR-BU".into(),
            (false, false, false) => "-PR-BU-LB".into(),
            (p, b, l) => format!("PR={p},BU={b},LB={l}"),
        }
    }
}

impl Default for Features {
    fn default() -> Self {
        Features::ALL
    }
}

/// Complete RACAM hardware configuration (paper Table 2).
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    pub dram: DramConfig,
    pub periph: PeriphConfig,
    pub timing: TimingParams,
    pub features: Features,
}

impl HwConfig {
    /// Total number of PEs across the whole memory system.
    pub fn total_pes(&self) -> u64 {
        self.dram.total_banks() * self.periph.pes_per_bank as u64
    }

    /// Total storage capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.dram.capacity_bits() / 8
    }

    /// Steady-state latency of one SIMD multiply pass at `prec` with the
    /// locality buffer: the maximum of the PE serial-add pipeline (n²+4
    /// cycles) and the 4n-row operand/result stream (§3.3); the row stream
    /// dominates at the calibrated clocks, giving near-linear precision
    /// scaling (Fig. 1 / Fig. 14).
    pub fn mul_pass_ns(&self, prec: Precision) -> f64 {
        let n = prec.bits() as f64;
        let pe_ns = (n * n + 4.0) * 1e9 / self.timing.pe_freq_hz;
        let row_ns = 4.0 * n * self.timing.t_cas_ns;
        pe_ns.max(row_ns)
    }

    /// Peak int-`n` multiply-accumulate throughput in MAC/s of the full
    /// system with locality buffers (calibration anchor: int8 ⇒ 986.9 TOPS,
    /// paper Table 4, counting 1 MAC = 2 ops).
    pub fn peak_macs(&self, prec: Precision) -> f64 {
        self.total_pes() as f64 / (self.mul_pass_ns(prec) * 1e-9)
    }

    pub fn peak_tops(&self, prec: Precision) -> f64 {
        2.0 * self.peak_macs(prec) / 1e12
    }

    /// Validate internal consistency; returns a human-readable error list.
    pub fn validate(&self) -> std::result::Result<(), Vec<String>> {
        let mut errs = Vec::new();
        if self.dram.cols % self.periph.pes_per_bank != 0 {
            errs.push(format!(
                "subarray columns ({}) must be a multiple of PEs per bank ({})",
                self.dram.cols, self.periph.pes_per_bank
            ));
        }
        if self.periph.locality_buffer_rows < 17 && self.features.locality_buffer {
            errs.push(format!(
                "locality buffer has {} rows; 17 are required for full reuse of int8 multiplies (2n+1)",
                self.periph.locality_buffer_rows
            ));
        }
        if self.periph.locality_buffer_cols != self.periph.pes_per_bank {
            errs.push("locality buffer width must match PE count (one PE per buffer column)".into());
        }
        for (name, v) in [
            ("channels", self.dram.channels),
            ("ranks", self.dram.ranks),
            ("devices", self.dram.devices),
            ("banks", self.dram.banks),
            ("subarrays", self.dram.subarrays),
            ("rows", self.dram.rows),
            ("cols", self.dram.cols),
        ] {
            if v == 0 {
                errs.push(format!("DRAM {name} must be non-zero"));
            }
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }

    pub fn from_json(s: &str) -> crate::Result<Self> {
        let v = json::parse(s).map_err(anyhow::Error::from)?;
        Self::from_value(&v).map_err(anyhow::Error::from)
    }

    pub fn to_json(&self) -> String {
        self.to_value().pretty()
    }

    fn to_value(&self) -> json::Value {
        use json::Value as V;
        let d = &self.dram;
        let p = &self.periph;
        let t = &self.timing;
        let f = &self.features;
        V::obj(vec![
            (
                "dram",
                V::obj(vec![
                    ("channels", V::Num(d.channels as f64)),
                    ("ranks", V::Num(d.ranks as f64)),
                    ("devices", V::Num(d.devices as f64)),
                    ("banks", V::Num(d.banks as f64)),
                    ("subarrays", V::Num(d.subarrays as f64)),
                    ("rows", V::Num(d.rows as f64)),
                    ("cols", V::Num(d.cols as f64)),
                    ("device_width_bits", V::Num(d.device_width_bits as f64)),
                    ("mts", V::Num(d.mts as f64)),
                    ("global_bitline_bits", V::Num(d.global_bitline_bits as f64)),
                ]),
            ),
            (
                "periph",
                V::obj(vec![
                    ("pes_per_bank", V::Num(p.pes_per_bank as f64)),
                    ("locality_buffer_rows", V::Num(p.locality_buffer_rows as f64)),
                    ("locality_buffer_cols", V::Num(p.locality_buffer_cols as f64)),
                    ("popcount_width", V::Num(p.popcount_width as f64)),
                    ("accumulator_bits", V::Num(p.accumulator_bits as f64)),
                    ("bank_broadcast_bits", V::Num(p.bank_broadcast_bits as f64)),
                    ("col_broadcast_fanout", V::Num(p.col_broadcast_fanout as f64)),
                ]),
            ),
            (
                "timing",
                V::obj(vec![
                    ("t_rcd_ns", V::Num(t.t_rcd_ns)),
                    ("t_rp_ns", V::Num(t.t_rp_ns)),
                    ("t_ras_ns", V::Num(t.t_ras_ns)),
                    ("t_cas_ns", V::Num(t.t_cas_ns)),
                    ("pe_freq_hz", V::Num(t.pe_freq_hz)),
                    ("lb_access_cycles", V::Num(t.lb_access_cycles as f64)),
                    ("popcount_cycles", V::Num(t.popcount_cycles as f64)),
                    ("parallel_add_cycles", V::Num(t.parallel_add_cycles as f64)),
                    ("host_add_ns", V::Num(t.host_add_ns)),
                    ("channel_efficiency", V::Num(t.channel_efficiency)),
                ]),
            ),
            (
                "features",
                V::obj(vec![
                    ("locality_buffer", V::Bool(f.locality_buffer)),
                    ("popcount_reduction", V::Bool(f.popcount_reduction)),
                    ("broadcast_unit", V::Bool(f.broadcast_unit)),
                ]),
            ),
        ])
    }

    fn from_value(v: &json::Value) -> Result<Self, json::JsonError> {
        let d = v.get("dram")?;
        let p = v.get("periph")?;
        let t = v.get("timing")?;
        let f = v.get("features")?;
        Ok(HwConfig {
            dram: DramConfig {
                channels: d.get("channels")?.as_u32()?,
                ranks: d.get("ranks")?.as_u32()?,
                devices: d.get("devices")?.as_u32()?,
                banks: d.get("banks")?.as_u32()?,
                subarrays: d.get("subarrays")?.as_u32()?,
                rows: d.get("rows")?.as_u32()?,
                cols: d.get("cols")?.as_u32()?,
                device_width_bits: d.get("device_width_bits")?.as_u32()?,
                mts: d.get("mts")?.as_u32()?,
                global_bitline_bits: d.get("global_bitline_bits")?.as_u32()?,
            },
            periph: PeriphConfig {
                pes_per_bank: p.get("pes_per_bank")?.as_u32()?,
                locality_buffer_rows: p.get("locality_buffer_rows")?.as_u32()?,
                locality_buffer_cols: p.get("locality_buffer_cols")?.as_u32()?,
                popcount_width: p.get("popcount_width")?.as_u32()?,
                accumulator_bits: p.get("accumulator_bits")?.as_u32()?,
                bank_broadcast_bits: p.get("bank_broadcast_bits")?.as_u32()?,
                col_broadcast_fanout: p.get("col_broadcast_fanout")?.as_u32()?,
            },
            timing: TimingParams {
                t_rcd_ns: t.get("t_rcd_ns")?.as_f64()?,
                t_rp_ns: t.get("t_rp_ns")?.as_f64()?,
                t_ras_ns: t.get("t_ras_ns")?.as_f64()?,
                t_cas_ns: t.get("t_cas_ns")?.as_f64()?,
                pe_freq_hz: t.get("pe_freq_hz")?.as_f64()?,
                lb_access_cycles: t.get("lb_access_cycles")?.as_u32()?,
                popcount_cycles: t.get("popcount_cycles")?.as_u32()?,
                parallel_add_cycles: t.get("parallel_add_cycles")?.as_u32()?,
                host_add_ns: t.get("host_add_ns")?.as_f64()?,
                channel_efficiency: t.get("channel_efficiency")?.as_f64()?,
            },
            features: Features {
                locality_buffer: f.get("locality_buffer")?.as_bool()?,
                popcount_reduction: f.get("popcount_reduction")?.as_bool()?,
                broadcast_unit: f.get("broadcast_unit")?.as_bool()?,
            },
        })
    }
}

impl Default for HwConfig {
    fn default() -> Self {
        presets::racam_paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_valid() {
        racam_paper().validate().unwrap();
    }

    #[test]
    fn paper_capacity_is_1024_gib() {
        let hw = racam_paper();
        assert_eq!(hw.capacity_bytes(), 1024 * (1u64 << 30));
    }

    #[test]
    fn paper_int8_tops_matches_table4() {
        // Table 4 reports 986.9 int8 TOPS for the RACAM system.
        let hw = racam_paper();
        let tops = hw.peak_tops(Precision::Int8);
        assert!((tops - 986.9).abs() < 1.0, "got {tops}");
    }

    #[test]
    fn json_roundtrip() {
        let hw = racam_paper();
        let s = hw.to_json();
        let back = HwConfig::from_json(&s).unwrap();
        assert_eq!(hw, back);
    }

    #[test]
    fn validation_catches_zero_dims() {
        let mut hw = racam_paper();
        hw.dram.banks = 0;
        assert!(hw.validate().is_err());
    }

    #[test]
    fn validation_catches_short_locality_buffer() {
        let mut hw = racam_paper();
        hw.periph.locality_buffer_rows = 9;
        let errs = hw.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.contains("17")));
    }

    #[test]
    fn feature_labels() {
        assert_eq!(Features::ALL.label(), "Complete");
        assert_eq!(Features::NO_PR.label(), "-PR");
        assert_eq!(Features::NO_PR_BU.label(), "-PR-BU");
        assert_eq!(Features::NO_PR_BU_LB.label(), "-PR-BU-LB");
    }
}
