//! Timing parameters (paper Table 2, validated against DDR5-5200 spec
//! sheets / Ramulator in the paper's methodology §5.1).


/// All timing knobs of the analytical hardware model.
///
/// Row timings follow JEDEC DDR5-5200B speed bin; peripheral latencies come
/// from the paper's Design Compiler synthesis (we encode the resulting
/// cycle-level numbers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingParams {
    /// Row-to-column delay (ACT → READ), nanoseconds.
    pub t_rcd_ns: f64,
    /// Row precharge time, nanoseconds.
    pub t_rp_ns: f64,
    /// Restoration / RAS time (ACT → PRE), nanoseconds.
    pub t_ras_ns: f64,
    /// Column access strobe latency, nanoseconds.
    pub t_cas_ns: f64,
    /// PE / locality-buffer clock frequency, Hz (§5.1 synthesis).
    pub pe_freq_hz: f64,
    /// Locality buffer access latency, PE cycles.
    pub lb_access_cycles: u32,
    /// Popcount reduction latency per bit-slice, PE cycles.
    pub popcount_cycles: u32,
    /// Bit-parallel add (pim_add_parallel) latency, PE cycles.
    pub parallel_add_cycles: u32,
    /// Host-side reduction cost per element, ns — the *amortized* cost of a
    /// SIMD/streaming int32 add on the host CPU (≈16 adds/ns at AVX-class
    /// throughput), used when partial outputs must be reduced host-side.
    pub host_add_ns: f64,
    /// Effective fraction of peak channel bandwidth achieved for bulk
    /// host↔DRAM transfers (command overheads, refresh, turnaround).
    pub channel_efficiency: f64,
}

impl TimingParams {
    /// Full row cycle (ACT → PRE → ready) in nanoseconds.
    pub fn t_rc_ns(&self) -> f64 {
        self.t_ras_ns + self.t_rp_ns
    }

    /// One PE cycle in nanoseconds.
    pub fn pe_cycle_ns(&self) -> f64 {
        1e9 / self.pe_freq_hz
    }

    /// Latency of an overlapped (SALP-MASA) stream of `n` row accesses in
    /// nanoseconds: successive activations to *different* subarrays overlap,
    /// so the stream is pipelined at the global-bitline transfer rate and
    /// only the first access pays full tRCD (paper §3.3).
    pub fn salp_stream_ns(&self, n_rows: u64) -> f64 {
        if n_rows == 0 {
            return 0.0;
        }
        self.t_rcd_ns + n_rows as f64 * self.t_cas_ns
    }

    /// Latency of `n` *non-overlapped* row accesses (same subarray, or SALP
    /// unavailable): every access pays a full ACT–PRE cycle.
    pub fn serial_rows_ns(&self, n_rows: u64) -> f64 {
        n_rows as f64 * (self.t_rcd_ns + self.t_rc_ns())
    }
}

impl Default for TimingParams {
    fn default() -> Self {
        crate::config::racam_paper().timing
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> TimingParams {
        TimingParams::default()
    }

    #[test]
    fn row_cycle_is_ras_plus_rp() {
        let t = t();
        assert!((t.t_rc_ns() - (t.t_ras_ns + t.t_rp_ns)).abs() < 1e-9);
    }

    #[test]
    fn salp_stream_beats_serial() {
        let t = t();
        for n in [1u64, 4, 16, 64, 256] {
            assert!(t.salp_stream_ns(n) < t.serial_rows_ns(n), "n={n}");
        }
    }

    #[test]
    fn salp_zero_rows_is_free() {
        assert_eq!(t().salp_stream_ns(0), 0.0);
    }

    #[test]
    fn pe_cycle_matches_2ghz_synthesis() {
        assert!((t().pe_cycle_ns() - 0.5).abs() < 1e-9);
    }
}
