//! Built-in configurations matching the paper's Tables 3 and 4.

use super::{DramConfig, Features, HwConfig, LlmSpec, PeriphConfig, Precision, TimingParams};

/// The RACAM system of paper Table 4: 1024 GB DDR5, 8 channels, 32 ranks,
/// 8 × x16 devices, 16 banks, 128 subarrays of 128 × 16K, 1024 PEs/bank and
/// a 17×1024 locality buffer.
pub fn racam_paper() -> HwConfig {
    HwConfig {
        dram: DramConfig {
            channels: 8,
            ranks: 32,
            devices: 8,
            banks: 16,
            subarrays: 128,
            rows: 128,
            cols: 16 * 1024,
            device_width_bits: 16,
            mts: 5200,
            global_bitline_bits: 1024,
        },
        periph: PeriphConfig {
            pes_per_bank: 1024,
            locality_buffer_rows: 17,
            locality_buffer_cols: 1024,
            popcount_width: 1024,
            accumulator_bits: 32,
            bank_broadcast_bits: 64,
            col_broadcast_fanout: 64,
        },
        timing: ddr5_5200_timing(),
        features: Features::ALL,
    }
}

/// JEDEC DDR5-5200B row timings + synthesized peripheral latencies (§5.1).
pub fn ddr5_5200_timing() -> TimingParams {
    TimingParams {
        t_rcd_ns: 16.0,
        t_rp_ns: 16.0,
        t_ras_ns: 32.0,
        // One global-bitline beat per streamed row under SALP.  Calibrated
        // so an int8 multiply pass (4n = 32 beats) takes 68 ns, which makes
        // the whole system hit Table 4's 986.9 int8 TOPS exactly.
        t_cas_ns: 2.125,
        // Synthesized PE/buffer logic clocks ~2 GHz — fast enough that the
        // n²-cycle serial adds hide behind the 4n-beat row stream, giving
        // the near-linear precision scaling of Figs. 1/14.
        pe_freq_hz: 2e9,
        lb_access_cycles: 1,
        popcount_cycles: 2,
        parallel_add_cycles: 4,
        host_add_ns: 1.0 / 16.0,
        channel_efficiency: 0.85,
    }
}

/// A deliberately small configuration for fast functional tests and the
/// quickstart example: 1 channel / 1 rank / 1 device / 2 banks, 4 subarrays
/// of 64 × 512, 128 PEs per bank.
pub fn racam_tiny() -> HwConfig {
    HwConfig {
        dram: DramConfig {
            channels: 1,
            ranks: 1,
            devices: 1,
            banks: 2,
            subarrays: 4,
            rows: 64,
            cols: 512,
            device_width_bits: 16,
            mts: 5200,
            global_bitline_bits: 128,
        },
        periph: PeriphConfig {
            pes_per_bank: 128,
            locality_buffer_rows: 17,
            locality_buffer_cols: 128,
            popcount_width: 128,
            accumulator_bits: 32,
            bank_broadcast_bits: 64,
            col_broadcast_fanout: 16,
        },
        timing: ddr5_5200_timing(),
        features: Features::ALL,
    }
}

/// Scale channel/rank counts down by `factor` (the paper's Fig. 13 PE-count
/// sensitivity reduces channels and ranks to hit 1/4, 1/16, 1/64 capacity).
pub fn scale_capacity(hw: &HwConfig, factor: u32) -> HwConfig {
    let mut hw = hw.clone();
    let mut remaining = factor;
    // Halve ranks first, then channels, preserving at least 1 of each.
    while remaining > 1 {
        if hw.dram.ranks > 1 {
            hw.dram.ranks /= 2;
        } else if hw.dram.channels > 1 {
            hw.dram.channels /= 2;
        } else {
            break;
        }
        remaining /= 2;
    }
    hw
}

/// Partition the DRAM channels of `hw` across `shards` worker shards so
/// each shard's simulated clock reflects only its own share of the memory
/// bandwidth (the multi-worker coordinator's honest-capacity split).
///
/// Channels divide as evenly as possible, remainder going to the
/// lowest-indexed shards, so the aggregate capacity/bandwidth across all
/// shards equals the original config exactly.  Returns `None` when there
/// are more shards than channels (no non-empty partition exists); callers
/// fall back to sharing the full config.
pub fn partition_channels(hw: &HwConfig, shards: usize) -> Option<Vec<HwConfig>> {
    assert!(shards >= 1, "cannot partition across zero shards");
    let channels = hw.dram.channels as usize;
    if shards > channels {
        return None;
    }
    let base = channels / shards;
    let rem = channels % shards;
    Some(
        (0..shards)
            .map(|i| {
                let mut part = hw.clone();
                part.dram.channels = (base + usize::from(i < rem)) as u32;
                part
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// LLM presets (paper Table 3)
// ---------------------------------------------------------------------------

pub fn gpt3_6_7b() -> LlmSpec {
    LlmSpec {
        name: "GPT-3 6.7B".into(),
        layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 32,
        ffn: 4 * 4096,
        gated_ffn: false,
        vocab: 50257,
        prec: Precision::Int8,
    }
}

pub fn gpt3_175b() -> LlmSpec {
    LlmSpec {
        name: "GPT-3 175B".into(),
        layers: 96,
        hidden: 12288,
        heads: 96,
        kv_heads: 96,
        ffn: 4 * 12288,
        gated_ffn: false,
        vocab: 50257,
        prec: Precision::Int8,
    }
}

pub fn llama3_8b() -> LlmSpec {
    LlmSpec {
        name: "Llama-3 8B".into(),
        layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 8,
        ffn: 14336,
        gated_ffn: true,
        vocab: 128256,
        prec: Precision::Int8,
    }
}

pub fn llama3_70b() -> LlmSpec {
    LlmSpec {
        name: "Llama-3 70B".into(),
        layers: 80,
        hidden: 8192,
        heads: 64,
        kv_heads: 8,
        ffn: 28672,
        gated_ffn: true,
        vocab: 128256,
        prec: Precision::Int8,
    }
}

/// The four models of Table 3, in the paper's order.
pub fn paper_models() -> Vec<LlmSpec> {
    vec![gpt3_6_7b(), gpt3_175b(), llama3_8b(), llama3_70b()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_config_valid() {
        racam_tiny().validate().unwrap();
    }

    #[test]
    fn capacity_scaling() {
        let hw = racam_paper();
        let quarter = scale_capacity(&hw, 4);
        assert_eq!(quarter.total_pes(), hw.total_pes() / 4);
        let sixty_fourth = scale_capacity(&hw, 64);
        assert_eq!(sixty_fourth.total_pes(), hw.total_pes() / 64);
        sixty_fourth.validate().unwrap();
    }

    #[test]
    fn scaling_never_hits_zero() {
        let hw = racam_tiny();
        let s = scale_capacity(&hw, 1024);
        assert!(s.dram.channels >= 1 && s.dram.ranks >= 1);
    }

    #[test]
    fn four_paper_models() {
        assert_eq!(paper_models().len(), 4);
    }

    #[test]
    fn channel_partition_conserves_aggregate_capacity() {
        // Satellite acceptance: N-shard aggregate capacity == 1-shard capacity.
        let hw = racam_paper();
        for shards in [1usize, 2, 3, 5, 8] {
            let parts = partition_channels(&hw, shards).unwrap();
            assert_eq!(parts.len(), shards);
            let agg_capacity: u64 = parts.iter().map(|p| p.capacity_bytes()).sum();
            assert_eq!(agg_capacity, hw.capacity_bytes(), "{shards} shards");
            let agg_bw: f64 = parts.iter().map(|p| p.dram.total_bw_bytes()).sum();
            assert!((agg_bw - hw.dram.total_bw_bytes()).abs() < 1.0, "{shards} shards");
            let agg_pes: u64 = parts.iter().map(|p| p.total_pes()).sum();
            assert_eq!(agg_pes, hw.total_pes());
            for p in &parts {
                p.validate().unwrap();
            }
        }
    }

    #[test]
    fn channel_partition_gives_remainder_to_low_shards() {
        let parts = partition_channels(&racam_paper(), 3).unwrap();
        let counts: Vec<u32> = parts.iter().map(|p| p.dram.channels).collect();
        assert_eq!(counts, vec![3, 3, 2]);
    }

    #[test]
    fn channel_partition_refuses_oversubscription() {
        // More shards than channels: no honest partition exists.
        assert!(partition_channels(&racam_tiny(), 2).is_none());
        assert!(partition_channels(&racam_paper(), 9).is_none());
    }
}
