//! Declarative serving-cluster specification: named shard *groups*, each
//! with a count, a [`ShardRole`], an admission [`SchedulerKind`], a
//! [`ServingPolicy`], and an optional DRAM-channel share — the single
//! entry point `coordinator::ClusterBuilder` consumes to assemble a
//! role-aware multi-shard coordinator.
//!
//! A [`ClusterSpec`] replaces the old constructor sprawl
//! (`Coordinator::new` / `with_service` / `with_schedulers` /
//! `with_shard_services` plus post-hoc `set_policy`) with one JSON-loadable
//! description:
//!
//! ```json
//! {
//!   "kv_link_gbps": 64,
//!   "mapping_store": "results/mapping_store.json",
//!   "groups": [
//!     {"name": "prefill", "count": 2, "role": "prefill", "scheduler": "fcfs",
//!      "max_batch": 4, "channels": 4,
//!      "policy": {"prefill_chunk_tokens": 256, "preempt": false}},
//!     {"name": "decode", "count": 2, "role": "decode", "scheduler": "fcfs",
//!      "max_batch": 8, "channels": 4, "policy": {}}
//!   ]
//! }
//! ```
//!
//! Roles implement prefill/decode **disaggregation** (the Sangam-style
//! split RACAM's channel-partitioned parallelism makes natural): `Prefill`
//! shards run prompts only and hand finished requests to `Decode` shards
//! over a simulated KV-transfer link of `kv_link_gbps` GB/s — one shared
//! link: transfers serialize FIFO in prefill-finish order, so concurrent
//! finishes queue rather than multiplying the bandwidth; `Unified`
//! shards do both (today's behavior — a `Unified`-only spec reproduces the
//! pre-redesign coordinator bit-for-bit).  Validation is two-stage:
//! [`ClusterSpec::validate`] checks everything hardware-independent (roles
//! must be balanced, counts non-zero, policies legal), and the builder
//! additionally checks channel shares against the concrete device (shares
//! must sum exactly to the device's channels).

use super::json::{self, JsonError, Value};
use super::ServingPolicy;

/// Default KV-transfer link bandwidth between prefill and decode shards,
/// GB/s.  64 GB/s is a CXL-class inter-stack link — the integration point
/// chiplet DRAM-PIM designs (Sangam) assume; note 1 GB/s ≡ 1 byte/ns, so
/// transfer nanoseconds are simply `bytes / gbps`.
pub const DEFAULT_KV_LINK_GBPS: f64 = 64.0;

/// What lifecycle stages a shard group serves.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardRole {
    /// Prefill + decode on one shard (the pre-disaggregation behavior).
    #[default]
    Unified,
    /// Prompt processing only: finished prefills are handed to a decode
    /// shard through the cluster's KV-transfer link.
    Prefill,
    /// Token generation only: receives prefilled requests (with their KV
    /// cache) from prefill shards; never admits a fresh prompt.
    Decode,
}

impl ShardRole {
    pub fn label(&self) -> &'static str {
        match self {
            ShardRole::Unified => "unified",
            ShardRole::Prefill => "prefill",
            ShardRole::Decode => "decode",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "unified" => Some(ShardRole::Unified),
            "prefill" => Some(ShardRole::Prefill),
            "decode" => Some(ShardRole::Decode),
            _ => None,
        }
    }

    /// Whether a shard of this role may be handed a *fresh* prompt by the
    /// coordinator's dispatch (decode-only shards may not — they receive
    /// work exclusively through the KV-transfer handoff).
    pub fn accepts_fresh_prompts(&self) -> bool {
        !matches!(self, ShardRole::Decode)
    }
}

/// The admission-scheduler roster, by name (the same roster `racam serve
/// --sched` exposes).  `coordinator::ClusterBuilder` turns a kind into a
/// boxed [`Scheduler`](crate::coordinator::Scheduler) per shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    /// First-come-first-served (the paper setting).
    #[default]
    Fcfs,
    /// Prompt-length-bucketed admission.
    Bucketed,
    /// Earliest-deadline-first admission (+ deadline shedding under a
    /// preemption-enabled policy).
    Edf,
}

impl SchedulerKind {
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Fcfs => "fcfs",
            SchedulerKind::Bucketed => "bucketed",
            SchedulerKind::Edf => "edf",
        }
    }

    pub fn from_label(s: &str) -> Option<Self> {
        match s {
            "fcfs" => Some(SchedulerKind::Fcfs),
            "bucket" | "bucketed" => Some(SchedulerKind::Bucketed),
            "edf" => Some(SchedulerKind::Edf),
            _ => None,
        }
    }
}

/// One named group of identically configured shards.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardGroup {
    /// Group label, surfaced in per-group utilization reporting.
    pub name: String,
    /// Number of shards in the group (must be ≥ 1).
    pub count: usize,
    pub role: ShardRole,
    pub scheduler: SchedulerKind,
    /// Max concurrent batch per shard.
    pub max_batch: usize,
    /// Serving policy applied to every shard of the group.
    pub policy: ServingPolicy,
    /// Optional DRAM-channel share for the whole group (split across its
    /// `count` shards).  Either every group sets a share (and they must sum
    /// to the device's channels) or none does (channels are partitioned
    /// evenly across all shards, the legacy behavior).
    pub channels: Option<u32>,
}

impl ShardGroup {
    /// A unified FCFS group with the default (paper-faithful) policy.
    pub fn unified(name: &str, count: usize, max_batch: usize) -> Self {
        ShardGroup {
            name: name.into(),
            count,
            role: ShardRole::Unified,
            scheduler: SchedulerKind::Fcfs,
            max_batch,
            policy: ServingPolicy::default(),
            channels: None,
        }
    }

    /// Builder-style role override.
    pub fn with_role(mut self, role: ShardRole) -> Self {
        self.role = role;
        self
    }

    /// Builder-style scheduler override.
    pub fn with_scheduler(mut self, kind: SchedulerKind) -> Self {
        self.scheduler = kind;
        self
    }

    /// Builder-style policy override.
    pub fn with_policy(mut self, policy: ServingPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Builder-style channel-share override.
    pub fn with_channels(mut self, channels: u32) -> Self {
        self.channels = Some(channels);
        self
    }
}

/// A complete serving-cluster description (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub groups: Vec<ShardGroup>,
    /// KV-transfer link bandwidth between prefill and decode shards, GB/s.
    pub kv_link_gbps: f64,
    /// Optional persistent mapping-table path (the warm store): every
    /// mapping service the builder creates loads it at construction and
    /// merges its cache back on drop, so repeated runs — and concurrent
    /// processes sharing the file — never re-search a kernel shape.
    /// Entries are keyed by shape + channel count, so one file safely
    /// serves heterogeneous channel partitions.
    pub mapping_store: Option<String>,
}

impl ClusterSpec {
    /// The legacy shape: one `Unified` FCFS group of `n_shards` shards with
    /// the default policy — builds a coordinator identical to what
    /// `Coordinator::new(hw, spec, n_shards, max_batch, ..)` produced.
    pub fn unified(n_shards: usize, max_batch: usize) -> Self {
        ClusterSpec {
            groups: vec![ShardGroup::unified("unified", n_shards, max_batch)],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        }
    }

    /// A prefill/decode-disaggregated cluster: `prefill` prompt shards
    /// feeding `decode` generation shards over the default KV link, both
    /// FCFS with the default policy.  Channel shares are left automatic.
    pub fn disaggregated(prefill: usize, decode: usize, max_batch: usize) -> Self {
        ClusterSpec {
            groups: vec![
                ShardGroup::unified("prefill", prefill, max_batch).with_role(ShardRole::Prefill),
                ShardGroup::unified("decode", decode, max_batch).with_role(ShardRole::Decode),
            ],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        }
    }

    /// Builder-style KV-link override (GB/s).
    pub fn with_kv_link_gbps(mut self, gbps: f64) -> Self {
        self.kv_link_gbps = gbps;
        self
    }

    /// Builder-style warm-store override (see [`ClusterSpec::mapping_store`]).
    pub fn with_mapping_store(mut self, path: &str) -> Self {
        self.mapping_store = Some(path.to_string());
        self
    }

    /// Total shards across all groups.
    pub fn total_shards(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Whether any group is role-split (a `Prefill` or `Decode` group).
    pub fn is_disaggregated(&self) -> bool {
        self.groups.iter().any(|g| g.role != ShardRole::Unified)
    }

    /// Hardware-independent validation (the builder additionally checks
    /// channel shares against the concrete device).
    pub fn validate(&self) -> Result<(), String> {
        if self.groups.is_empty() {
            return Err("a cluster needs at least one shard group".into());
        }
        for g in &self.groups {
            if g.count == 0 {
                return Err(format!("group '{}' has zero shards", g.name));
            }
            if g.max_batch == 0 {
                return Err(format!("group '{}': max_batch must be at least 1", g.name));
            }
            g.policy.validate().map_err(|e| format!("group '{}': {e}", g.name))?;
            if let Some(ch) = g.channels {
                if (ch as usize) < g.count {
                    return Err(format!(
                        "group '{}': {ch} channel(s) cannot cover {} shard(s)",
                        g.name, g.count
                    ));
                }
            }
        }
        for (i, g) in self.groups.iter().enumerate() {
            if self.groups[..i].iter().any(|o| o.name == g.name) {
                return Err(format!("duplicate group name '{}'", g.name));
            }
        }
        // Roles must be balanced: a prefill group's handoffs need a decode
        // group to land on, and a decode group starves without a feeder.
        let prefill = self.groups.iter().any(|g| g.role == ShardRole::Prefill);
        let decode = self.groups.iter().any(|g| g.role == ShardRole::Decode);
        match (prefill, decode) {
            (true, false) => {
                return Err("unbalanced roles: prefill group(s) without a decode group".into())
            }
            (false, true) => {
                return Err("unbalanced roles: decode group(s) without a prefill group".into())
            }
            _ => {}
        }
        // Channel shares are all-or-none; the builder checks the sum
        // against the device.
        let with = self.groups.iter().filter(|g| g.channels.is_some()).count();
        if with != 0 && with != self.groups.len() {
            return Err(
                "either every group sets a channel share or none does (mixed shares)".into()
            );
        }
        if !(self.kv_link_gbps.is_finite() && self.kv_link_gbps > 0.0) {
            return Err(format!(
                "kv_link_gbps must be positive and finite, got {}",
                self.kv_link_gbps
            ));
        }
        Ok(())
    }

    pub fn from_json(s: &str) -> crate::Result<Self> {
        let v = json::parse(s).map_err(anyhow::Error::from)?;
        let spec = Self::from_value(&v).map_err(anyhow::Error::from)?;
        spec.validate().map_err(|e| anyhow::anyhow!("invalid cluster spec: {e}"))?;
        Ok(spec)
    }

    pub fn to_json(&self) -> String {
        self.to_value().pretty()
    }

    fn group_to_value(g: &ShardGroup) -> Value {
        let mut pairs = vec![
            ("name", Value::Str(g.name.clone())),
            ("count", Value::Num(g.count as f64)),
            ("role", Value::Str(g.role.label().into())),
            ("scheduler", Value::Str(g.scheduler.label().into())),
            ("max_batch", Value::Num(g.max_batch as f64)),
            ("policy", g.policy.to_value()),
        ];
        if let Some(ch) = g.channels {
            pairs.push(("channels", Value::Num(ch as f64)));
        }
        Value::obj(pairs)
    }

    fn group_from_value(v: &Value) -> Result<ShardGroup, JsonError> {
        let role = match v.get("role") {
            Ok(r) => {
                let s = r.as_str()?;
                ShardRole::from_label(s)
                    .ok_or_else(|| JsonError(format!("unknown shard role '{s}'")))?
            }
            Err(_) => ShardRole::Unified,
        };
        let scheduler = match v.get("scheduler") {
            Ok(r) => {
                let s = r.as_str()?;
                SchedulerKind::from_label(s)
                    .ok_or_else(|| JsonError(format!("unknown scheduler '{s}'")))?
            }
            Err(_) => SchedulerKind::Fcfs,
        };
        let policy = match v.get("policy") {
            Ok(p) => ServingPolicy::from_json(&p.pretty())
                .map_err(|e| JsonError(format!("bad policy: {e}")))?,
            Err(_) => ServingPolicy::default(),
        };
        let channels = match v.get("channels") {
            Ok(c) => Some(c.as_u32()?),
            Err(_) => None,
        };
        Ok(ShardGroup {
            name: v.get("name")?.as_str()?.to_string(),
            count: v.get("count")?.as_u32()? as usize,
            role,
            scheduler,
            max_batch: match v.get("max_batch") {
                Ok(b) => b.as_u32()? as usize,
                Err(_) => 4,
            },
            policy,
            channels,
        })
    }

    fn to_value(&self) -> Value {
        let mut pairs = vec![
            ("kv_link_gbps", Value::Num(self.kv_link_gbps)),
            ("groups", Value::Arr(self.groups.iter().map(Self::group_to_value).collect())),
        ];
        if let Some(path) = &self.mapping_store {
            pairs.push(("mapping_store", Value::Str(path.clone())));
        }
        Value::obj(pairs)
    }

    fn from_value(v: &Value) -> Result<Self, JsonError> {
        let Value::Arr(groups) = v.get("groups")? else {
            return Err(JsonError("'groups' must be an array".into()));
        };
        Ok(ClusterSpec {
            groups: groups.iter().map(Self::group_from_value).collect::<Result<_, _>>()?,
            kv_link_gbps: match v.get("kv_link_gbps") {
                Ok(g) => g.as_f64()?,
                Err(_) => DEFAULT_KV_LINK_GBPS,
            },
            mapping_store: match v.get("mapping_store") {
                Ok(m) => Some(m.as_str()?.to_string()),
                Err(_) => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unified_preset_shape() {
        let spec = ClusterSpec::unified(3, 2);
        spec.validate().unwrap();
        assert_eq!(spec.total_shards(), 3);
        assert!(!spec.is_disaggregated());
        assert_eq!(spec.groups[0].scheduler, SchedulerKind::Fcfs);
        assert_eq!(spec.groups[0].policy, ServingPolicy::default());
        assert_eq!(spec.kv_link_gbps, DEFAULT_KV_LINK_GBPS);
    }

    #[test]
    fn disaggregated_preset_is_balanced() {
        let spec = ClusterSpec::disaggregated(2, 2, 4);
        spec.validate().unwrap();
        assert!(spec.is_disaggregated());
        assert_eq!(spec.total_shards(), 4);
        assert!(spec.groups.iter().any(|g| g.role == ShardRole::Prefill));
        assert!(spec.groups.iter().any(|g| g.role == ShardRole::Decode));
    }

    #[test]
    fn json_roundtrip() {
        let spec = ClusterSpec {
            groups: vec![
                ShardGroup::unified("prefill", 2, 4)
                    .with_role(ShardRole::Prefill)
                    .with_scheduler(SchedulerKind::Edf)
                    .with_policy(ServingPolicy::chunked(256))
                    .with_channels(4),
                ShardGroup::unified("decode", 2, 8)
                    .with_role(ShardRole::Decode)
                    .with_channels(4),
            ],
            kv_link_gbps: 32.0,
            mapping_store: Some("results/mapping_store.json".into()),
        };
        let back = ClusterSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        // Absent mapping_store stays None through the round trip.
        let plain = ClusterSpec::unified(2, 4);
        assert_eq!(ClusterSpec::from_json(&plain.to_json()).unwrap().mapping_store, None);
    }

    #[test]
    fn json_defaults_fill_in() {
        // Role, scheduler, policy, max_batch, channels and the KV link are
        // all optional.
        let spec = ClusterSpec::from_json(
            r#"{"groups": [{"name": "all", "count": 2}]}"#,
        )
        .unwrap();
        assert_eq!(spec.groups[0].role, ShardRole::Unified);
        assert_eq!(spec.groups[0].scheduler, SchedulerKind::Fcfs);
        assert_eq!(spec.groups[0].policy, ServingPolicy::default());
        assert_eq!(spec.groups[0].channels, None);
        assert_eq!(spec.kv_link_gbps, DEFAULT_KV_LINK_GBPS);
    }

    #[test]
    fn unbalanced_roles_rejected() {
        let only_prefill = ClusterSpec {
            groups: vec![ShardGroup::unified("p", 2, 4).with_role(ShardRole::Prefill)],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        };
        assert!(only_prefill.validate().unwrap_err().contains("unbalanced"));
        let only_decode = ClusterSpec {
            groups: vec![ShardGroup::unified("d", 2, 4).with_role(ShardRole::Decode)],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        };
        assert!(only_decode.validate().unwrap_err().contains("unbalanced"));
        // And the JSON loader enforces the same rule.
        let json = only_decode.to_json();
        assert!(ClusterSpec::from_json(&json).is_err());
    }

    #[test]
    fn zero_count_group_rejected() {
        let mut spec = ClusterSpec::unified(2, 4);
        spec.groups[0].count = 0;
        assert!(spec.validate().unwrap_err().contains("zero shards"));
        assert!(ClusterSpec::from_json(
            r#"{"groups": [{"name": "g", "count": 0}]}"#
        )
        .is_err());
    }

    #[test]
    fn mixed_channel_shares_rejected() {
        let spec = ClusterSpec {
            groups: vec![
                ShardGroup::unified("a", 1, 4).with_channels(4),
                ShardGroup::unified("b", 1, 4),
            ],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        };
        assert!(spec.validate().unwrap_err().contains("mixed"));
    }

    #[test]
    fn channel_share_must_cover_count() {
        let spec = ClusterSpec {
            groups: vec![ShardGroup::unified("a", 4, 4).with_channels(2)],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        };
        assert!(spec.validate().unwrap_err().contains("cannot cover"));
    }

    #[test]
    fn duplicate_names_and_bad_link_rejected() {
        let spec = ClusterSpec {
            groups: vec![ShardGroup::unified("a", 1, 4), ShardGroup::unified("a", 1, 4)],
            kv_link_gbps: DEFAULT_KV_LINK_GBPS,
            mapping_store: None,
        };
        assert!(spec.validate().unwrap_err().contains("duplicate"));
        let bad_link = ClusterSpec::unified(1, 1).with_kv_link_gbps(0.0);
        assert!(bad_link.validate().unwrap_err().contains("kv_link_gbps"));
    }

    #[test]
    fn role_and_scheduler_labels_roundtrip() {
        for r in [ShardRole::Unified, ShardRole::Prefill, ShardRole::Decode] {
            assert_eq!(ShardRole::from_label(r.label()), Some(r));
        }
        assert!(ShardRole::from_label("gpu").is_none());
        for k in [SchedulerKind::Fcfs, SchedulerKind::Bucketed, SchedulerKind::Edf] {
            assert_eq!(SchedulerKind::from_label(k.label()), Some(k));
        }
        assert_eq!(SchedulerKind::from_label("bucket"), Some(SchedulerKind::Bucketed));
        assert!(SchedulerKind::from_label("lifo").is_none());
        assert!(ShardRole::Unified.accepts_fresh_prompts());
        assert!(ShardRole::Prefill.accepts_fresh_prompts());
        assert!(!ShardRole::Decode.accepts_fresh_prompts());
    }
}
