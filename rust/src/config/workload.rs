//! Workload specifications (paper Table 3 and §5.3 scenarios).


/// Integer operand precision.  RACAM is bit-serial, so precision is a
/// runtime knob (the `prec[3:0]` control field of Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Int2,
    Int4,
    Int8,
    Int16,
}

impl Precision {
    pub fn bits(&self) -> u32 {
        match self {
            Precision::Int2 => 2,
            Precision::Int4 => 4,
            Precision::Int8 => 8,
            Precision::Int16 => 16,
        }
    }

    pub fn from_bits(bits: u32) -> Option<Self> {
        match bits {
            2 => Some(Precision::Int2),
            4 => Some(Precision::Int4),
            8 => Some(Precision::Int8),
            16 => Some(Precision::Int16),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::Int2 => "int2",
            Precision::Int4 => "int4",
            Precision::Int8 => "int8",
            Precision::Int16 => "int16",
        }
    }
}

/// A matrix multiplication `O[M,N] = I[M,K] × W[K,N]`.
///
/// `weight_static` marks W as a static operand (model weight) that is
/// pre-transposed and laid out in DRAM offline (§2.2), i.e. it costs no
/// runtime I/O on the PIM systems.  GEMV is the `m == 1` special case.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MatmulShape {
    pub m: u64,
    pub k: u64,
    pub n: u64,
    pub prec: Precision,
    pub weight_static: bool,
    /// The dynamic input is already resident in PIM DRAM (it is the output
    /// of the previous kernel); it relays out over the internal fabric
    /// instead of crossing the host channel when broadcast units exist.
    pub input_resident: bool,
}

impl MatmulShape {
    pub fn new(m: u64, k: u64, n: u64, prec: Precision) -> Self {
        MatmulShape { m, k, n, prec, weight_static: true, input_resident: false }
    }

    pub fn dynamic(m: u64, k: u64, n: u64, prec: Precision) -> Self {
        MatmulShape { m, k, n, prec, weight_static: false, input_resident: false }
    }

    /// Mark the input as PIM-resident (inter-kernel dataflow).
    pub fn resident(mut self) -> Self {
        self.input_resident = true;
        self
    }

    pub fn is_gemv(&self) -> bool {
        self.m == 1 || self.n == 1
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }

    /// 2·MACs, the FLOP-equivalent op count used for TOPS numbers.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Bytes of the dynamic input operand I.
    pub fn input_bytes(&self) -> u64 {
        (self.m * self.k * self.prec.bits() as u64).div_ceil(8)
    }

    /// Bytes of W (counts as dynamic I/O only when `!weight_static`).
    pub fn weight_bytes(&self) -> u64 {
        (self.k * self.n * self.prec.bits() as u64).div_ceil(8)
    }

    /// Bytes of the int32 output matrix.
    pub fn output_bytes(&self) -> u64 {
        self.m * self.n * 4
    }

    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.m, self.k, self.n)
    }
}

/// Transformer hyper-parameters of one evaluated LLM (paper Table 3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LlmSpec {
    pub name: String,
    pub layers: u32,
    pub hidden: u64,
    pub heads: u32,
    /// KV heads (grouped-query attention); equals `heads` for MHA models.
    pub kv_heads: u32,
    /// FFN intermediate size (4·hidden for GPT-style, 3.5·hidden-ish for Llama).
    pub ffn: u64,
    /// Gated FFN (SwiGLU) has three projection matmuls instead of two.
    pub gated_ffn: bool,
    pub vocab: u64,
    pub prec: Precision,
}

impl LlmSpec {
    pub fn head_dim(&self) -> u64 {
        self.hidden / self.heads as u64
    }

    /// Total weight parameter count of the matmul weights (attention + FFN);
    /// embedding/vocab projection included once.
    pub fn weight_params(&self) -> u64 {
        let h = self.hidden;
        let kv = self.kv_heads as u64 * self.head_dim();
        let attn = h * h + 2 * h * kv + h * h; // Q,K,V,O
        let ffn = if self.gated_ffn { 3 * h * self.ffn } else { 2 * h * self.ffn };
        self.layers as u64 * (attn + ffn) + self.vocab * h
    }

    /// Weight footprint in bytes at the model's precision.
    pub fn weight_bytes(&self) -> u64 {
        self.weight_params() * self.prec.bits() as u64 / 8
    }

    /// KV-cache footprint of one request at `ctx_tokens` of context:
    /// 2 (K and V) · layers · kv_heads · head_dim · ctx · bits / 8 — the
    /// payload a prefill shard ships to a decode shard when a cluster is
    /// disaggregated (`config::ClusterSpec` roles).
    pub fn kv_cache_bytes(&self, ctx_tokens: u64) -> u64 {
        2 * self.layers as u64
            * self.kv_heads as u64
            * self.head_dim()
            * ctx_tokens
            * self.prec.bits() as u64
            / 8
    }
}

/// Inference stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Prompt processing: sequence-parallel GEMMs, compute-bound.
    Prefill,
    /// Token generation with KV cache: GEMVs, memory-bound.
    Decode,
}

impl Stage {
    pub fn label(&self) -> &'static str {
        match self {
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
        }
    }
}

/// End-to-end inference scenario (paper §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scenario {
    pub name: &'static str,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
}

impl Scenario {
    /// "Prefill heavy": 1024 prompt + 4096 output tokens.
    pub const CODE_GENERATION: Scenario =
        Scenario { name: "Code Generation", prompt_tokens: 1024, output_tokens: 4096 };
    /// "Decode heavy": 8192 prompt + 256 output tokens.
    pub const CONTEXT_UNDERSTANDING: Scenario =
        Scenario { name: "Context Understanding", prompt_tokens: 8192, output_tokens: 256 };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpt3_175b, gpt3_6_7b, llama3_70b, llama3_8b};

    #[test]
    fn precision_roundtrip() {
        for p in [Precision::Int2, Precision::Int4, Precision::Int8, Precision::Int16] {
            assert_eq!(Precision::from_bits(p.bits()), Some(p));
        }
        assert_eq!(Precision::from_bits(3), None);
    }

    #[test]
    fn gemv_detection() {
        assert!(MatmulShape::new(1, 4096, 4096, Precision::Int8).is_gemv());
        assert!(!MatmulShape::new(64, 64, 64, Precision::Int8).is_gemv());
    }

    #[test]
    fn shape_byte_math() {
        let s = MatmulShape::new(4, 16, 8, Precision::Int4);
        assert_eq!(s.input_bytes(), 4 * 16 / 2);
        assert_eq!(s.weight_bytes(), 16 * 8 / 2);
        assert_eq!(s.output_bytes(), 4 * 8 * 4);
        assert_eq!(s.macs(), 4 * 16 * 8);
    }

    #[test]
    fn model_parameter_counts_are_plausible() {
        // Param counts should land near the models' nominal sizes.
        let cases = [
            (gpt3_6_7b(), 6.7e9, 0.15),
            (gpt3_175b(), 175e9, 0.15),
            (llama3_8b(), 8e9, 0.20),
            (llama3_70b(), 70e9, 0.15),
        ];
        for (spec, nominal, tol) in cases {
            let p = spec.weight_params() as f64;
            let rel = (p - nominal).abs() / nominal;
            assert!(rel < tol, "{}: {p:.3e} vs nominal {nominal:.3e} (rel {rel:.2})", spec.name);
        }
    }

    #[test]
    fn kv_cache_bytes_scales_with_context_and_gqa() {
        // GPT-3 6.7B int8: 2 · 32 layers · 32 kv_heads · 128 head_dim per
        // token = 256 KiB/token.
        let gpt = gpt3_6_7b();
        assert_eq!(gpt.kv_cache_bytes(1), 2 * 32 * 4096);
        assert_eq!(gpt.kv_cache_bytes(1024), 1024 * 2 * 32 * 4096);
        // GQA shrinks the cache: Llama-3 8B has 8 kv heads to GPT's 32.
        let llama = llama3_8b();
        assert_eq!(llama.kv_cache_bytes(1024) * 4, gpt.kv_cache_bytes(1024));
    }

    #[test]
    fn gpt3_175b_weights_exceed_h100_hbm() {
        // This drives the paper's offloading story: 175B int8 > 80 GB.
        assert!(gpt3_175b().weight_bytes() > 80 * (1u64 << 30));
        assert!(gpt3_6_7b().weight_bytes() < 80 * (1u64 << 30));
    }
}
