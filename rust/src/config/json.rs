//! Minimal self-contained JSON reader/writer for configuration files.
//!
//! The build environment is offline (no serde), so this module implements
//! the small JSON subset the config system needs: objects, arrays, strings,
//! numbers, booleans and null, with a recursive-descent parser and a pretty
//! printer.  It is deliberately strict: duplicate keys, trailing garbage and
//! malformed escapes are errors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Result<&Value, JsonError> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| JsonError(format!("missing key '{key}'"))),
            _ => Err(JsonError(format!("expected object while reading '{key}'"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(JsonError(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_u32(&self) -> Result<u32, JsonError> {
        let n = self.as_f64()?;
        if n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n) {
            Ok(n as u32)
        } else {
            Err(JsonError(format!("expected u32, got {n}")))
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(JsonError(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(JsonError(format!("expected string, got {self:?}"))),
        }
    }

    /// Pretty-print with two-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    out.push_str(&pad);
                    out.push_str("  ");
                    v.write(out, indent + 1);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    out.push_str(&pad);
                    out.push_str("  ");
                    out.push_str(&format!("{:?}: ", k));
                    v.write(out, indent + 1);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Parse error with byte position context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (wanted '{word}')")))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("malformed number"))?;
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("malformed number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        self.pos = start + len;
                        if self.pos > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            if map.insert(key.clone(), val).is_some() {
                return Err(self.err(&format!("duplicate key '{key}'")));
            }
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_config_like_document() {
        let src = r#"{"dram": {"channels": 8, "mts": 5200}, "features": {"lb": true}, "name": "racam", "eff": 0.85}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("dram").unwrap().get("channels").unwrap().as_u32().unwrap(), 8);
        assert_eq!(v.get("eff").unwrap().as_f64().unwrap(), 0.85);
        assert!(v.get("features").unwrap().get("lb").unwrap().as_bool().unwrap());
        // pretty → parse → identical
        let again = parse(&v.pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn arrays_and_nesting() {
        let v = parse(r#"[1, [2, 3], {"a": [true, null, "x"]}]"#).unwrap();
        let again = parse(&v.pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""line\nquote\" tab\t uA backslash\\""#).unwrap();
        assert_eq!(v, Value::Str("line\nquote\" tab\t uA backslash\\".into()));
        let again = parse(&v.pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"µ-ops × 2\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "µ-ops × 2");
    }

    #[test]
    fn negative_and_float_numbers() {
        let v = parse("[-5, 3.25, 1e3, -2.5e-2]").unwrap();
        if let Value::Arr(a) = &v {
            assert_eq!(a[0].as_f64().unwrap(), -5.0);
            assert_eq!(a[1].as_f64().unwrap(), 3.25);
            assert_eq!(a[2].as_f64().unwrap(), 1000.0);
            assert_eq!(a[3].as_f64().unwrap(), -0.025);
        } else {
            panic!();
        }
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1,\"a\":2}").is_err());
        assert!(parse("true false").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("[1 2]").is_err());
    }

    #[test]
    fn u32_bounds() {
        assert!(parse("4294967296").unwrap().as_u32().is_err());
        assert!(parse("1.5").unwrap().as_u32().is_err());
        assert_eq!(parse("4294967295").unwrap().as_u32().unwrap(), u32::MAX);
    }
}
