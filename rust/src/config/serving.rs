//! Serving-loop policy: how the per-shard iteration engine interleaves
//! prefill and decode work, and whether running requests may be preempted.
//!
//! A [`ServingPolicy`] is declarative configuration for
//! `coordinator::Server`'s event-driven serving loop.  The default
//! (`prefill_chunk_tokens = None`, `preempt = false`) reproduces the
//! paper-faithful whole-prefill schedule bit-for-bit: every admitted
//! request's full prompt is prefetched in one step before the next decode
//! iteration.  Setting a chunk size bounds how long one prompt may occupy
//! the shard between decode iterations, and enabling preemption lets
//! deadline-aware schedulers shed or re-queue running requests (see
//! `coordinator::Scheduler::should_preempt`).
//!
//! Policies are JSON-loadable like [`super::HwConfig`] and
//! [`super::TrafficSpec`], so a serving configuration can live in a file
//! next to the hardware config:
//!
//! ```json
//! {"prefill_chunk_tokens": 256, "preempt": true}
//! ```

use super::json::{self, JsonError, Value};

/// Default chunk granularity of the [`ServingPolicy::interactive`] preset.
/// Matches the 256-token context-bucket boundary the serving cost caches
/// use (`coordinator::BUCKET_TOKENS`), so a chunk never spans more than one
/// new pricing bucket.
pub const DEFAULT_PREFILL_CHUNK: u64 = 256;

/// Which serving-loop implementation a shard runs.  Both produce
/// bit-identical simulated results (timestamps, costs, tokens, stats);
/// they differ only in host wall time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// The event-calendar engine (the default): lockstep-decode stretches
    /// fast-forward to the next material event — arrival release, batch
    /// membership change, pricing-bucket edge, preemption horizon —
    /// instead of paying the full per-iteration scheduling machinery for
    /// every token.  See `docs/serving.md` ("Engine internals").
    #[default]
    Calendar,
    /// The per-iteration reference engine: every simulated step runs the
    /// complete admission / preemption / prefill-selection round.  Kept as
    /// the equivalence oracle for the calendar engine (and for schedulers
    /// whose hooks are stateful — the calendar engine falls back to
    /// per-iteration stepping for those automatically).
    Oracle,
}

impl EngineKind {
    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Calendar => "calendar",
            EngineKind::Oracle => "oracle",
        }
    }

    pub fn from_label(s: &str) -> Option<EngineKind> {
        match s {
            "calendar" => Some(EngineKind::Calendar),
            "oracle" => Some(EngineKind::Oracle),
            _ => None,
        }
    }
}

/// Host-executor configuration: how the coordinator schedules shard
/// serving loops onto OS threads (see `runtime::executor`).  Purely a
/// host-side knob — simulated results are bit-identical for every value
/// (the cross-thread determinism gate in `tests/engine_equivalence.rs`
/// pins this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostExecutor {
    /// Worker-pool size.  `None` (the default) resolves to the
    /// `RACAM_THREADS` environment variable if set, else the host's
    /// available parallelism.  Explicit values floor at 1.
    pub threads: Option<usize>,
    /// Scheduling rounds one shard runs per executor task poll — the
    /// work-stealing granularity.  Larger batches amortize queue traffic;
    /// smaller ones rebalance sooner.  Floored at 1.
    pub batch_rounds: u64,
}

impl HostExecutor {
    /// Default rounds per poll: long enough that queue traffic is noise
    /// next to the simulated work, short enough that a thief can pick up
    /// a lagging shard mid-run.
    pub const DEFAULT_BATCH_ROUNDS: u64 = 1024;

    /// An executor pinned to `threads` workers.
    pub const fn with_threads(threads: usize) -> Self {
        HostExecutor { threads: Some(threads), batch_rounds: Self::DEFAULT_BATCH_ROUNDS }
    }
}

impl Default for HostExecutor {
    fn default() -> Self {
        HostExecutor { threads: None, batch_rounds: Self::DEFAULT_BATCH_ROUNDS }
    }
}

/// How the serving loop schedules prefill work and preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServingPolicy {
    /// Maximum prompt tokens one prefill step may consume before the loop
    /// returns to decode iterations.  `None` (the default) charges each
    /// admitted request's whole prompt in a single step — the legacy
    /// schedule, where one long prompt stalls every running decode.
    pub prefill_chunk_tokens: Option<u64>,
    /// When true, the serving loop consults the scheduler's
    /// `should_preempt` hook once per iteration for every running request,
    /// and sheds or re-queues the ones the policy gives up on.
    pub preempt: bool,
    /// Which serving-loop implementation runs the schedule.  Results are
    /// bit-identical either way; `Oracle` trades speed for the reference
    /// per-iteration structure (equivalence tests, stateful schedulers).
    pub engine: EngineKind,
}

impl ServingPolicy {
    /// The paper-faithful schedule: whole-prompt prefill, no preemption.
    /// Identical to `ServingPolicy::default()`.
    pub const fn whole_prefill() -> Self {
        ServingPolicy {
            prefill_chunk_tokens: None,
            preempt: false,
            engine: EngineKind::Calendar,
        }
    }

    /// Bound prefill steps to `tokens` prompt tokens (preemption off).
    pub const fn chunked(tokens: u64) -> Self {
        ServingPolicy {
            prefill_chunk_tokens: Some(tokens),
            preempt: false,
            engine: EngineKind::Calendar,
        }
    }

    /// Enable the preemption hook on top of this policy.
    pub const fn with_preemption(mut self) -> Self {
        self.preempt = true;
        self
    }

    /// Run this schedule on the given serving-loop implementation.
    pub const fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Run this schedule on the per-iteration reference engine.
    pub const fn oracle(self) -> Self {
        self.with_engine(EngineKind::Oracle)
    }

    /// Latency-oriented preset: bucket-sized prefill chunks so short
    /// requests' first tokens are never stalled behind a whole long
    /// prompt, plus deadline preemption for schedulers that implement it.
    pub const fn interactive() -> Self {
        ServingPolicy::chunked(DEFAULT_PREFILL_CHUNK).with_preemption()
    }

    /// Whether this policy is the bit-for-bit legacy schedule.
    pub fn is_whole_prefill(&self) -> bool {
        self.prefill_chunk_tokens.is_none() && !self.preempt
    }

    /// Short human label for table rows and CLI output, e.g. `whole`,
    /// `chunk256`, `chunk256+preempt`.
    pub fn label(&self) -> String {
        let mut s = match self.prefill_chunk_tokens {
            None => "whole".to_string(),
            Some(c) => format!("chunk{c}"),
        };
        if self.preempt {
            s.push_str("+preempt");
        }
        if self.engine == EngineKind::Oracle {
            s.push_str("+oracle");
        }
        s
    }

    /// A zero-token chunk would make prefill steps spin without advancing.
    pub fn validate(&self) -> Result<(), String> {
        match self.prefill_chunk_tokens {
            Some(0) => Err("prefill_chunk_tokens must be at least 1 (or omitted)".into()),
            _ => Ok(()),
        }
    }

    pub fn from_json(s: &str) -> crate::Result<Self> {
        let v = json::parse(s).map_err(anyhow::Error::from)?;
        let policy = Self::from_value(&v).map_err(anyhow::Error::from)?;
        policy.validate().map_err(|e| anyhow::anyhow!("invalid serving policy: {e}"))?;
        Ok(policy)
    }

    pub fn to_json(&self) -> String {
        self.to_value().pretty()
    }

    /// The JSON value behind [`ServingPolicy::to_json`] (shared with
    /// `ClusterSpec` serialization, which embeds policies directly
    /// instead of round-tripping through a string).
    pub(crate) fn to_value(&self) -> Value {
        let mut pairs = Vec::new();
        if let Some(c) = self.prefill_chunk_tokens {
            pairs.push(("prefill_chunk_tokens", Value::Num(c as f64)));
        }
        pairs.push(("preempt", Value::Bool(self.preempt)));
        if self.engine != EngineKind::Calendar {
            pairs.push(("engine", Value::Str(self.engine.label().into())));
        }
        Value::obj(pairs)
    }

    fn from_value(v: &Value) -> Result<Self, JsonError> {
        let prefill_chunk_tokens = match v.get("prefill_chunk_tokens") {
            Ok(c) => Some(c.as_u32()? as u64),
            Err(_) => None,
        };
        let preempt = match v.get("preempt") {
            Ok(b) => b.as_bool()?,
            Err(_) => false,
        };
        let engine = match v.get("engine") {
            Ok(e) => {
                let s = e.as_str()?;
                EngineKind::from_label(s)
                    .ok_or_else(|| JsonError(format!("unknown engine '{s}' (calendar|oracle)")))?
            }
            Err(_) => EngineKind::Calendar,
        };
        Ok(ServingPolicy { prefill_chunk_tokens, preempt, engine })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_legacy_whole_prefill() {
        let p = ServingPolicy::default();
        assert_eq!(p, ServingPolicy::whole_prefill());
        assert!(p.is_whole_prefill());
        assert_eq!(p.label(), "whole");
    }

    #[test]
    fn presets_and_labels() {
        assert_eq!(ServingPolicy::chunked(128).label(), "chunk128");
        let i = ServingPolicy::interactive();
        assert_eq!(i.prefill_chunk_tokens, Some(DEFAULT_PREFILL_CHUNK));
        assert!(i.preempt);
        assert_eq!(i.label(), "chunk256+preempt");
        assert!(!i.is_whole_prefill());
    }

    #[test]
    fn json_roundtrip() {
        for p in [
            ServingPolicy::whole_prefill(),
            ServingPolicy::chunked(512),
            ServingPolicy::interactive(),
            ServingPolicy::whole_prefill().with_preemption(),
        ] {
            let back = ServingPolicy::from_json(&p.to_json()).unwrap();
            assert_eq!(p, back, "{}", p.label());
        }
    }

    #[test]
    fn missing_fields_default_to_legacy() {
        let p = ServingPolicy::from_json("{}").unwrap();
        assert!(p.is_whole_prefill());
    }

    #[test]
    fn zero_chunk_rejected() {
        assert!(ServingPolicy::chunked(0).validate().is_err());
        assert!(ServingPolicy::from_json(r#"{"prefill_chunk_tokens": 0}"#).is_err());
        ServingPolicy::chunked(1).validate().unwrap();
    }

    #[test]
    fn engine_kind_roundtrips_and_defaults_to_calendar() {
        assert_eq!(ServingPolicy::default().engine, EngineKind::Calendar);
        assert_eq!(ServingPolicy::from_json("{}").unwrap().engine, EngineKind::Calendar);
        let oracle = ServingPolicy::interactive().oracle();
        assert_eq!(oracle.engine, EngineKind::Oracle);
        assert_eq!(oracle.label(), "chunk256+preempt+oracle");
        let back = ServingPolicy::from_json(&oracle.to_json()).unwrap();
        assert_eq!(back, oracle);
        // The engine choice does not change what schedule the policy is.
        assert!(ServingPolicy::whole_prefill().oracle().is_whole_prefill());
        assert!(ServingPolicy::from_json(r#"{"engine": "warp"}"#).is_err());
        // Calendar is the implicit default, so default policies serialize
        // without an engine field (old policy files stay byte-compatible).
        assert!(!ServingPolicy::whole_prefill().to_json().contains("engine"));
    }
}
