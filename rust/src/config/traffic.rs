//! Open-loop traffic specification: arrival process, length distributions,
//! and SLO deadline for a serving run (consumed by [`crate::traffic`]).
//!
//! A [`TrafficSpec`] is declarative — the actual request stream is
//! materialized by [`crate::traffic::generate`], deterministically from the
//! seed.  Specs are JSON-loadable like [`super::HwConfig`] so a serving
//! scenario can be described in a file next to the hardware config.

use super::json::{self, JsonError, Value};
use super::Scenario;

/// Request arrival process on the simulated clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson process: i.i.d. exponential inter-arrival gaps.
    Poisson { rate_per_s: f64 },
    /// Bursts of `burst` back-to-back requests arriving at Poisson epochs;
    /// the epoch rate is `rate_per_s / burst` so the *mean* request rate
    /// stays `rate_per_s` while the instantaneous load spikes.
    Bursty { rate_per_s: f64, burst: u32 },
}

impl ArrivalProcess {
    /// Mean request rate in requests per second.
    pub fn rate_per_s(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_per_s } => *rate_per_s,
            ArrivalProcess::Bursty { rate_per_s, .. } => *rate_per_s,
        }
    }

    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { rate_per_s } => format!("poisson({rate_per_s}/s)"),
            ArrivalProcess::Bursty { rate_per_s, burst } => {
                format!("bursty({rate_per_s}/s x{burst})")
            }
        }
    }
}

/// Token-length distribution for prompts or outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDist {
    /// Every request has exactly this many tokens.
    Fixed(u64),
    /// Uniform over `[lo, hi]` inclusive.
    Uniform { lo: u64, hi: u64 },
    /// Discretized lognormal-ish: `round(median · exp(sigma · N(0,1)))`,
    /// clamped to `[1, cap]` — the heavy right tail of real prompt-length
    /// traces without a trace file.
    LogNormal { median: u64, sigma: f64, cap: u64 },
}

impl LengthDist {
    pub fn label(&self) -> String {
        match self {
            LengthDist::Fixed(n) => format!("fixed({n})"),
            LengthDist::Uniform { lo, hi } => format!("uniform({lo}..{hi})"),
            LengthDist::LogNormal { median, sigma, cap } => {
                format!("lognormal(med={median},s={sigma},cap={cap})")
            }
        }
    }
}

/// A complete open-loop workload description.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficSpec {
    /// Generator seed; the request stream is a pure function of the spec.
    pub seed: u64,
    /// Number of requests in the stream.
    pub requests: u64,
    pub arrival: ArrivalProcess,
    pub prompt: LengthDist,
    pub output: LengthDist,
    /// Optional end-to-end SLO budget (ns past arrival), driving goodput.
    /// This is the *mean*: the generator spreads per-request budgets
    /// uniformly over [0.5×, 1.5×] of it, so deadline order differs from
    /// arrival order and deadline-aware schedulers (EDF) have something
    /// real to reorder — a constant budget would make EDF degenerate to
    /// FCFS exactly.
    pub deadline_ns: Option<u64>,
}

impl TrafficSpec {
    /// A spec matching one of the paper's §5.3 inference scenarios: fixed
    /// prompt/output lengths from the preset, Poisson arrivals at `rate`.
    pub fn for_scenario(sc: &Scenario, rate_per_s: f64, requests: u64, seed: u64) -> TrafficSpec {
        TrafficSpec {
            seed,
            requests,
            arrival: ArrivalProcess::Poisson { rate_per_s },
            prompt: LengthDist::Fixed(sc.prompt_tokens),
            output: LengthDist::Fixed(sc.output_tokens),
            deadline_ns: None,
        }
    }

    pub fn from_json(s: &str) -> crate::Result<Self> {
        let v = json::parse(s).map_err(anyhow::Error::from)?;
        let spec = Self::from_value(&v).map_err(anyhow::Error::from)?;
        spec.validate().map_err(|e| anyhow::anyhow!("invalid traffic spec: {e}"))?;
        Ok(spec)
    }

    /// Range checks: loading a spec that would panic the generator (zero
    /// rate) or silently degenerate (inverted uniform bounds) is an error.
    pub fn validate(&self) -> Result<(), String> {
        let check_rate = |r: f64| -> Result<(), String> {
            if r.is_finite() && r > 0.0 {
                Ok(())
            } else {
                Err(format!("arrival rate must be positive and finite, got {r}"))
            }
        };
        match self.arrival {
            ArrivalProcess::Poisson { rate_per_s } => check_rate(rate_per_s)?,
            ArrivalProcess::Bursty { rate_per_s, burst } => {
                check_rate(rate_per_s)?;
                if burst == 0 {
                    return Err("burst size must be at least 1".into());
                }
            }
        }
        for (name, dist) in [("prompt", &self.prompt), ("output", &self.output)] {
            match dist {
                LengthDist::Fixed(_) => {}
                LengthDist::Uniform { lo, hi } => {
                    if lo > hi {
                        return Err(format!("{name}: uniform lo {lo} > hi {hi}"));
                    }
                }
                LengthDist::LogNormal { median, sigma, cap } => {
                    if *median == 0 || *cap == 0 {
                        return Err(format!("{name}: lognormal median/cap must be >= 1"));
                    }
                    if !sigma.is_finite() || *sigma < 0.0 {
                        return Err(format!("{name}: lognormal sigma must be finite and >= 0"));
                    }
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> String {
        self.to_value().pretty()
    }

    fn arrival_to_value(a: &ArrivalProcess) -> Value {
        match a {
            ArrivalProcess::Poisson { rate_per_s } => Value::obj(vec![
                ("kind", Value::Str("poisson".into())),
                ("rate_per_s", Value::Num(*rate_per_s)),
            ]),
            ArrivalProcess::Bursty { rate_per_s, burst } => Value::obj(vec![
                ("kind", Value::Str("bursty".into())),
                ("rate_per_s", Value::Num(*rate_per_s)),
                ("burst", Value::Num(*burst as f64)),
            ]),
        }
    }

    fn arrival_from_value(v: &Value) -> Result<ArrivalProcess, JsonError> {
        match v.get("kind")?.as_str()? {
            "poisson" => {
                Ok(ArrivalProcess::Poisson { rate_per_s: v.get("rate_per_s")?.as_f64()? })
            }
            "bursty" => Ok(ArrivalProcess::Bursty {
                rate_per_s: v.get("rate_per_s")?.as_f64()?,
                burst: v.get("burst")?.as_u32()?,
            }),
            other => Err(JsonError(format!("unknown arrival kind '{other}'"))),
        }
    }

    fn dist_to_value(d: &LengthDist) -> Value {
        match d {
            LengthDist::Fixed(n) => Value::obj(vec![
                ("kind", Value::Str("fixed".into())),
                ("tokens", Value::Num(*n as f64)),
            ]),
            LengthDist::Uniform { lo, hi } => Value::obj(vec![
                ("kind", Value::Str("uniform".into())),
                ("lo", Value::Num(*lo as f64)),
                ("hi", Value::Num(*hi as f64)),
            ]),
            LengthDist::LogNormal { median, sigma, cap } => Value::obj(vec![
                ("kind", Value::Str("lognormal".into())),
                ("median", Value::Num(*median as f64)),
                ("sigma", Value::Num(*sigma)),
                ("cap", Value::Num(*cap as f64)),
            ]),
        }
    }

    fn dist_from_value(v: &Value) -> Result<LengthDist, JsonError> {
        match v.get("kind")?.as_str()? {
            "fixed" => Ok(LengthDist::Fixed(v.get("tokens")?.as_u32()? as u64)),
            "uniform" => Ok(LengthDist::Uniform {
                lo: v.get("lo")?.as_u32()? as u64,
                hi: v.get("hi")?.as_u32()? as u64,
            }),
            "lognormal" => Ok(LengthDist::LogNormal {
                median: v.get("median")?.as_u32()? as u64,
                sigma: v.get("sigma")?.as_f64()?,
                cap: v.get("cap")?.as_u32()? as u64,
            }),
            other => Err(JsonError(format!("unknown length distribution '{other}'"))),
        }
    }

    fn to_value(&self) -> Value {
        let mut pairs = vec![
            ("seed", Value::Num(self.seed as f64)),
            ("requests", Value::Num(self.requests as f64)),
            ("arrival", Self::arrival_to_value(&self.arrival)),
            ("prompt", Self::dist_to_value(&self.prompt)),
            ("output", Self::dist_to_value(&self.output)),
        ];
        if let Some(d) = self.deadline_ns {
            pairs.push(("deadline_ms", Value::Num(d as f64 / 1e6)));
        }
        Value::obj(pairs)
    }

    fn from_value(v: &Value) -> Result<Self, JsonError> {
        let deadline_ns = match v.get("deadline_ms") {
            Ok(d) => Some((d.as_f64()? * 1e6).round() as u64),
            Err(_) => None,
        };
        Ok(TrafficSpec {
            seed: v.get("seed")?.as_f64()? as u64,
            requests: v.get("requests")?.as_f64()? as u64,
            arrival: Self::arrival_from_value(v.get("arrival")?)?,
            prompt: Self::dist_from_value(v.get("prompt")?)?,
            output: Self::dist_from_value(v.get("output")?)?,
            deadline_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let spec = TrafficSpec {
            seed: 99,
            requests: 128,
            arrival: ArrivalProcess::Bursty { rate_per_s: 250.0, burst: 8 },
            prompt: LengthDist::LogNormal { median: 512, sigma: 0.7, cap: 8192 },
            output: LengthDist::Uniform { lo: 16, hi: 256 },
            deadline_ns: Some(250_000_000),
        };
        let back = TrafficSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn json_roundtrip_without_deadline() {
        let spec = TrafficSpec::for_scenario(&Scenario::CODE_GENERATION, 100.0, 32, 7);
        assert_eq!(spec.prompt, LengthDist::Fixed(1024));
        assert_eq!(spec.output, LengthDist::Fixed(4096));
        let back = TrafficSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        assert_eq!(back.deadline_ns, None);
    }

    #[test]
    fn unknown_kinds_error() {
        let bad = r#"{"seed": 1, "requests": 2,
            "arrival": {"kind": "sine", "rate_per_s": 5},
            "prompt": {"kind": "fixed", "tokens": 4},
            "output": {"kind": "fixed", "tokens": 4}}"#;
        assert!(TrafficSpec::from_json(bad).is_err());
    }

    #[test]
    fn invalid_specs_fail_to_load() {
        let base = TrafficSpec::for_scenario(&Scenario::CODE_GENERATION, 100.0, 8, 1);

        let mut zero_rate = base.clone();
        zero_rate.arrival = ArrivalProcess::Poisson { rate_per_s: 0.0 };
        assert!(zero_rate.validate().is_err());
        assert!(TrafficSpec::from_json(&zero_rate.to_json()).is_err());

        let mut inverted = base.clone();
        inverted.prompt = LengthDist::Uniform { lo: 100, hi: 10 };
        assert!(inverted.validate().unwrap_err().contains("lo 100 > hi 10"));

        let mut zero_burst = base.clone();
        zero_burst.arrival = ArrivalProcess::Bursty { rate_per_s: 10.0, burst: 0 };
        assert!(zero_burst.validate().is_err());

        let mut bad_sigma = base;
        bad_sigma.output = LengthDist::LogNormal { median: 8, sigma: f64::NAN, cap: 64 };
        assert!(bad_sigma.validate().is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(ArrivalProcess::Poisson { rate_per_s: 10.0 }.label(), "poisson(10/s)");
        assert_eq!(LengthDist::Fixed(8).label(), "fixed(8)");
        assert!(ArrivalProcess::Bursty { rate_per_s: 8.0, burst: 4 }.rate_per_s() == 8.0);
    }
}
