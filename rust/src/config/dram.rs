//! DRAM organization parameters (paper §2.1 / Table 2).


/// Hierarchical DRAM organization: channel → rank → device → bank → subarray.
///
/// Counts are *per parent*: `ranks` is ranks per channel, `devices` is
/// devices per rank, `banks` is banks per device, `subarrays` is subarrays
/// per bank.  `rows`/`cols` describe one subarray mat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramConfig {
    pub channels: u32,
    /// Ranks per channel.
    pub ranks: u32,
    /// Devices (chips) per rank.
    pub devices: u32,
    /// Banks per device.
    pub banks: u32,
    /// Subarrays per bank.
    pub subarrays: u32,
    /// Rows per subarray.
    pub rows: u32,
    /// Columns (bitlines) per subarray.
    pub cols: u32,
    /// Device external data width in bits (e.g. x16).
    pub device_width_bits: u32,
    /// I/O frequency in MT/s (DDR data rate, e.g. 5200 for DDR5-5200).
    pub mts: u32,
    /// Global bitline bus width in bits (bank ↔ locality buffer path).
    pub global_bitline_bits: u32,
}

impl DramConfig {
    /// Total banks in the system (compute-parallel units).
    pub fn total_banks(&self) -> u64 {
        self.channels as u64 * self.ranks as u64 * self.devices as u64 * self.banks as u64
    }

    /// Total subarrays in the system.
    pub fn total_subarrays(&self) -> u64 {
        self.total_banks() * self.subarrays as u64
    }

    /// Storage capacity in bits.
    pub fn capacity_bits(&self) -> u64 {
        self.total_subarrays() * self.rows as u64 * self.cols as u64
    }

    /// Per-channel peak external bandwidth in bytes/s.
    ///
    /// A channel bus is `devices × device_width` bits wide (one rank drives
    /// the bus at a time) transferring at `mts` MT/s.
    pub fn channel_bw_bytes(&self) -> f64 {
        let bus_bits = (self.devices * self.device_width_bits) as f64;
        bus_bits / 8.0 * self.mts as f64 * 1e6
    }

    /// Aggregate external bandwidth across all channels, bytes/s.
    pub fn total_bw_bytes(&self) -> f64 {
        self.channels as f64 * self.channel_bw_bytes()
    }

    /// Row size of one subarray in bytes.
    pub fn row_bytes(&self) -> u64 {
        self.cols as u64 / 8
    }

    /// Count for a mapping hierarchy level (see [`crate::mapping::Level`]).
    pub fn level_count(&self, level: crate::mapping::Level) -> u32 {
        use crate::mapping::Level::*;
        match level {
            Channel => self.channels,
            Rank => self.ranks,
            Device => self.devices,
            Bank => self.banks,
            Array => self.subarrays, // blocks-per-bank is derived in mapping with PE width
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::config::racam_paper;

    #[test]
    fn bandwidth_ddr5_5200_x16_8dev() {
        let d = racam_paper().dram;
        // 8 devices × 16 bits = 128-bit bus at 5200 MT/s = 83.2 GB/s/channel.
        let bw = d.channel_bw_bytes();
        assert!((bw - 83.2e9).abs() < 1e7, "got {bw}");
        assert!((d.total_bw_bytes() - 8.0 * 83.2e9).abs() < 1e8);
    }

    #[test]
    fn totals() {
        let d = racam_paper().dram;
        assert_eq!(d.total_banks(), 8 * 32 * 8 * 16);
        assert_eq!(d.total_subarrays(), d.total_banks() * 128);
    }
}
