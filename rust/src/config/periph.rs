//! RACAM peripheral-unit configuration (paper Table 2, §3).


/// Configuration of the units RACAM adds to a conventional DRAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeriphConfig {
    /// Bit-serial PEs per bank, one per locality-buffer column (§3.2).
    pub pes_per_bank: u32,
    /// Locality buffer rows per bank; 2n+1 rows give full reuse for n-bit
    /// multiplies, the paper selects 17 (up to int8) (§3.3).
    pub locality_buffer_rows: u32,
    /// Locality buffer columns per bank (must equal `pes_per_bank`).
    pub locality_buffer_cols: u32,
    /// Popcount reduction unit input width in bits (§3.4); the unit consumes
    /// one bit-slice of this many columns per cycle.
    pub popcount_width: u32,
    /// Accumulator width of the popcount reduction unit, bits (int32 adds).
    pub accumulator_bits: u32,
    /// Bank-level broadcast input width in bits (§3.5).
    pub bank_broadcast_bits: u32,
    /// Column-level broadcast fan-out (columns written per input bit).
    pub col_broadcast_fanout: u32,
}

impl PeriphConfig {
    /// Maximum operand precision with full bit reuse: buffer must hold
    /// n rows of op1 + 1 row of the streamed op2 bit + n result rows in
    /// flight ⇒ 2n+1 rows (paper §3.3).
    pub fn max_full_reuse_bits(&self) -> u32 {
        (self.locality_buffer_rows.saturating_sub(1)) / 2
    }

    /// Locality buffer capacity per bank, bits.
    pub fn locality_buffer_bits(&self) -> u64 {
        self.locality_buffer_rows as u64 * self.locality_buffer_cols as u64
    }
}

#[cfg(test)]
mod tests {
    use crate::config::racam_paper;

    #[test]
    fn paper_buffer_supports_int8() {
        let p = racam_paper().periph;
        assert_eq!(p.locality_buffer_rows, 17);
        assert_eq!(p.max_full_reuse_bits(), 8);
    }

    #[test]
    fn buffer_capacity() {
        let p = racam_paper().periph;
        assert_eq!(p.locality_buffer_bits(), 17 * 1024);
    }
}
