//! Area estimation (paper §5.2).
//!
//! * DRAM chip area scales linearly with capacity at the bit density of a
//!   Micron 16 Gb DDR5 die (TechInsights).
//! * The locality buffer is SRAM, priced at the TSMC 45 nm 6T cell
//!   (0.296 µm²/bit) and scaled to 14 nm — one node behind DDR5 peripheral
//!   logic, as fabricated peripheries use older nodes for thermal stability.
//! * Peripheral logic (PEs, popcount units, broadcast demuxes, FSMs) uses
//!   FreePDK45 synthesis-class areas scaled to 14 nm and inflated by the
//!   post-synthesis model: `A_post = A_synth · (1 + β) / U` with placement
//!   utilization `U` and buffer-growth factor `β` (§5.2.2).

use crate::config::HwConfig;

/// Area model constants (all documented against the paper's sources).
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// Micron 16 Gb DDR5 die area, mm² (TechInsights teardown).
    pub dram_die_mm2: f64,
    /// Bits per 16 Gb die.
    pub dram_die_bits: f64,
    /// 45 nm 6T SRAM cell, µm²/bit (TSMC VLSI'04).
    pub sram_cell_um2_45: f64,
    /// Synthesis-class areas at 45 nm, µm².
    pub pe_um2_45: f64,
    /// Popcount reduction unit (1024-input tree + int32 accumulator), µm².
    pub popcount_um2_45: f64,
    /// Broadcast demux network per bank, µm².
    pub broadcast_um2_45: f64,
    /// Control FSM per device, µm².
    pub fsm_um2_45: f64,
    /// Linear feature-scale factor from 45 nm to the 14 nm peripheral node.
    pub node_scale: f64,
    /// Placement utilization U (§5.2.2).
    pub placement_util: f64,
    /// Buffer growth factor β (§5.2.2).
    pub buffer_growth: f64,
    /// H100 die area, mm² (4N process).
    pub h100_die_mm2: f64,
    /// HBM3 stack footprint flattened to one layer, mm² (5 stacks ≈ 110 mm²
    /// each).
    pub h100_hbm_mm2: f64,
    /// Transistor-density ratio from the H100's 4N node to the common 15 nm
    /// comparison node of Fig. 11 (density-based, not naive quadratic).
    pub h100_to_15nm_density: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            dram_die_mm2: 66.0,
            dram_die_bits: 16.0 * (1u64 << 30) as f64,
            sram_cell_um2_45: 0.296,
            pe_um2_45: 200.0,
            popcount_um2_45: 10_500.0,
            broadcast_um2_45: 2_000.0,
            fsm_um2_45: 40_000.0,
            node_scale: 45.0 / 14.0,
            placement_util: 0.65,
            buffer_growth: 0.20,
            h100_die_mm2: 814.0,
            h100_hbm_mm2: 550.0,
            h100_to_15nm_density: 6.5,
        }
    }
}

/// Area report for a RACAM configuration, mm².
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaReport {
    pub dram_mm2: f64,
    pub locality_buffer_mm2: f64,
    pub pe_mm2: f64,
    pub popcount_mm2: f64,
    pub broadcast_mm2: f64,
    pub fsm_mm2: f64,
}

impl AreaReport {
    /// Total added peripheral area (everything except the DRAM itself).
    pub fn added_mm2(&self) -> f64 {
        self.locality_buffer_mm2 + self.pe_mm2 + self.popcount_mm2 + self.broadcast_mm2 + self.fsm_mm2
    }

    /// Added area as a fraction of the DRAM chip area (paper: ≈ 4%).
    pub fn overhead_fraction(&self) -> f64 {
        self.added_mm2() / self.dram_mm2
    }
}

impl AreaModel {
    /// Node scaling for logic/SRAM: quadratic in the linear feature ratio.
    fn node_area_factor(&self) -> f64 {
        1.0 / (self.node_scale * self.node_scale)
    }

    /// Post-synthesis inflation: (1 + β) / U.
    fn post_synthesis_factor(&self) -> f64 {
        (1.0 + self.buffer_growth) / self.placement_util
    }

    /// Full area report for a hardware configuration.
    pub fn report(&self, hw: &HwConfig) -> AreaReport {
        let bits = hw.dram.capacity_bits() as f64;
        let dram_mm2 = bits * self.dram_die_mm2 / self.dram_die_bits;

        let banks = hw.dram.total_banks() as f64;
        let devices = (hw.dram.total_banks() / hw.dram.banks as u64) as f64;
        let um2_to_mm2 = 1e-6;
        let logic = self.node_area_factor() * self.post_synthesis_factor() * um2_to_mm2;

        let lb_bits = hw.periph.locality_buffer_bits() as f64 * banks;
        // SRAM scales by cell area only (no P&R inflation for the array).
        let locality_buffer_mm2 = lb_bits * self.sram_cell_um2_45 * self.node_area_factor() * um2_to_mm2;

        AreaReport {
            dram_mm2,
            locality_buffer_mm2,
            pe_mm2: banks * hw.periph.pes_per_bank as f64 * self.pe_um2_45 * logic,
            popcount_mm2: banks * self.popcount_um2_45 * logic,
            broadcast_mm2: banks * self.broadcast_um2_45 * logic,
            fsm_mm2: devices * self.fsm_um2_45 * logic,
        }
    }

    /// H100 reference area at the common 15 nm node (die scaled by
    /// transistor density + HBM flattened), mm² — the Fig. 11 denominator.
    pub fn h100_mm2_at_15nm(&self) -> f64 {
        self.h100_die_mm2 * self.h100_to_15nm_density + self.h100_hbm_mm2
    }

    /// Proteus added-circuitry area: 1% of its PIM DRAM chip area
    /// (paper §6.1, citing [14, 70]).
    pub fn proteus_added_mm2(&self, pim_capacity_bytes: u64) -> f64 {
        let bits = (pim_capacity_bytes * 8) as f64;
        0.01 * bits * self.dram_die_mm2 / self.dram_die_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::racam_paper;

    #[test]
    fn paper_overhead_is_about_4_percent() {
        let r = AreaModel::default().report(&racam_paper());
        let f = r.overhead_fraction();
        assert!((0.03..0.05).contains(&f), "overhead {f:.4}");
    }

    #[test]
    fn pe_area_dominates_additions() {
        let r = AreaModel::default().report(&racam_paper());
        assert!(r.pe_mm2 > r.locality_buffer_mm2);
        assert!(r.pe_mm2 > r.popcount_mm2 + r.broadcast_mm2 + r.fsm_mm2);
    }

    #[test]
    fn added_area_is_about_a_quarter_of_h100() {
        // Paper §6.1: "total area of peripheral units is 24% of the scaled
        // H100 area".
        let m = AreaModel::default();
        let r = m.report(&racam_paper());
        let frac = r.added_mm2() / m.h100_mm2_at_15nm();
        assert!((0.15..0.35).contains(&frac), "added/H100 = {frac:.3}");
    }

    #[test]
    fn dram_area_scales_with_capacity() {
        let m = AreaModel::default();
        let hw = racam_paper();
        let half = crate::config::scale_capacity(&hw, 2);
        let full = m.report(&hw).dram_mm2;
        let halved = m.report(&half).dram_mm2;
        assert!((full / halved - 2.0).abs() < 1e-9);
    }

    #[test]
    fn proteus_added_area_is_tiny() {
        let m = AreaModel::default();
        let a = m.proteus_added_mm2(16 * (1 << 30));
        assert!(a < 10.0, "{a}");
        assert!(a > 0.1);
    }
}
