//! Metrics: latency breakdowns, throughput conversions and geometric means
//! used by every experiment (paper Figs. 9–17 all report one of these).

/// PIM-vs-I/O latency decomposition of a kernel or workload (Fig. 17).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// Total latency of PIM compute commands, ns.
    pub pim_ns: f64,
    /// Total host-interaction latency (layout, collection, host reduce), ns.
    pub io_ns: f64,
}

impl LatencyBreakdown {
    pub fn new(pim_ns: f64, io_ns: f64) -> Self {
        LatencyBreakdown { pim_ns, io_ns }
    }

    pub fn total_ns(&self) -> f64 {
        self.pim_ns + self.io_ns
    }

    pub fn pim_fraction(&self) -> f64 {
        self.pim_ns / self.total_ns().max(f64::MIN_POSITIVE)
    }

    /// Accumulate another breakdown (kernel → layer → model).
    pub fn add(&mut self, other: &LatencyBreakdown) {
        self.pim_ns += other.pim_ns;
        self.io_ns += other.io_ns;
    }

    pub fn scaled(&self, factor: f64) -> LatencyBreakdown {
        LatencyBreakdown { pim_ns: self.pim_ns * factor, io_ns: self.io_ns * factor }
    }
}

/// Throughput in requests (or tokens) per second from a latency in ns.
pub fn throughput_per_s(latency_ns: f64) -> f64 {
    1e9 / latency_ns.max(f64::MIN_POSITIVE)
}

/// Nearest-rank percentile over an unsorted sample, `p` in `[0, 100]`
/// (tail-latency reporting: p50/p95/p99 of TTFT/TPOT/e2e populations).
/// Returns 0 for an empty sample.  Taking several percentiles of one
/// population?  Sort once and use [`percentile_sorted`].
pub fn percentile(values: &[f64], p: f64) -> f64 {
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&sorted, p)
}

/// Nearest-rank percentile of an already ascending-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Geometric mean (the paper's headline aggregations are geomeans).
pub fn geomean(values: &[f64]) -> f64 {
    assert!(!values.is_empty(), "geomean of empty slice");
    let log_sum: f64 = values.iter().map(|v| v.max(f64::MIN_POSITIVE).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

/// Convert ns to a human string (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates() {
        let mut b = LatencyBreakdown::new(100.0, 50.0);
        b.add(&LatencyBreakdown::new(10.0, 5.0));
        assert_eq!(b.total_ns(), 165.0);
        assert!((b.pim_fraction() - 110.0 / 165.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[42.0], 99.0), 42.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // Unsorted input is handled.
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0, 1.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[3.0]) - 3.0).abs() < 1e-12);
        assert!((geomean(&[10.0, 10.0, 10.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_inverse() {
        assert!((throughput_per_s(1e9) - 1.0).abs() < 1e-12);
        assert!((throughput_per_s(1e6) - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(12.34), "12.3ns");
        assert_eq!(fmt_ns(12_340.0), "12.34µs");
        assert_eq!(fmt_ns(12_340_000.0), "12.34ms");
        assert_eq!(fmt_ns(2.5e9), "2.500s");
    }

    #[test]
    fn scaled_breakdown() {
        let b = LatencyBreakdown::new(10.0, 20.0).scaled(3.0);
        assert_eq!(b.pim_ns, 30.0);
        assert_eq!(b.io_ns, 60.0);
    }
}
