//! A minimal, dependency-free Rust lexer for the detcheck rules.
//!
//! This is deliberately *not* a real parser: the rules in
//! [`super::rules`] are token-pattern checks, so all the lexer has to do
//! is (a) scrub everything that is not code — line comments, nested
//! block comments, string literals (plain, raw, byte), char literals —
//! while preserving line numbers, (b) tokenize what remains, and
//! (c) recover just enough structure for the rules to scope themselves:
//! `#[cfg(test)]`/`#[test]` regions, `fn` body spans, and `impl` block
//! spans.
//!
//! Waiver comments (of the form `detcheck: allow(<rule>) -- <reason>`)
//! are harvested during scrubbing.  A waiver directive must sit at the
//! *start* of its comment (after the `//` and optional doc-comment
//! markers); mentions of the syntax mid-sentence — like the one in the
//! paragraph above — are ignored, so documentation cannot accidentally
//! waive anything.

/// One token of scrubbed source.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub line: u32,
}

/// A `detcheck: allow(...)` comment found during scrubbing.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Line the comment itself sits on.
    pub line: u32,
    /// Code line the waiver applies to: its own line if that line has
    /// code, otherwise the next line that does (standalone comments
    /// waive the statement below them).
    pub covers: u32,
    /// The rule name inside `allow(...)`.
    pub rule: String,
    /// The mandatory `-- <reason>` text; `None` means the waiver is
    /// malformed and is itself reported as a finding.
    pub reason: Option<String>,
}

/// A function body, as a half-open token range over [`Lexed::toks`].
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// Token index of the opening `{`.
    pub start: usize,
    /// Token index one past the matching `}`.
    pub end: usize,
}

/// An `impl` block: header tokens plus the body token range.
#[derive(Debug, Clone)]
pub struct ImplSpan {
    /// Every token between `impl` and the body `{`, in order (e.g.
    /// `["<", "R", ">", "Recorder", "for", "Wrap", "<", "R", ">"]`), so
    /// rules can extract the trait and self type.
    pub header: Vec<String>,
    pub start: usize,
    pub end: usize,
}

/// Fully lexed file: tokens plus the structure the rules need.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub waivers: Vec<Waiver>,
    /// Per-token flag: true when the token sits inside a
    /// `#[cfg(test)]`/`#[test]` region.
    pub test_mask: Vec<bool>,
    pub fns: Vec<FnSpan>,
    pub impls: Vec<ImplSpan>,
    /// Raw source lines, for finding snippets (1-indexed via `line - 1`).
    pub lines: Vec<String>,
}

impl Lexed {
    /// The trimmed raw source line, for human-readable findings.
    pub fn snippet(&self, line: u32) -> String {
        self.lines
            .get(line.saturating_sub(1) as usize)
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    }
}

/// Lex one file.
pub fn lex(src: &str) -> Lexed {
    let (scrubbed, mut waivers) = scrub(src);
    let toks = tokenize(&scrubbed);
    // Resolve which code line each waiver covers: its own line when that
    // line has tokens (trailing comment), else the next line that does.
    for w in &mut waivers {
        let own = toks.iter().any(|t| t.line == w.line);
        w.covers = if own {
            w.line
        } else {
            toks.iter().map(|t| t.line).filter(|&l| l > w.line).min().unwrap_or(w.line)
        };
    }
    let (test_mask, fns, impls) = structure(&toks);
    let lines = src.lines().map(|l| l.to_string()).collect();
    Lexed { toks, waivers, test_mask, fns, impls, lines }
}

/// Blank out comments, strings, and char literals, preserving newlines
/// so token line numbers stay aligned with the raw source.  Returns the
/// scrubbed text and any waiver comments encountered.
fn scrub(src: &str) -> (String, Vec<Waiver>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Vec::with_capacity(n);
    let mut waivers = Vec::new();
    let mut line: u32 = 1;
    let mut i = 0;
    // Push a scrubbed span verbatim for its newlines only.
    fn blank(out: &mut Vec<u8>, line: &mut u32, seg: &[u8]) {
        for &c in seg {
            if c == b'\n' {
                out.push(b'\n');
                *line += 1;
            } else {
                out.push(b' ');
            }
        }
    }
    while i < n {
        let c = b[i];
        if c == b'\n' {
            out.push(b'\n');
            line += 1;
            i += 1;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = src[i..].find('\n').map(|k| i + k).unwrap_or(n);
            if let Some(w) = parse_waiver(&src[i..j], line) {
                waivers.push(w);
            }
            blank(&mut out, &mut line, &b[i..j]);
            i = j;
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            // Nested block comment.
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, &mut line, &b[i..j]);
            i = j;
        } else if is_string_start(b, i) {
            // Optional `b`, optional `r` + hashes, then `"`.
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            let raw = b[j] == b'r';
            if raw {
                j += 1;
            }
            let mut hashes = 0usize;
            while raw && j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            debug_assert_eq!(b[j], b'"');
            j += 1;
            let end = if raw {
                // Raw string: ends at `"` followed by `hashes` hashes.
                let closer = format!("\"{}", "#".repeat(hashes));
                src[j..].find(&closer).map(|k| j + k + closer.len()).unwrap_or(n)
            } else {
                let mut k = j;
                loop {
                    if k >= n {
                        break n;
                    }
                    match b[k] {
                        b'\\' => k += 2,
                        b'"' => break k + 1,
                        _ => k += 1,
                    }
                }
            };
            blank(&mut out, &mut line, &b[i..end]);
            i = end;
        } else if c == b'\'' || (c == b'b' && i + 1 < n && b[i + 1] == b'\'' && !ident_tail(b, i)) {
            let q = if c == b'b' { i + 1 } else { i };
            // Distinguish a char literal from a lifetime: a literal is
            // `'\...'` or `'x'` (one char then a closing quote).
            let is_char = q + 1 < n
                && (b[q + 1] == b'\\' || {
                    // `'x'` — any single byte followed by `'` (covers
                    // `'_'`; a lifetime `'_` has no closing quote).
                    q + 2 < n && b[q + 1] != b'\'' && b[q + 2] == b'\''
                });
            if is_char {
                let end = if b[q + 1] == b'\\' {
                    // Escaped char (possibly `'\u{..}'`): scan to the
                    // closing quote.
                    let mut k = q + 2;
                    while k < n && b[k] != b'\'' {
                        k += 1;
                    }
                    (k + 1).min(n)
                } else {
                    q + 3
                };
                blank(&mut out, &mut line, &b[i..end]);
                i = end;
            } else {
                // Lifetime tick: keep it (harmless single-char token).
                out.push(b'\'');
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    (String::from_utf8_lossy(&out).into_owned(), waivers)
}

/// Is `b[i]` the start of a string literal (`"`, `r"`, `r#"`, `b"`,
/// `br"`, ...), and not the tail of a longer identifier like `var"`?
fn is_string_start(b: &[u8], i: usize) -> bool {
    if b[i] == b'"' {
        return true;
    }
    if ident_tail(b, i) {
        return false;
    }
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
        if j >= b.len() {
            return false;
        }
    }
    if b.get(j) == Some(&b'"') {
        return b[i] == b'b';
    }
    if b.get(j) != Some(&b'r') {
        return false;
    }
    j += 1;
    while b.get(j) == Some(&b'#') {
        j += 1;
    }
    b.get(j) == Some(&b'"')
}

/// True when the byte before `i` is part of an identifier, meaning the
/// `r`/`b` at `i` is an identifier tail, not a literal prefix.
fn ident_tail(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

/// Parse a waiver directive from a line comment.  The directive must
/// lead the comment (after slashes, `!`, and whitespace); the reason
/// after `--` is mandatory and its absence is recorded as `None` so the
/// rule engine can report the malformed waiver.
fn parse_waiver(comment: &str, line: u32) -> Option<Waiver> {
    let body = comment.trim_start_matches('/').trim_start_matches('!').trim_start();
    let rest = body.strip_prefix("detcheck:")?.trim_start();
    let rest = rest.strip_prefix("allow")?.trim_start();
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let tail = rest[close + 1..].trim();
    let reason = tail
        .strip_prefix("--")
        .map(|r| r.trim())
        .filter(|r| !r.is_empty())
        .map(|r| r.to_string());
    Some(Waiver { line, covers: line, rule, reason })
}

/// Tokenize scrubbed source: identifiers, numbers, `::`, and single
/// punctuation characters, each tagged with its 1-based line.
fn tokenize(scrubbed: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    for (ln0, text) in scrubbed.lines().enumerate() {
        let line = (ln0 + 1) as u32;
        let b = text.as_bytes();
        let mut i = 0;
        while i < b.len() {
            let c = b[i];
            if c.is_ascii_whitespace() {
                i += 1;
            } else if c.is_ascii_alphabetic() || c == b'_' {
                let s = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                toks.push(Tok { text: text[s..i].to_string(), line });
            } else if c.is_ascii_digit() {
                let s = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                // Float part — consume `.` only when a digit follows, so
                // ranges like `0..n` stay three tokens.
                if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    }
                }
                toks.push(Tok { text: text[s..i].to_string(), line });
            } else if c == b':' && i + 1 < b.len() && b[i + 1] == b':' {
                toks.push(Tok { text: "::".to_string(), line });
                i += 2;
            } else if c.is_ascii() {
                toks.push(Tok { text: (c as char).to_string(), line });
                i += 1;
            } else {
                // Non-ASCII outside comments/strings: skip the byte.
                i += 1;
            }
        }
    }
    toks
}

/// Structural pass: test regions, fn spans, impl spans.
fn structure(toks: &[Tok]) -> (Vec<bool>, Vec<FnSpan>, Vec<ImplSpan>) {
    let n = toks.len();
    let mut test_mask = vec![false; n];
    let mut fns = Vec::new();
    let mut impls = Vec::new();
    let mut i = 0;
    let mut pending_test = false;
    let mut group_depth = 0i32;
    while i < n {
        let t = toks[i].text.as_str();
        match t {
            "(" | "[" => group_depth += 1,
            ")" | "]" => group_depth -= 1,
            _ => {}
        }
        if t == "#" && i + 1 < n && (toks[i + 1].text == "[" || toks[i + 1].text == "!") {
            // Attribute: `#[...]` or inner `#![...]`.
            let mut j = i + 1;
            if toks[j].text == "!" {
                j += 1;
            }
            if j < n && toks[j].text == "[" {
                let mut depth = 1usize;
                let mut idents = Vec::new();
                j += 1;
                while j < n && depth > 0 {
                    match toks[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => depth -= 1,
                        s => {
                            if s.starts_with(|c: char| c.is_ascii_alphabetic() || c == '_') {
                                idents.push(s.to_string());
                            }
                        }
                    }
                    j += 1;
                }
                // `#[test]` or any `cfg(...)` mentioning `test` — except
                // `cfg(not(test))`, which marks *non*-test code.
                let is_test_attr = idents.first().map(String::as_str) == Some("test")
                    || (idents.first().map(String::as_str) == Some("cfg")
                        && idents.iter().any(|s| s == "test")
                        && !idents.iter().any(|s| s == "not"));
                if is_test_attr {
                    pending_test = true;
                }
                i = j;
                continue;
            }
        }
        if pending_test {
            // The attribute governs the next item: a braced item puts
            // its whole `{...}` block in the test region; a `;`-item
            // (e.g. `#[cfg(test)] use ...;`) consumes the flag with no
            // region.  Brackets/parens are tracked so `;` inside
            // `[u8; N]` or params does not end the item early.
            match t {
                "{" if group_depth == 0 => {
                    let end = match_brace(toks, i);
                    for m in test_mask.iter_mut().take(end + 1).skip(i) {
                        *m = true;
                    }
                    pending_test = false;
                    // Fall through: the region's tokens still get fn /
                    // impl spans recorded (rules decide what test code
                    // may do).
                }
                ";" if group_depth == 0 => pending_test = false,
                _ => {}
            }
        }
        match t {
            "fn" if i + 1 < n => {
                let name = toks[i + 1].text.clone();
                if name.starts_with(|c: char| c.is_ascii_alphabetic() || c == '_') {
                    if let Some(body) = find_body(toks, i + 2) {
                        let end = match_brace(toks, body);
                        fns.push(FnSpan { name, start: body, end });
                    }
                }
                i += 1;
            }
            // Item-position `impl` blocks only: argument-position
            // `impl Trait` sits inside parens (group_depth > 0), and
            // return-position `-> impl Trait` is preceded by the `>` of
            // the arrow (`->` lexes as two tokens).  Neither opens an
            // impl block.
            "impl" if group_depth == 0 && (i == 0 || toks[i - 1].text != ">") => {
                if let Some(body) = find_body(toks, i + 1) {
                    let header = toks[i + 1..body].iter().map(|t| t.text.clone()).collect();
                    let end = match_brace(toks, body);
                    impls.push(ImplSpan { header, start: body, end });
                }
            }
            _ => {}
        }
        i += 1;
    }
    (test_mask, fns, impls)
}

/// From `start`, find the opening `{` of the item's body, skipping the
/// signature (params, return type, where clause).  Returns `None` when a
/// `;` ends the item first (trait method declaration, `impl Trait for T;`
/// never — but harmless).  Parens and square brackets are depth-tracked
/// so `;` inside `[u8; 4]` or `(a, b)` doesn't terminate the scan.
fn find_body(toks: &[Tok], start: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (k, t) in toks.iter().enumerate().skip(start) {
        match t.text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            "{" if depth == 0 => return Some(k),
            ";" if depth == 0 => return None,
            _ => {}
        }
    }
    None
}

/// Token index one past the `}` matching the `{` at `open`.
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
    }
    toks.len()
}
