//! The detcheck rules.
//!
//! Each rule is a token-pattern check over [`super::lexer::Lexed`] files,
//! scoped by module path and file kind.  The rules encode this repo's
//! determinism and purity contracts — see `docs/analysis.md` for the
//! catalog, the *why* behind each contract, and the waiver etiquette.
//!
//! Rules come in two shapes: per-file (wall-clock, map-iteration,
//! thread-spawn, float-reduce, panic-hygiene, recorder-purity) and
//! corpus-wide (deprecated-internal collects `#[deprecated]` associated
//! fns anywhere and flags qualified calls everywhere else;
//! engine-parity cross-references `EventKind` variants against the
//! calendar/oracle call graphs).

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::Tok;
use super::{FileCtx, FileKind};

/// Every rule name, as accepted inside a waiver's `allow(...)`.
pub const RULES: [&str; 8] = [
    "wall-clock",
    "map-iteration",
    "thread-spawn",
    "float-reduce",
    "panic-hygiene",
    "deprecated-internal",
    "recorder-purity",
    "engine-parity",
];

/// A rule hit before waivers are applied.
#[derive(Debug, Clone)]
pub struct RawFinding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub snippet: String,
    pub hint: String,
}

/// Run every rule over the corpus.
pub fn run_all(files: &[FileCtx]) -> Vec<RawFinding> {
    let mut out = Vec::new();
    for f in files {
        wall_clock(f, &mut out);
        map_iteration(f, &mut out);
        thread_spawn(f, &mut out);
        float_reduce(f, &mut out);
        panic_hygiene(f, &mut out);
        recorder_purity(f, &mut out);
    }
    deprecated_internal(files, &mut out);
    engine_parity(files, &mut out);
    out
}

// ---------------------------------------------------------------------
// Scoping helpers
// ---------------------------------------------------------------------

/// Does `module` match an allowlist entry?  Entries are exact module
/// paths, or prefixes when suffixed with `*` (`experiments*` covers
/// `experiments` and every `experiments::` submodule).
fn allowed(module: &str, allow: &[&str]) -> bool {
    allow.iter().any(|a| match a.strip_suffix('*') {
        Some(prefix) => module.starts_with(prefix),
        None => module == *a,
    })
}

fn is_ident(t: &str) -> bool {
    t.starts_with(|c: char| c.is_ascii_alphabetic() || c == '_')
}

fn is_upper_ident(t: &str) -> bool {
    t.starts_with(|c: char| c.is_ascii_uppercase())
}

/// Emit one finding per occurrence of any token pattern, outside
/// `#[cfg(test)]` regions.
fn flag_patterns(
    f: &FileCtx,
    rule: &'static str,
    pats: &[&[&str]],
    hint: &str,
    out: &mut Vec<RawFinding>,
) {
    flag_patterns_in(f, rule, pats, hint, 0, f.lex.toks.len(), out);
}

/// Same, restricted to the token range `[start, end)`.
fn flag_patterns_in(
    f: &FileCtx,
    rule: &'static str,
    pats: &[&[&str]],
    hint: &str,
    start: usize,
    end: usize,
    out: &mut Vec<RawFinding>,
) {
    let toks = &f.lex.toks;
    let end = end.min(toks.len());
    for pat in pats {
        if pat.is_empty() || end < pat.len() {
            continue;
        }
        for i in start..=(end - pat.len()) {
            if f.lex.test_mask[i] {
                continue;
            }
            if pat.iter().enumerate().all(|(k, p)| toks[i + k].text == *p) {
                out.push(RawFinding {
                    rule,
                    file: f.path.clone(),
                    line: toks[i].line,
                    snippet: f.lex.snippet(toks[i].line),
                    hint: hint.to_string(),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------
// wall-clock
// ---------------------------------------------------------------------

const WALL_CLOCK_ALLOW: &[&str] = &["runtime::executor", "report::bench", "experiments*"];
const WALL_CLOCK_PATS: &[&[&str]] = &[
    &["Instant", "::", "now"],
    &["SystemTime", "::", "now"],
    &["SystemTime", "::", "UNIX_EPOCH"],
];

fn wall_clock(f: &FileCtx, out: &mut Vec<RawFinding>) {
    if matches!(f.kind, FileKind::Test | FileKind::Bench | FileKind::Example)
        || allowed(&f.module, WALL_CLOCK_ALLOW)
    {
        return;
    }
    flag_patterns(
        f,
        "wall-clock",
        WALL_CLOCK_PATS,
        "simulated results must come from the event clock; host timing belongs in \
         runtime::executor / report::bench, or at a single per-run timer site with a waiver",
        out,
    );
}

// ---------------------------------------------------------------------
// map-iteration
// ---------------------------------------------------------------------

const MAP_ITER_SCOPE: &[&str] = &["coordinator*", "traffic::slo", "telemetry*", "mapping::service"];
const MAP_ITER_ALLOW: &[&str] = &["mapping::service"];
const MAP_ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "retain"];

/// Collect local names declared (or bound) with a `HashMap`/`HashSet`
/// type, by walking back from each type mention to `name:` / `name =`.
/// Purely name-based — the documented approximation detcheck makes.
fn map_names(toks: &[Tok]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if toks[i].text != "HashMap" && toks[i].text != "HashSet" {
            continue;
        }
        // Walk back over a `std::collections::` qualifier ...
        let mut k = i;
        while k >= 2 && toks[k - 1].text == "::" && is_ident(&toks[k - 2].text) {
            k -= 2;
        }
        // ... and over reference/mutability sigils.
        while k >= 1 && (toks[k - 1].text == "&" || toks[k - 1].text == "mut") {
            k -= 1;
        }
        if k >= 2 && toks[k - 1].text == ":" && is_ident(&toks[k - 2].text) {
            names.insert(toks[k - 2].text.clone());
        } else if k >= 2 && toks[k - 1].text == "=" && is_ident(&toks[k - 2].text) {
            names.insert(toks[k - 2].text.clone());
        }
    }
    names
}

fn map_iteration(f: &FileCtx, out: &mut Vec<RawFinding>) {
    if !allowed(&f.module, MAP_ITER_SCOPE) || allowed(&f.module, MAP_ITER_ALLOW) {
        return;
    }
    let names = map_names(&f.lex.toks);
    if names.is_empty() {
        return;
    }
    let hint = "HashMap/HashSet order is nondeterministic and leaks into results: look up \
                by key, or collect and sort the keys before draining";
    flag_map_iteration_in(f, &names, hint, 0, f.lex.toks.len(), out);
}

fn flag_map_iteration_in(
    f: &FileCtx,
    names: &BTreeSet<String>,
    hint: &str,
    start: usize,
    end: usize,
    out: &mut Vec<RawFinding>,
) {
    let toks = &f.lex.toks;
    let end = end.min(toks.len());
    for i in start..end {
        if f.lex.test_mask[i] {
            continue;
        }
        // `map.iter()` and friends.
        if toks[i].text == "."
            && i + 2 < end
            && MAP_ITER_METHODS.contains(&toks[i + 1].text.as_str())
            && toks[i + 2].text == "("
            && i > 0
            && names.contains(&toks[i - 1].text)
        {
            out.push(RawFinding {
                rule: "map-iteration",
                file: f.path.clone(),
                line: toks[i].line,
                snippet: f.lex.snippet(toks[i].line),
                hint: hint.to_string(),
            });
        }
        // `for pat in [&][mut] map { ... }`.
        if toks[i].text == "in" {
            let mut j = i + 1;
            while j < end && (toks[j].text == "&" || toks[j].text == "mut") {
                j += 1;
            }
            if j < end
                && names.contains(&toks[j].text)
                && toks.get(j + 1).map(|t| t.text.as_str()) != Some(".")
                && preceded_by_for(toks, i)
            {
                out.push(RawFinding {
                    rule: "map-iteration",
                    file: f.path.clone(),
                    line: toks[i].line,
                    snippet: f.lex.snippet(toks[i].line),
                    hint: hint.to_string(),
                });
            }
        }
    }
}

/// Is the `in` at `idx` part of a `for ... in` loop?  Scan back to the
/// nearest statement boundary looking for the `for` keyword.
fn preceded_by_for(toks: &[Tok], idx: usize) -> bool {
    let mut k = idx;
    let mut steps = 0;
    while k > 0 && steps < 64 {
        k -= 1;
        steps += 1;
        match toks[k].text.as_str() {
            "for" => return true,
            ";" | "{" | "}" => return false,
            _ => {}
        }
    }
    false
}

// ---------------------------------------------------------------------
// thread-spawn
// ---------------------------------------------------------------------

const THREAD_ALLOW: &[&str] = &["runtime::executor", "mapping::service"];
const THREAD_PATS: &[&[&str]] =
    &[&["thread", "::", "spawn"], &["thread", "::", "scope"], &["thread", "::", "Builder"]];

fn thread_spawn(f: &FileCtx, out: &mut Vec<RawFinding>) {
    if matches!(f.kind, FileKind::Test | FileKind::Bench | FileKind::Example)
        || allowed(&f.module, THREAD_ALLOW)
    {
        return;
    }
    flag_patterns(
        f,
        "thread-spawn",
        THREAD_PATS,
        "all parallelism funnels through runtime::executor's deterministic-merge pool (or \
         mapping::service's audited scoped section)",
        out,
    );
}

// ---------------------------------------------------------------------
// float-reduce
// ---------------------------------------------------------------------

const FLOAT_SCOPE: &[&str] = &["coordinator*", "traffic::slo"];
const FLOAT_PATS: &[&[&str]] =
    &[&["sum", "::", "<", "f64", ">"], &["product", "::", "<", "f64", ">"]];

fn float_reduce(f: &FileCtx, out: &mut Vec<RawFinding>) {
    if !allowed(&f.module, FLOAT_SCOPE) {
        return;
    }
    flag_patterns(
        f,
        "float-reduce",
        FLOAT_PATS,
        "float addition is non-associative: reduce with an explicit sequential fold \
         (`.fold(0.0, |acc, x| acc + x)`) so the order is pinned in the source",
        out,
    );
}

// ---------------------------------------------------------------------
// panic-hygiene
// ---------------------------------------------------------------------

const PANIC_ALLOW: &[&str] = &["runtime::executor", "mapping::service", "experiments*"];

fn panic_hygiene(f: &FileCtx, out: &mut Vec<RawFinding>) {
    if f.kind != FileKind::Lib || allowed(&f.module, PANIC_ALLOW) {
        return;
    }
    let toks = &f.lex.toks;
    let hint = "library code returns errors instead of panicking: propagate with `?` / \
                `anyhow::bail!`, or restructure so the invariant needs no panicking call";
    for i in 0..toks.len() {
        if f.lex.test_mask[i] {
            continue;
        }
        // `.unwrap()` / `.expect(...)`.
        let method_panic = toks[i].text == "."
            && i + 2 < toks.len()
            && (toks[i + 1].text == "unwrap" || toks[i + 1].text == "expect")
            && toks[i + 2].text == "(";
        // `panic!` / `todo!` / `unimplemented!` (`unreachable!` and the
        // assert family are allowed — see docs/analysis.md).
        let macro_panic = matches!(toks[i].text.as_str(), "panic" | "todo" | "unimplemented")
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("!");
        if method_panic || macro_panic {
            out.push(RawFinding {
                rule: "panic-hygiene",
                file: f.path.clone(),
                line: toks[i].line,
                snippet: f.lex.snippet(toks[i].line),
                hint: hint.to_string(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// recorder-purity
// ---------------------------------------------------------------------

fn recorder_purity(f: &FileCtx, out: &mut Vec<RawFinding>) {
    let hint = "telemetry::Recorder impls and Scheduler::preempt_horizon are documented pure \
                observers: no clocks, no threads, no order-dependent iteration or reduction";
    let mut spans: Vec<(usize, usize)> = Vec::new();
    for im in &f.lex.impls {
        if trait_of_impl(&im.header).as_deref() == Some("Recorder") {
            spans.push((im.start, im.end));
        }
    }
    for fnsp in &f.lex.fns {
        if fnsp.name == "preempt_horizon" {
            spans.push((fnsp.start, fnsp.end));
        }
    }
    if spans.is_empty() {
        return;
    }
    let names = map_names(&f.lex.toks);
    for (start, end) in spans {
        if f.lex.test_mask.get(start).copied().unwrap_or(false) {
            continue; // test doubles get a pass
        }
        flag_patterns_in(f, "recorder-purity", WALL_CLOCK_PATS, hint, start, end, out);
        flag_patterns_in(f, "recorder-purity", THREAD_PATS, hint, start, end, out);
        flag_patterns_in(f, "recorder-purity", FLOAT_PATS, hint, start, end, out);
        if !names.is_empty() {
            flag_map_iteration_in(f, &names, hint, start, end, out);
        }
    }
}

/// The trait name of an `impl Trait for Type` header (the identifier
/// just before `for`, skipping a trailing generic list); `None` for
/// inherent impls.
fn trait_of_impl(header: &[String]) -> Option<String> {
    let p = header.iter().position(|t| t == "for")?;
    let mut depth = 0i32;
    let mut k = p;
    while k > 0 {
        k -= 1;
        match header[k].as_str() {
            ">" => depth += 1,
            "<" => depth -= 1,
            t if depth == 0 && is_ident(t) => return Some(t.to_string()),
            _ => {}
        }
    }
    None
}

/// The self type of an `impl` header: after `for` when present,
/// otherwise the first identifier past the leading generic list.
fn self_type_of_impl(header: &[String]) -> Option<String> {
    if let Some(p) = header.iter().position(|t| t == "for") {
        return header[p + 1..].iter().find(|t| is_ident(t)).cloned();
    }
    let mut i = 0;
    if header.first().map(String::as_str) == Some("<") {
        let mut depth = 0i32;
        while i < header.len() {
            match header[i].as_str() {
                "<" => depth += 1,
                ">" => depth -= 1,
                _ => {}
            }
            i += 1;
            if depth == 0 {
                break;
            }
        }
    }
    header[i..].iter().find(|t| is_ident(t)).cloned()
}

// ---------------------------------------------------------------------
// deprecated-internal
// ---------------------------------------------------------------------

fn deprecated_internal(files: &[FileCtx], out: &mut Vec<RawFinding>) {
    // Phase A: collect `#[deprecated]` associated fns corpus-wide, as
    // (self type, fn name, defining module).
    let mut shims: Vec<(String, String, String)> = Vec::new();
    for f in files {
        let toks = &f.lex.toks;
        for i in 0..toks.len() {
            if toks[i].text != "#"
                || toks.get(i + 1).map(|t| t.text.as_str()) != Some("[")
                || toks.get(i + 2).map(|t| t.text.as_str()) != Some("deprecated")
            {
                continue;
            }
            // Scan forward for the `fn` this attribute decorates.
            let mut j = i + 3;
            let mut fn_name = None;
            while j + 1 < toks.len() {
                match toks[j].text.as_str() {
                    "fn" => {
                        fn_name = Some((toks[j + 1].text.clone(), j));
                        break;
                    }
                    "struct" | "enum" | "mod" | "trait" | "{" | ";" => break,
                    _ => j += 1,
                }
            }
            let Some((name, at)) = fn_name else { continue };
            // Innermost enclosing impl gives the self type.
            let ty = f
                .lex
                .impls
                .iter()
                .filter(|im| im.start < at && at < im.end)
                .max_by_key(|im| im.start)
                .and_then(|im| self_type_of_impl(&im.header));
            if let Some(ty) = ty {
                shims.push((ty, name, f.module.clone()));
            }
        }
    }
    if shims.is_empty() {
        return;
    }
    // Phase B: flag qualified calls outside the defining module.
    for f in files {
        for (ty, name, defmod) in &shims {
            if &f.module == defmod {
                continue;
            }
            let pat: &[&str] = &[ty, "::", name];
            flag_patterns_in(
                f,
                "deprecated-internal",
                &[pat],
                "construct through ClusterBuilder; the deprecated constructors exist only as \
                 compatibility shims",
                0,
                f.lex.toks.len(),
                out,
            );
        }
    }
}

// ---------------------------------------------------------------------
// engine-parity
// ---------------------------------------------------------------------

/// Variants the calendar engine may emit without an oracle counterpart:
/// the oracle prices per iteration and never materializes a bucket edge.
const CALENDAR_ONLY: &[&str] = &["BucketEdge"];

#[derive(Default)]
struct FnInfo {
    mentions: BTreeSet<String>,
    calls: BTreeSet<String>,
}

fn engine_parity(files: &[FileCtx], out: &mut Vec<RawFinding>) {
    // 1. The EventKind enum and its variants.
    let mut variants: Vec<(String, String, u32)> = Vec::new();
    'files: for f in files {
        let toks = &f.lex.toks;
        for i in 0..toks.len().saturating_sub(1) {
            if toks[i].text != "enum" || toks[i + 1].text != "EventKind" {
                continue;
            }
            let mut open = i + 2;
            while open < toks.len() && toks[open].text != "{" {
                open += 1;
            }
            if open >= toks.len() {
                continue;
            }
            let end = brace_end(toks, open);
            let mut depth = 1i32;
            let mut prev = "{".to_string();
            for tok in toks.iter().take(end.saturating_sub(1)).skip(open + 1) {
                let t = tok.text.as_str();
                if t == "{" {
                    depth += 1;
                } else if t == "}" {
                    depth -= 1;
                }
                if depth == 1 {
                    if is_upper_ident(t) && matches!(prev.as_str(), "{" | "," | "]") {
                        variants.push((t.to_string(), f.path.clone(), tok.line));
                    }
                    prev = t.to_string();
                }
            }
            break 'files;
        }
    }
    if variants.is_empty() {
        return;
    }
    let variant_set: BTreeSet<&str> = variants.iter().map(|(v, _, _)| v.as_str()).collect();

    // 2. The engine file: the one defining the calendar round.
    let engine = files.iter().find(|f| {
        f.lex.fns.iter().any(|s| s.name == "round_calendar" || s.name == "run_calendar")
    });
    let Some(engine) = engine else { return };

    // 3. Per-fn emissions and local calls (test fns excluded).
    let mut fns: BTreeMap<String, FnInfo> = BTreeMap::new();
    for span in &engine.lex.fns {
        if engine.lex.test_mask.get(span.start).copied().unwrap_or(false) {
            continue;
        }
        let info = fns.entry(span.name.clone()).or_default();
        let toks = &engine.lex.toks;
        let end = span.end.min(toks.len());
        for k in span.start..end {
            if toks[k].text == "EventKind"
                && k + 2 < end
                && toks[k + 1].text == "::"
                && variant_set.contains(toks[k + 2].text.as_str())
            {
                info.mentions.insert(toks[k + 2].text.clone());
            }
            if is_ident(&toks[k].text)
                && toks.get(k + 1).map(|t| t.text.as_str()) == Some("(")
            {
                info.calls.insert(toks[k].text.clone());
            }
        }
    }

    // 4. Transitive emissions from each engine root.
    let root = |a: &str, b: &str| if fns.contains_key(a) { a.to_string() } else { b.to_string() };
    let reach_cal = reach(&fns, &root("round_calendar", "run_calendar"));
    let reach_ora = reach(&fns, &root("round_oracle", "run_oracle"));
    let engine_mentions: BTreeSet<String> =
        fns.values().flat_map(|i| i.mentions.iter().cloned()).collect();

    // 5. Variants emitted by the dispatch layer (other coordinator files).
    let mut other_mentions: BTreeSet<String> = BTreeSet::new();
    for f in files {
        if f.path == engine.path || !f.module.starts_with("coordinator") {
            continue;
        }
        let toks = &f.lex.toks;
        for k in 0..toks.len().saturating_sub(2) {
            if f.lex.test_mask[k] {
                continue;
            }
            if toks[k].text == "EventKind"
                && toks[k + 1].text == "::"
                && variant_set.contains(toks[k + 2].text.as_str())
            {
                other_mentions.insert(toks[k + 2].text.clone());
            }
        }
    }

    // 6. Verdicts, anchored at each variant's declaration.
    let hint = "every EventKind must be emitted by both engine paths (round_calendar and \
                round_oracle) or by the dispatch layer; BucketEdge is the documented \
                calendar-only exception — see docs/analysis.md";
    for (v, file, line) in &variants {
        let snippet = format!("EventKind::{v}");
        let push = |out: &mut Vec<RawFinding>, what: String| {
            out.push(RawFinding {
                rule: "engine-parity",
                file: file.clone(),
                line: *line,
                snippet: snippet.clone(),
                hint: format!("{what}; {hint}"),
            });
        };
        if engine_mentions.contains(v) {
            if CALENDAR_ONLY.contains(&v.as_str()) {
                if !reach_cal.contains(v) {
                    push(out, format!("calendar-only variant {v} is not reachable from the calendar engine"));
                }
            } else {
                match (reach_cal.contains(v), reach_ora.contains(v)) {
                    (true, true) => {}
                    (true, false) => push(out, format!("{v} reaches only the calendar engine; the oracle never emits it")),
                    (false, true) => push(out, format!("{v} reaches only the oracle engine; the calendar never emits it")),
                    (false, false) => push(out, format!("{v} is emitted outside both engine round paths")),
                }
            }
        } else if !other_mentions.contains(v) {
            push(out, format!("{v} has no emission site in coordinator code"));
        }
    }
}

/// Variants transitively mentioned from `root` through same-file calls.
fn reach(fns: &BTreeMap<String, FnInfo>, root: &str) -> BTreeSet<String> {
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut stack = vec![root.to_string()];
    let mut vars = BTreeSet::new();
    while let Some(name) = stack.pop() {
        if !seen.insert(name.clone()) {
            continue;
        }
        if let Some(info) = fns.get(&name) {
            vars.extend(info.mentions.iter().cloned());
            for c in &info.calls {
                if !seen.contains(c) {
                    stack.push(c.clone());
                }
            }
        }
    }
    vars
}

/// Token index one past the `}` matching the `{` at `open`.
fn brace_end(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
    }
    toks.len()
}
