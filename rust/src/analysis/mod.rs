//! `detcheck`: a determinism & purity static-analysis pass.
//!
//! The repo's headline results all rest on bit-identity contracts — the
//! calendar engine matches the per-iteration oracle, any worker-pool
//! size merges to the 1-thread report, the best-first mapping winner
//! equals the serial exhaustive reference, and recording a trace changes
//! nothing.  The dynamic gates (`tests/engine_equivalence.rs`, the
//! proptests) catch a violation after it is written; this module catches
//! the *source patterns* that cause them — wall-clock reads in simulated
//! paths, `HashMap` iteration order leaking into results, ad-hoc `f64`
//! reductions, stray threads — before they run.
//!
//! The pass is offline and dependency-free: [`lexer`] scrubs and
//! tokenizes each file, [`rules`] runs token-pattern checks scoped by
//! module path, and this module applies inline waivers and renders the
//! report.  Deliberate exceptions carry a comment of the form
//! `detcheck: allow(<rule>) -- <reason>` (the directive must lead the
//! comment; the reason is mandatory); a waiver that matches nothing is
//! itself a finding, so stale exceptions cannot accumulate.
//!
//! Run it as `cargo run --bin detcheck` (or `racam detcheck`) from the
//! `rust/` directory; see `docs/analysis.md` for the rule catalog.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::json::Value;

/// One file handed to [`analyze`]: a (possibly virtual) path plus its
/// source text.  The path drives rule scoping, so test fixtures can
/// impersonate any module.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub src: String,
}

/// What kind of target a file belongs to, derived from its path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FileKind {
    /// Library code under `src/` — the strictest tier.
    Lib,
    /// `src/bin/*` and `src/main.rs`.
    Bin,
    /// Integration tests under `tests/`.
    Test,
    /// `benches/`.
    Bench,
    /// `examples/`.
    Example,
}

/// A lexed file plus its rule-scoping identity.
pub struct FileCtx {
    pub path: String,
    /// Module path under `src/` (`coordinator::server`); empty for
    /// `lib.rs` and non-library targets.
    pub module: String,
    pub kind: FileKind,
    pub lex: lexer::Lexed,
}

/// One reported problem.  `waived` carries the reason from a matching
/// inline waiver; unwaived findings fail the run.
#[derive(Debug, Clone)]
pub struct Finding {
    /// A rule name from [`rules::RULES`], or `"waiver"` for waiver
    /// hygiene problems (malformed, unknown rule, unused).
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub snippet: String,
    pub hint: String,
    pub waived: Option<String>,
}

/// The result of one analysis pass.
pub struct Report {
    /// Files scanned.
    pub files: usize,
    /// All findings, waived and not, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn unwaived_count(&self) -> usize {
        self.findings.iter().filter(|f| f.waived.is_none()).count()
    }

    pub fn waived_count(&self) -> usize {
        self.findings.iter().filter(|f| f.waived.is_some()).count()
    }

    /// Human-readable report: unwaived findings with hints, then the
    /// accepted waivers, then a one-line summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for f in self.findings.iter().filter(|f| f.waived.is_none()) {
            s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.snippet));
            s.push_str(&format!("    hint: {}\n", f.hint));
        }
        let waived: Vec<&Finding> = self.findings.iter().filter(|f| f.waived.is_some()).collect();
        if !waived.is_empty() {
            s.push_str("waived:\n");
            for f in &waived {
                let reason = f.waived.as_deref().unwrap_or("");
                s.push_str(&format!("  {}:{}: [{}] {} -- {}\n", f.file, f.line, f.rule, f.snippet, reason));
            }
        }
        s.push_str(&format!(
            "detcheck: {} unwaived finding(s), {} waived, {} file(s) scanned\n",
            self.unwaived_count(),
            self.waived_count(),
            self.files,
        ));
        s
    }

    /// Machine-readable report (written to `detcheck.json` in CI).
    pub fn to_json(&self) -> Value {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Value::obj(vec![
                    ("file", Value::Str(f.file.clone())),
                    ("line", Value::Num(f.line as f64)),
                    ("rule", Value::Str(f.rule.to_string())),
                    ("snippet", Value::Str(f.snippet.clone())),
                    ("hint", Value::Str(f.hint.clone())),
                    ("waived", Value::Bool(f.waived.is_some())),
                    ("reason", Value::Str(f.waived.clone().unwrap_or_default())),
                ])
            })
            .collect();
        Value::obj(vec![
            ("files", Value::Num(self.files as f64)),
            ("unwaived", Value::Num(self.unwaived_count() as f64)),
            ("waived", Value::Num(self.waived_count() as f64)),
            ("findings", Value::Arr(findings)),
        ])
    }
}

/// Derive (module path, file kind) from a path like
/// `src/coordinator/server.rs` or `tests/detcheck.rs`.
fn classify(path: &str) -> (String, FileKind) {
    let norm = path.replace('\\', "/");
    let comps: Vec<&str> = norm.split('/').filter(|c| !c.is_empty() && *c != ".").collect();
    if let Some(si) = comps.iter().position(|c| *c == "src") {
        let rest = &comps[si + 1..];
        if rest.first().copied() == Some("bin") || rest == ["main.rs"] {
            return ("bin".to_string(), FileKind::Bin);
        }
        let mut parts: Vec<String> = rest
            .iter()
            .map(|c| c.trim_end_matches(".rs").to_string())
            .filter(|c| !c.is_empty())
            .collect();
        if parts.last().map(String::as_str) == Some("mod") {
            parts.pop();
        }
        if parts.last().map(String::as_str) == Some("lib") {
            parts.pop();
        }
        return (parts.join("::"), FileKind::Lib);
    }
    if comps.contains(&"tests") {
        return (String::new(), FileKind::Test);
    }
    if comps.contains(&"benches") {
        return (String::new(), FileKind::Bench);
    }
    if comps.contains(&"examples") {
        return (String::new(), FileKind::Example);
    }
    (String::new(), FileKind::Lib)
}

/// Analyze a set of (path, source) pairs: lex, run every rule, apply
/// inline waivers, and report waiver-hygiene problems.
pub fn analyze(files: &[SourceFile]) -> Report {
    let ctxs: Vec<FileCtx> = files
        .iter()
        .map(|s| {
            let (module, kind) = classify(&s.path);
            FileCtx { path: s.path.clone(), module, kind, lex: lexer::lex(&s.src) }
        })
        .collect();
    let raw = rules::run_all(&ctxs);

    let index: BTreeMap<&str, usize> =
        ctxs.iter().enumerate().map(|(i, c)| (c.path.as_str(), i)).collect();
    let mut used: Vec<Vec<bool>> = ctxs.iter().map(|c| vec![false; c.lex.waivers.len()]).collect();
    let mut findings: Vec<Finding> = Vec::new();

    for rf in raw {
        let mut waived = None;
        if let Some(&ci) = index.get(rf.file.as_str()) {
            for (wi, w) in ctxs[ci].lex.waivers.iter().enumerate() {
                if w.rule == rf.rule && w.covers == rf.line {
                    if let Some(reason) = &w.reason {
                        waived = Some(reason.clone());
                        used[ci][wi] = true;
                        break;
                    }
                }
            }
        }
        findings.push(Finding {
            rule: rf.rule,
            file: rf.file,
            line: rf.line,
            snippet: rf.snippet,
            hint: rf.hint,
            waived,
        });
    }

    // Waiver hygiene: malformed, unknown-rule, and unused waivers are
    // findings themselves (and can never be waived).
    for (ci, ctx) in ctxs.iter().enumerate() {
        for (wi, w) in ctx.lex.waivers.iter().enumerate() {
            let problem = if !rules::RULES.contains(&w.rule.as_str()) {
                Some(format!("waiver names unknown rule '{}'", w.rule))
            } else if w.reason.is_none() {
                Some(format!(
                    "malformed waiver for '{}': a `-- <reason>` is mandatory",
                    w.rule
                ))
            } else if !used[ci][wi] {
                Some(format!(
                    "unused waiver for '{}': nothing on line {} triggers the rule",
                    w.rule, w.covers
                ))
            } else {
                None
            };
            if let Some(p) = problem {
                findings.push(Finding {
                    rule: "waiver",
                    file: ctx.path.clone(),
                    line: w.line,
                    snippet: ctx.lex.snippet(w.line),
                    hint: p,
                    waived: None,
                });
            }
        }
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.hint.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.hint.as_str()))
    });
    Report { files: ctxs.len(), findings }
}

/// Shared CLI driver for the `detcheck` bin and the `racam detcheck`
/// subcommand: `detcheck [DIR|FILE ...] [--json PATH]`.  With no
/// explicit paths it scans `src` and `tests` under the current
/// directory (run it from `rust/`).
pub fn run_cli(args: &[String]) -> Result<Report> {
    let mut paths: Vec<String> = Vec::new();
    let mut json_out: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => {
                json_out = Some(it.next().context("--json needs a path")?.clone());
            }
            other => paths.push(other.to_string()),
        }
    }
    if paths.is_empty() {
        for d in ["src", "tests"] {
            if Path::new(d).is_dir() {
                paths.push(d.to_string());
            }
        }
    }
    if paths.is_empty() {
        bail!("no source directories found: run from rust/ or pass directories explicitly");
    }
    let mut sources = Vec::new();
    for p in &paths {
        collect_sources(Path::new(p), &mut sources)?;
    }
    sources.sort_by(|a, b| a.path.cmp(&b.path));
    let report = analyze(&sources);
    if let Some(p) = json_out {
        if let Some(dir) = Path::new(&p).parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(&p, report.to_json().pretty())
            .with_context(|| format!("writing {p}"))?;
    }
    Ok(report)
}

/// Recursively gather `.rs` files.  Skips build output (`target`),
/// vendored dependencies (`vendor`), and the analyzer's own
/// deliberately-violating test corpus (`detcheck_fixtures`).
fn collect_sources(path: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    if path.is_dir() {
        let name = path.file_name().map(|n| n.to_string_lossy().to_string()).unwrap_or_default();
        if matches!(name.as_str(), "target" | "vendor" | "detcheck_fixtures") {
            return Ok(());
        }
        let mut entries: Vec<_> = std::fs::read_dir(path)
            .with_context(|| format!("reading {}", path.display()))?
            .collect::<std::io::Result<Vec<_>>>()
            .with_context(|| format!("reading {}", path.display()))?
            .into_iter()
            .map(|e| e.path())
            .collect();
        entries.sort();
        for e in entries {
            collect_sources(&e, out)?;
        }
    } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let p = path.to_string_lossy().replace('\\', "/");
        out.push(SourceFile { path: p, src });
    }
    Ok(())
}
