//! H100 (PCIe) roofline model, standing in for the LLMCompass simulation of
//! the paper's methodology (§5.4).  Per kernel the latency is the max of
//! the compute roofline, the HBM traffic roofline and — when the model's
//! weights exceed HBM capacity — the offload-link streaming time (Table 4:
//! 512 GB host memory offloads the weights, as on Grace-Hopper).

use crate::config::{LlmSpec, MatmulShape};
use crate::metrics::LatencyBreakdown;
use crate::workloads::CostModel;

/// H100 PCIe + 512 GB offload memory (paper Table 4).
#[derive(Debug, Clone)]
pub struct H100Model {
    /// Peak int8 tensor-core throughput, ops/s (Table 4: 1978.9 TOPS).
    pub peak_int8_ops: f64,
    /// HBM3 bandwidth, bytes/s (Table 4: 3352 GB/s).
    pub hbm_bw: f64,
    /// HBM capacity, bytes (80 GB).
    pub hbm_bytes: u64,
    /// Host↔GPU offload bandwidth, bytes/s (Grace-Hopper NVLink-C2C class).
    pub offload_bw: f64,
    /// Achievable fraction of peak compute on dense GEMMs (MFU).
    pub gemm_efficiency: f64,
    /// Achievable fraction of peak bandwidth on streaming GEMVs.
    pub bw_efficiency: f64,
    /// Weights resident in HBM?  Set per model via [`Self::for_model`].
    pub weights_offloaded: bool,
}

impl Default for H100Model {
    fn default() -> Self {
        H100Model {
            peak_int8_ops: 1978.9e12,
            hbm_bw: 3352e9,
            hbm_bytes: 80 * (1 << 30),
            offload_bw: 256e9,
            gemm_efficiency: 0.60,
            bw_efficiency: 0.80,
            weights_offloaded: false,
        }
    }
}

impl H100Model {
    /// Configure for an LLM: weights stream from host memory when the int8
    /// checkpoint exceeds the 80 GB HBM (GPT-3 175B does; 6.7B/8B don't).
    pub fn for_model(spec: &LlmSpec) -> Self {
        let mut m = H100Model::default();
        m.weights_offloaded = spec.weight_bytes() > m.hbm_bytes;
        m
    }

    /// Roofline latency of one kernel, ns.
    pub fn kernel_ns(&self, shape: &MatmulShape) -> f64 {
        let compute_ns = shape.ops() as f64 / (self.peak_int8_ops * self.gemm_efficiency) * 1e9;
        // Weight bytes stream from HBM (resident) or over the offload link.
        let act_bytes = (shape.input_bytes() + shape.output_bytes()) as f64;
        let weight_bytes = shape.weight_bytes() as f64;
        let (hbm_bytes, offload_bytes) = if shape.weight_static && self.weights_offloaded {
            (act_bytes, weight_bytes)
        } else {
            (act_bytes + weight_bytes, 0.0)
        };
        let hbm_ns = hbm_bytes / (self.hbm_bw * self.bw_efficiency) * 1e9;
        let offload_ns = offload_bytes / self.offload_bw * 1e9;
        // Kernel-launch floor: even tiny GEMVs cost a few µs on a GPU.
        const LAUNCH_NS: f64 = 4_000.0;
        compute_ns.max(hbm_ns).max(offload_ns).max(LAUNCH_NS)
    }
}

impl CostModel for H100Model {
    fn name(&self) -> &str {
        "H100"
    }

    fn kernel_cost(&self, shape: &MatmulShape) -> Option<LatencyBreakdown> {
        Some(LatencyBreakdown::new(self.kernel_ns(shape), 0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpt3_175b, gpt3_6_7b, MatmulShape, Precision};

    #[test]
    fn big_gemm_is_compute_bound() {
        let m = H100Model::default();
        let s = MatmulShape::new(8192, 8192, 8192, Precision::Int8);
        let ns = m.kernel_ns(&s);
        let compute = s.ops() as f64 / (m.peak_int8_ops * m.gemm_efficiency) * 1e9;
        assert!((ns - compute).abs() / compute < 1e-9);
    }

    #[test]
    fn gemv_is_bandwidth_bound() {
        let m = H100Model::default();
        let s = MatmulShape::new(1, 12288, 12288, Precision::Int8);
        let ns = m.kernel_ns(&s);
        let bw_ns = s.weight_bytes() as f64 / (m.hbm_bw * m.bw_efficiency) * 1e9;
        assert!((ns - bw_ns).abs() / bw_ns < 0.05, "{ns} vs {bw_ns}");
    }

    #[test]
    fn offloaded_weights_dominate_gemv() {
        let resident = H100Model::for_model(&gpt3_6_7b());
        let offloaded = H100Model::for_model(&gpt3_175b());
        assert!(!resident.weights_offloaded);
        assert!(offloaded.weights_offloaded);
        let s = MatmulShape::new(1, 12288, 12288, Precision::Int8);
        assert!(offloaded.kernel_ns(&s) > 5.0 * resident.kernel_ns(&s));
    }

    #[test]
    fn launch_floor_applies_to_tiny_kernels() {
        let m = H100Model::default();
        assert_eq!(m.kernel_ns(&MatmulShape::new(1, 64, 64, Precision::Int8)), 4_000.0);
    }

    #[test]
    fn dynamic_weights_never_offload() {
        let mut m = H100Model::default();
        m.weights_offloaded = true;
        let s = MatmulShape::dynamic(128, 128, 4096, Precision::Int8);
        // Attention operands are activations: they live in HBM.
        let ns = m.kernel_ns(&s);
        let offload_ns = s.weight_bytes() as f64 / m.offload_bw * 1e9;
        assert!(ns < offload_ns.max(4_000.0) + 1e6); // not offload-priced
    }
}
