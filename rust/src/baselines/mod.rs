//! Baseline system models (paper Table 4): the NVIDIA H100 GPU (priced with
//! an LLMCompass-style roofline) and Proteus, the state-of-the-art
//! processing-using-DRAM system (bit-serial, no bit reuse, no broadcast,
//! no in-DRAM reduction).
//!
//! Both implement [`crate::workloads::CostModel`] uniformly with
//! [`crate::workloads::RacamSystem`], so experiments and the serving
//! coordinator price any system through the same interface.

mod h100;
mod proteus;

pub use h100::H100Model;
pub use proteus::ProteusModel;
