//! Proteus baseline (paper Table 4 / Table 5): the state-of-the-art
//! processing-using-DRAM system.  Bit-serial like RACAM, but:
//!
//! * **no bit-level reuse** — every multiplier bit re-reads the multiplicand
//!   from the cell array, so an n-bit multiply costs O(n²) row cycles;
//! * **no broadcast units** — the host explicitly writes dynamic operands
//!   into every participating bank (`#Banks × Bytes` channel traffic, §1);
//! * **no reduction units** — partial sums are read out and reduced by the
//!   host CPU.
//!
//! Calibration anchor: Table 4 credits the Proteus system (DDR5-5200,
//! 1 channel / 1 rank / 16 banks) with 0.15 int8 TOPS.

use crate::config::{MatmulShape, Precision};
use crate::metrics::LatencyBreakdown;
use crate::workloads::CostModel;

#[derive(Debug, Clone)]
pub struct ProteusModel {
    pub banks: u64,
    /// SIMD columns per bank (an 8 KB DDR5 row buffer = 65536 bitlines).
    pub cols_per_bank: u64,
    /// Full row cycle (ACT→PRE→ready), ns.
    pub t_rc_ns: f64,
    /// Channel bandwidth, bytes/s (one DDR5-5200 x64 channel).
    pub channel_bw: f64,
    /// Host-side add, ns per element (amortized SIMD cost).
    pub host_add_ns: f64,
    /// Achieved fraction of peak throughput.  Proteus's published GEMM
    /// results are far below its theoretical peak (per-operand transposes,
    /// row-buffer fragmentation, AAP command sequencing, per-kernel
    /// reconfiguration), which is why the paper finds it "poor … compared
    /// to GPUs" even though Table 4 credits it 0.15 peak TOPS.
    pub achieved_efficiency: f64,
    /// PIM-enabled DRAM capacity, bytes (1 rank of 8 × 16 Gb devices);
    /// larger models stream weights from the offload memory over the one
    /// channel.
    pub pim_capacity: u64,
    /// Weights exceed the PIM capacity and stream from offload memory.
    pub weights_offloaded: bool,
}

impl Default for ProteusModel {
    fn default() -> Self {
        ProteusModel {
            banks: 16,
            cols_per_bank: 65536,
            t_rc_ns: 48.0,
            channel_bw: 41.6e9,
            host_add_ns: 1.0 / 16.0,
            achieved_efficiency: 0.08,
            pim_capacity: 16 * (1 << 30),
            weights_offloaded: false,
        }
    }
}

impl ProteusModel {
    /// Configure for an LLM: weights stream over the single channel when
    /// the checkpoint exceeds the PIM-enabled capacity.
    pub fn for_model(spec: &crate::config::LlmSpec) -> Self {
        let mut m = ProteusModel::default();
        m.weights_offloaded = spec.weight_bytes() > m.pim_capacity;
        m
    }
}

impl ProteusModel {
    /// Row operations of one n-bit multiply without bit reuse (Table 5:
    /// O(n²)): each of the n partial products re-streams the n multiplicand
    /// planes and read-modify-writes the result window (3 row ops per
    /// plane per step in the majority-based PUD scheme).
    pub fn mul_row_ops(n: u64) -> u64 {
        3 * n * n + 2 * n
    }

    /// Bit-serial SIMD multiply pass latency over one bank's columns, ns.
    pub fn mul_pass_ns(&self, prec: Precision) -> f64 {
        Self::mul_row_ops(prec.bits() as u64) as f64 * self.t_rc_ns
    }

    /// Peak int-n MAC throughput (system-wide), MAC/s — the Table 4 TOPS
    /// anchor divided by 2 ops/MAC.
    pub fn peak_macs(&self, prec: Precision) -> f64 {
        let per_pass_macs = (self.banks * self.cols_per_bank) as f64;
        // A reduction over K costs ~log2(cols) extra add passes worth of
        // row ops, folded into an effective 1.30 overhead factor.
        per_pass_macs / (self.mul_pass_ns(prec) * 1.30) * 1e9
    }

    pub fn peak_tops(&self, prec: Precision) -> f64 {
        2.0 * self.peak_macs(prec) / 1e12
    }

    /// Achieved compute latency for one kernel, ns.
    pub fn compute_ns(&self, shape: &MatmulShape) -> f64 {
        shape.macs() as f64 / (self.peak_macs(shape.prec) * self.achieved_efficiency) * 1e9
    }

    /// Kernel latency, ns.
    pub fn kernel_ns(&self, shape: &MatmulShape) -> f64 {
        let compute_ns = self.compute_ns(shape);
        // Input: host replicates the dynamic operand into every bank.
        let mut in_bytes = shape.input_bytes() as f64 * self.banks as f64;
        if !shape.weight_static {
            in_bytes += shape.weight_bytes() as f64 * self.banks as f64;
        } else if self.weights_offloaded {
            // Static weights that don't fit in the PIM DRAM stream in from
            // offload memory (laid out once per use, no replication).
            in_bytes += shape.weight_bytes() as f64;
        }
        // Output: partial sums from every bank, host-reduced.
        let out_bytes = (shape.output_bytes() * self.banks) as f64;
        let host_ns = (self.banks - 1) as f64 * (shape.m * shape.n) as f64 * self.host_add_ns;
        let io_ns = (in_bytes + out_bytes) / self.channel_bw * 1e9 + host_ns;
        compute_ns + io_ns
    }
}

impl CostModel for ProteusModel {
    fn name(&self) -> &str {
        "Proteus"
    }

    fn kernel_cost(&self, shape: &MatmulShape) -> Option<LatencyBreakdown> {
        // Split for reporting: compute vs host I/O.
        let compute_ns = self.compute_ns(shape);
        let total = self.kernel_ns(shape);
        Some(LatencyBreakdown::new(compute_ns, total - compute_ns))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, HwConfig};

    #[test]
    fn int8_tops_matches_table4() {
        let p = ProteusModel::default();
        let tops = p.peak_tops(Precision::Int8);
        assert!((tops - 0.15).abs() < 0.02, "Proteus int8 TOPS {tops}");
    }

    #[test]
    fn row_ops_are_quadratic() {
        assert!(ProteusModel::mul_row_ops(16) > 3 * ProteusModel::mul_row_ops(8));
    }

    #[test]
    fn racam_peak_is_orders_of_magnitude_higher() {
        // Table 4: 986.9 vs 0.15 TOPS.
        let racam: HwConfig = racam_paper();
        let ratio = racam.peak_tops(Precision::Int8) / ProteusModel::default().peak_tops(Precision::Int8);
        assert!(ratio > 1000.0, "ratio {ratio}");
    }

    #[test]
    fn io_includes_bank_replication() {
        let p = ProteusModel::default();
        let s = MatmulShape::new(1, 4096, 4096, Precision::Int8);
        let b = p.kernel_cost(&s).unwrap();
        assert!(b.io_ns > 0.0);
        // Host writes #banks copies of the 4 KB input = 64 KB min.
        let min_io_ns = (16.0 * 4096.0) / p.channel_bw * 1e9;
        assert!(b.io_ns > min_io_ns);
    }

    #[test]
    fn precision_scaling_is_quadratic_in_compute() {
        let p = ProteusModel::default();
        let s8 = MatmulShape::new(64, 4096, 64, Precision::Int8);
        let s4 = MatmulShape { prec: Precision::Int4, ..s8 };
        let c8 = s8.macs() as f64 / p.peak_macs(Precision::Int8);
        let c4 = s4.macs() as f64 / p.peak_macs(Precision::Int4);
        let ratio = c8 / c4;
        assert!(ratio > 3.0, "O(n²) scaling gives ≳4x from int8→int4, got {ratio}");
    }
}
