//! `racam` — CLI for the RACAM reproduction.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! racam map  <M> <K> <N> [--prec 8] [--all]     search a GEMM mapping
//! racam llm  <model> [--stage prefill|decode|e2e] [--scenario code|ctx]
//! racam area                                     area report (§5.2)
//! racam config [--dump cfg.json | --load cfg.json]
//! racam experiments <id|all>                     regenerate paper artifacts
//! ```

use racam::area::AreaModel;
use racam::config::{self, racam_paper, HwConfig, MatmulShape, Precision, Scenario};
use racam::experiments;
use racam::mapping::MappingService;
use racam::metrics::fmt_ns;
use racam::workloads::{self, RacamSystem};
use racam::Result;

fn main() {
    if let Err(e) = run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Vec<String>) -> Result<()> {
    let mut it = args.into_iter();
    match it.next().as_deref() {
        Some("map") => cmd_map(it.collect()),
        Some("llm") => cmd_llm(it.collect()),
        Some("area") => cmd_area(),
        Some("config") => cmd_config(it.collect()),
        Some("experiments") => cmd_experiments(it.collect()),
        Some("serve") => cmd_serve(it.collect()),
        Some("detcheck") => cmd_detcheck(it.collect()),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "racam — reuse-aware in-DRAM PIM simulator + automated mapping\n\
         \n\
         usage:\n\
         \x20 racam map <M> <K> <N> [--prec BITS] [--all] [--store FILE]\n\
         \x20 racam llm <gpt3-6.7b|gpt3-175b|llama3-8b|llama3-70b> [--stage prefill|decode|e2e] [--scenario code|ctx]\n\
         \x20 racam area\n\
         \x20 racam config [--dump FILE | --load FILE]\n\
         \x20 racam experiments <fig1|fig9|...|ext-trace|traffic|prefill|disagg|scale|all>\n\
         \x20 racam detcheck [DIR ...] [--json FILE]\n\
         \x20 racam serve [--requests N] [--tokens N] [--batch N] [--shards N] [--synthetic]\n\
         \x20             [--mapping-cache FILE] [--warm-store FILE]\n\
         \x20             [--sched fcfs|bucket|edf] [--rate R]\n\
         \x20             [--deadline-ms MS] [--traffic SPEC.json | --trace TRACE.json]\n\
         \x20             [--chunk-tokens N] [--preempt] [--serving POLICY.json]\n\
         \x20             [--engine calendar|oracle] [--cluster CLUSTER.json]\n\
         \x20             [--faults FAULTS.json] [--threads N]\n\
         \x20             [--trace-out TRACE.json] [--metrics]\n\
         \n\
         serve traffic modes: --rate R replays a Poisson stream at R req/s on the\n\
         simulated clock (add --deadline-ms for an e2e SLO); --traffic loads a\n\
         TrafficSpec JSON; --trace replays a recorded trace. All three print SLO\n\
         tables (TTFT/TPOT tails, goodput, shed counts).\n\
         \n\
         serving policy: --chunk-tokens N bounds each prefill step to N prompt\n\
         tokens (chunked prefill; unset = whole-prompt, the paper schedule);\n\
         --preempt lets deadline-aware schedulers (edf) shed past-deadline work;\n\
         --serving loads a ServingPolicy JSON instead of the two flags;\n\
         --engine picks the serving-loop implementation (calendar = the\n\
         fast-forwarding event-calendar engine, the default; oracle = the\n\
         per-iteration reference — bit-identical simulated results);\n\
         --threads N pins the host worker pool that runs the shard loops\n\
         (default: the RACAM_THREADS env var, else all cores; simulated\n\
         results are bit-identical for every value).\n\
         \n\
         mapping warm store: --warm-store attaches a persistent shared mapping\n\
         table (docs/mapping.md): every shard service loads it at startup and\n\
         merges its searches back atomically on exit, so concurrent and\n\
         repeated runs fold one table; --mapping-cache is the legacy\n\
         shard-0-only load/save pair.\n\
         \n\
         cluster: --cluster loads a ClusterSpec JSON declaring shard groups\n\
         (count, role unified|prefill|decode, scheduler, policy, channel share,\n\
         kv_link_gbps) and replaces --shards/--batch/--sched/--chunk-tokens/\n\
         --preempt/--serving. Prefill groups hand finished prompts to decode\n\
         groups over the simulated KV link (see docs/serving.md).\n\
         \n\
         faults: --faults loads a FaultSpec JSON — a seeded schedule of\n\
         simulated-time fault events (shard crashes, brownouts, KV-link\n\
         outages/degradation, DRAM channel loss) plus a recovery policy\n\
         (retry budget, backoff, utilization ceiling). The run prints an\n\
         availability table and, with --trace-out, exports the injections\n\
         on a dedicated 'faults' track. Same spec + seed = bit-identical\n\
         reports across engines and thread counts (docs/robustness.md).\n\
         \n\
         detcheck: static determinism & purity gate (docs/analysis.md) — scans\n\
         src/ and tests/ (or the given dirs) for wall-clock reads, HashMap\n\
         iteration, stray threads, ad-hoc f64 reductions, panicking library\n\
         code, deprecated-constructor calls, and engine-parity gaps; fails on\n\
         any unwaived finding; --json writes the machine-readable report.\n\
         \n\
         telemetry: --trace-out writes a Chrome-trace/Perfetto JSON of the run\n\
         (tracks: one per shard + the KV link on the simulated-ns timeline,\n\
         plus host-executor workers on wall ns); --metrics prints the\n\
         deterministic counters + log-bucketed histograms (TTFT/TPOT/queue\n\
         depth/batch occupancy). Recording never perturbs the simulation —\n\
         results stay bit-identical (see docs/observability.md)."
    );
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1).cloned())
}

/// `racam detcheck [DIR ...] [--json FILE]` — the same pass as the
/// standalone `detcheck` bin, registered here for discoverability.
fn cmd_detcheck(args: Vec<String>) -> Result<()> {
    let report = racam::analysis::run_cli(&args)?;
    print!("{}", report.render());
    anyhow::ensure!(
        report.unwaived_count() == 0,
        "detcheck: {} unwaived finding(s)",
        report.unwaived_count()
    );
    Ok(())
}

/// Aggregate (hits, misses, warm_loads) across shard services, counting
/// each shared cache once (equal-channel shards alias one service).
fn mapping_counters(services: &[MappingService]) -> (u64, u64, u64) {
    let mut distinct: Vec<&MappingService> = Vec::new();
    for svc in services {
        if !distinct.iter().any(|d| d.shares_cache_with(svc)) {
            distinct.push(svc);
        }
    }
    distinct
        .iter()
        .fold((0, 0, 0), |(h, m, w), s| (h + s.hits(), m + s.misses(), w + s.warm_loads()))
}

fn cmd_map(args: Vec<String>) -> Result<()> {
    let pos: Vec<u64> =
        args.iter().take_while(|a| !a.starts_with("--")).filter_map(|a| a.parse().ok()).collect();
    anyhow::ensure!(pos.len() == 3, "usage: racam map <M> <K> <N> [--prec BITS] [--all]");
    let bits: u32 = flag_value(&args, "--prec").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let prec = Precision::from_bits(bits)
        .ok_or_else(|| anyhow::anyhow!("unsupported precision {bits} (2/4/8/16)"))?;
    let shape = MatmulShape::new(pos[0], pos[1], pos[2], prec);

    let service = MappingService::for_config(&racam_paper());
    if let Some(path) = flag_value(&args, "--store") {
        // Attach the shared warm store: known shapes answer from the
        // table, and this search merges back into it on exit.
        let n = service.set_warm_path(std::path::Path::new(&path))?;
        println!("warm store  : {path} ({n} entries loaded)");
    }
    // Exhaustive on purpose: `racam map` reports the whole-space spread,
    // which the pruned serving search intentionally skips.
    let r = service
        .search_exhaustive(&shape)
        .ok_or_else(|| anyhow::anyhow!("no candidate mapping evaluates for {}", shape.label()))?;
    println!("shape       : {} ({})", shape.label(), prec.label());
    println!("candidates  : {}", r.candidates);
    println!("best mapping: {}", r.best.mapping);
    println!("tile (M,K,N): {:?}", r.best.tile);
    println!(
        "latency     : {}  (compute {}, io {})",
        fmt_ns(r.best.total_ns()),
        fmt_ns(r.best.compute_ns),
        fmt_ns(r.best.io_ns())
    );
    println!("pe util     : {:.1}%", r.best.pe_util * 100.0);
    println!("spread      : {:.1}x worst/best", r.spread());
    // The serving-path search on the same shape (cached, so a --store run
    // persists the entry): same winner by the bit-identity contract, a
    // fraction of the evaluations.
    let bf = service
        .search_cached(&shape)
        .ok_or_else(|| anyhow::anyhow!("best-first search failed for {}", shape.label()))?;
    println!(
        "best-first  : {} evaluated + {} pruned ({} bound calls, frontier peak {})",
        bf.candidates, bf.pruned, bf.bound_calls, bf.frontier_peak
    );
    if args.iter().any(|a| a == "--all") {
        for e in service.evaluate_all(&shape) {
            println!("{:>14.0}ns  util={:<6.3} {}", e.total_ns(), e.pe_util, e.mapping);
        }
    }
    Ok(())
}

fn cmd_llm(args: Vec<String>) -> Result<()> {
    let model = args.first().map(String::as_str).unwrap_or("gpt3-6.7b");
    let spec = match model {
        "gpt3-6.7b" => config::gpt3_6_7b(),
        "gpt3-175b" => config::gpt3_175b(),
        "llama3-8b" => config::llama3_8b(),
        "llama3-70b" => config::llama3_70b(),
        other => anyhow::bail!("unknown model '{other}'"),
    };
    let stage = flag_value(&args, "--stage").unwrap_or_else(|| "e2e".into());
    let scenario = match flag_value(&args, "--scenario").as_deref() {
        Some("ctx") => Scenario::CONTEXT_UNDERSTANDING,
        _ => Scenario::CODE_GENERATION,
    };
    let sys = RacamSystem::new(&racam_paper());
    let b = match stage.as_str() {
        "prefill" => workloads::stage_latency(&sys, &workloads::prefill_kernels(&spec, 1024))?,
        "decode" => workloads::stage_latency(&sys, &workloads::decode_kernels(&spec, 1024))?,
        "e2e" => workloads::e2e_latency(&sys, &spec, &scenario)?,
        other => anyhow::bail!("unknown stage '{other}'"),
    };
    println!("{} {} on RACAM:", spec.name, stage);
    println!("  pim   : {}", fmt_ns(b.pim_ns));
    println!("  io    : {}", fmt_ns(b.io_ns));
    println!("  total : {}", fmt_ns(b.total_ns()));
    println!("  cache : {} searches, {} hits", sys.service().misses(), sys.service().hits());
    Ok(())
}

fn cmd_area() -> Result<()> {
    let m = AreaModel::default();
    let r = m.report(&racam_paper());
    println!("DRAM chips       : {:>10.1} mm²", r.dram_mm2);
    println!("locality buffers : {:>10.1} mm²", r.locality_buffer_mm2);
    println!("bit-serial PEs   : {:>10.1} mm²", r.pe_mm2);
    println!("popcount units   : {:>10.1} mm²", r.popcount_mm2);
    println!("broadcast units  : {:>10.1} mm²", r.broadcast_mm2);
    println!("device FSMs      : {:>10.1} mm²", r.fsm_mm2);
    println!(
        "added total      : {:>10.1} mm²  ({:.2}% of DRAM)",
        r.added_mm2(),
        100.0 * r.overhead_fraction()
    );
    println!(
        "H100 @15nm ref   : {:>10.1} mm²  (added = {:.1}% of it)",
        m.h100_mm2_at_15nm(),
        100.0 * r.added_mm2() / m.h100_mm2_at_15nm()
    );
    Ok(())
}

fn cmd_config(args: Vec<String>) -> Result<()> {
    if let Some(path) = flag_value(&args, "--dump") {
        std::fs::write(&path, racam_paper().to_json())?;
        println!("wrote {path}");
    } else if let Some(path) = flag_value(&args, "--load") {
        let hw = HwConfig::from_json(&std::fs::read_to_string(&path)?)?;
        hw.validate().map_err(|e| anyhow::anyhow!("invalid config: {e:?}"))?;
        println!(
            "{path}: valid RACAM config, {} PEs, {:.1} int8 TOPS",
            hw.total_pes(),
            hw.peak_tops(Precision::Int8)
        );
    } else {
        println!("{}", racam_paper().to_json());
    }
    Ok(())
}

fn cmd_serve(args: Vec<String>) -> Result<()> {
    use racam::config::{
        ArrivalProcess, ClusterSpec, EngineKind, FaultSpec, LengthDist, SchedulerKind,
        ServingPolicy, TrafficSpec,
    };
    use racam::coordinator::{
        ClusterBuilder, ClusterCoordinator, Request, SyntheticEngine, TokenEngine,
    };
    use racam::runtime::executor::WorkerStats;
    use racam::telemetry::{chrome_trace, Event, Recorder, TraceRecorder};
    use racam::traffic::{generate, replay_trace, SloSummary};

    let n_req: u64 = flag_value(&args, "--requests").map(|v| v.parse()).transpose()?.unwrap_or(4);
    let tokens: usize = flag_value(&args, "--tokens").map(|v| v.parse()).transpose()?.unwrap_or(16);
    let batch: usize = flag_value(&args, "--batch").map(|v| v.parse()).transpose()?.unwrap_or(2);
    let shards: usize = flag_value(&args, "--shards").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let synthetic = args.iter().any(|a| a == "--synthetic");
    let sched = flag_value(&args, "--sched").unwrap_or_else(|| "fcfs".into());
    let rate: Option<f64> = flag_value(&args, "--rate").map(|v| v.parse()).transpose()?;
    let engine_flag: Option<EngineKind> = match flag_value(&args, "--engine") {
        Some(e) => Some(
            EngineKind::from_label(&e)
                .ok_or_else(|| anyhow::anyhow!("unknown engine '{e}' (calendar|oracle)"))?,
        ),
        None => None,
    };
    let threads: Option<usize> = flag_value(&args, "--threads").map(|v| v.parse()).transpose()?;
    let trace_out = flag_value(&args, "--trace-out");
    let show_metrics = args.iter().any(|a| a == "--metrics");
    // A deterministic fault schedule (docs/robustness.md): simulated-time
    // crashes, brownouts, link outages, and channel loss, validated here
    // and installed on the coordinator before the run starts.
    let faults: Option<FaultSpec> = match flag_value(&args, "--faults") {
        Some(path) => Some(FaultSpec::from_json(&std::fs::read_to_string(&path)?)?),
        None => None,
    };
    // Recording is zero-cost when off: the recorded build is only taken
    // when a telemetry flag asks for it.
    let record = trace_out.is_some() || show_metrics;
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    anyhow::ensure!(batch >= 1, "--batch must be at least 1");
    anyhow::ensure!(threads != Some(0), "--threads must be at least 1");

    // The cluster: an explicit JSON ClusterSpec (shard groups with roles,
    // schedulers, policies, channel shares — the prefill/decode
    // disaggregation entry point), or a single unified group synthesized
    // from the legacy flags.
    let cluster = if let Some(path) = flag_value(&args, "--cluster") {
        for flag in ["--shards", "--batch", "--sched", "--chunk-tokens", "--serving", "--engine"] {
            anyhow::ensure!(
                flag_value(&args, flag).is_none(),
                "--cluster replaces {flag}; put the setting in the cluster JSON"
            );
        }
        anyhow::ensure!(
            !args.iter().any(|a| a == "--preempt"),
            "--cluster replaces --preempt; put the policy in the cluster JSON"
        );
        ClusterSpec::from_json(&std::fs::read_to_string(&path)?)?
    } else {
        // Serving policy: a JSON file, or --chunk-tokens/--preempt flags
        // (the default is the paper-faithful whole-prompt schedule).
        let policy = if let Some(path) = flag_value(&args, "--serving") {
            anyhow::ensure!(
                flag_value(&args, "--chunk-tokens").is_none()
                    && !args.iter().any(|a| a == "--preempt"),
                "--serving replaces --chunk-tokens/--preempt; pass one or the other"
            );
            let p = ServingPolicy::from_json(&std::fs::read_to_string(&path)?)?;
            match engine_flag {
                Some(e) => p.with_engine(e),
                None => p,
            }
        } else {
            let chunk: Option<u64> =
                flag_value(&args, "--chunk-tokens").map(|v| v.parse()).transpose()?;
            let p = ServingPolicy {
                prefill_chunk_tokens: chunk,
                preempt: args.iter().any(|a| a == "--preempt"),
                engine: engine_flag.unwrap_or_default(),
            };
            p.validate().map_err(|e| anyhow::anyhow!("invalid serving policy: {e}"))?;
            p
        };
        let kind = SchedulerKind::from_label(&sched)
            .ok_or_else(|| anyhow::anyhow!("unknown scheduler '{sched}' (fcfs|bucket|edf)"))?;
        let mut c = ClusterSpec::unified(shards, batch);
        c.groups[0].scheduler = kind;
        c.groups[0].policy = policy;
        c
    };
    // The shared cross-process warm store (see docs/mapping.md): every
    // equal-channel mapping service loads the table at construction and
    // merges its cache back on exit.  A cluster JSON can set the path
    // itself (`mapping_store`); the flag overrides it.
    let cluster = match flag_value(&args, "--warm-store") {
        Some(path) => cluster.with_mapping_store(&path),
        None => cluster,
    };

    let spec = config::gpt3_6_7b();
    // Each worker shard prices against its honest share of the paper
    // device's DRAM channels (explicit group shares, or an even split;
    // equal shares alias one service).  A cache file warm-starts shard 0's
    // service (§7 amortization across processes) — entries are specific to
    // that per-shard channel count, so reuse the same cluster shape across
    // runs of one cache file.
    let builder = ClusterBuilder::new(cluster.clone(), &racam_paper(), spec.clone())?;
    let services = builder.services().to_vec();
    let cache_path = flag_value(&args, "--mapping-cache");
    if let Some(path) = &cache_path {
        let p = std::path::PathBuf::from(path);
        if p.exists() {
            let n = services[0].warm_start(&p)?;
            println!("pre-warmed mapping cache with {n} entries from {path}");
        }
    }

    // The request stream: an open-loop traffic source when asked for,
    // otherwise the legacy fixed batch of synthetic prompts.
    let requests: Vec<Request> = if let Some(path) = flag_value(&args, "--trace") {
        replay_trace(&std::fs::read_to_string(&path)?)?
    } else if let Some(path) = flag_value(&args, "--traffic") {
        generate(&TrafficSpec::from_json(&std::fs::read_to_string(&path)?)?)
    } else if let Some(rate_per_s) = rate {
        anyhow::ensure!(rate_per_s > 0.0, "--rate must be positive");
        let deadline_ms: Option<f64> =
            flag_value(&args, "--deadline-ms").map(|v| v.parse()).transpose()?;
        generate(&TrafficSpec {
            seed: 7,
            requests: n_req,
            arrival: ArrivalProcess::Poisson { rate_per_s },
            prompt: LengthDist::Uniform { lo: 8, hi: 96 },
            output: LengthDist::Fixed(tokens as u64),
            deadline_ns: deadline_ms.map(|ms| (ms * 1e6) as u64),
        })
    } else {
        (0..n_req)
            .map(|id| {
                let prompt: Vec<u32> =
                    (0..3 + id % 5).map(|i| ((id * 31 + i * 7) % 200) as u32).collect();
                Request::new(id, prompt, tokens)
            })
            .collect()
    };
    let open_loop = requests.iter().any(|r| r.arrival_ns > 0);

    fn drive<E: TokenEngine + Send, R: Recorder + Send>(
        coord: &mut ClusterCoordinator<E, R>,
        requests: Vec<Request>,
        threads: Option<usize>,
        faults: Option<&FaultSpec>,
    ) -> Result<racam::coordinator::ServerReport> {
        if let Some(t) = threads {
            coord.set_threads(t);
        }
        if let Some(spec) = faults {
            coord.set_faults(spec)?;
        }
        for req in requests {
            coord.submit(req);
        }
        coord.run_to_completion()
    }

    /// Pull the simulated-event tracks (one per shard + the KV link) and
    /// the host-executor worker counters out of a recorded coordinator.
    /// Fault/recovery instants additionally land on a dedicated `faults`
    /// track (merged across shards and the link, time-ordered) so a
    /// chaos run's injection schedule reads as one timeline.
    fn collect<E: TokenEngine + Send>(
        coord: &ClusterCoordinator<E, TraceRecorder>,
    ) -> (Vec<(String, Vec<Event>)>, Vec<WorkerStats>) {
        let mut tracks = Vec::with_capacity(coord.num_shards() + 2);
        for i in 0..coord.num_shards() {
            tracks.push((format!("shard {i}"), coord.shard_recorder(i).events.clone()));
        }
        tracks.push(("kv link".to_string(), coord.link_recorder().events.clone()));
        let mut fault_events: Vec<Event> = tracks
            .iter()
            .flat_map(|(_, events)| events.iter().filter(|e| e.kind.is_fault()).cloned())
            .collect();
        fault_events.sort_by(|a, b| a.at_ns.total_cmp(&b.at_ns));
        if !fault_events.is_empty() {
            tracks.push(("faults".to_string(), fault_events));
        }
        (tracks, coord.worker_stats().to_vec())
    }

    /// Build, drive, and (when recording) collect telemetry — one path
    /// for every engine kind.
    fn drive_built<E: TokenEngine + Send>(
        builder: ClusterBuilder,
        engine_factory: impl FnMut(usize) -> E,
        requests: Vec<Request>,
        threads: Option<usize>,
        record: bool,
        faults: Option<&FaultSpec>,
    ) -> Result<(
        racam::coordinator::ServerReport,
        Option<(Vec<(String, Vec<Event>)>, Vec<WorkerStats>)>,
    )> {
        if record {
            let mut coord = builder.build_recorded(
                engine_factory,
                |_| TraceRecorder::new(),
                TraceRecorder::new(),
            );
            let report = drive(&mut coord, requests, threads, faults)?;
            let telemetry = collect(&coord);
            Ok((report, Some(telemetry)))
        } else {
            let mut coord = builder.build(engine_factory);
            Ok((drive(&mut coord, requests, threads, faults)?, None))
        }
    }

    let (report, telemetry) = if synthetic {
        drive_built(
            builder,
            |_| SyntheticEngine::new(64, 256),
            requests,
            threads,
            record,
            faults.as_ref(),
        )?
    } else {
        #[cfg(feature = "pjrt")]
        {
            use racam::coordinator::HloDecodeEngine;
            use racam::runtime::{ArtifactSet, Runtime};
            let artifacts = ArtifactSet::discover();
            artifacts.require()?;
            let rt = Runtime::cpu()?;
            let mut modules = Vec::with_capacity(cluster.total_shards());
            for _ in 0..cluster.total_shards() {
                modules.push(rt.load_hlo_text(&artifacts.decode_step())?);
            }
            let mut modules = modules.into_iter();
            drive_built(
                builder,
                |_| {
                    HloDecodeEngine::new(modules.next().expect("one module per shard"), 64, 256)
                },
                requests,
                threads,
                record,
                faults.as_ref(),
            )?
        }
        #[cfg(not(feature = "pjrt"))]
        {
            anyhow::bail!(
                "this build has no PJRT runtime (compile with --features pjrt); use --synthetic"
            )
        }
    };

    if let Some(path) = &cache_path {
        services[0].persist(std::path::Path::new(path))?;
        println!("saved mapping cache ({} shapes) to {path}", services[0].cache_len());
    }

    let cluster_label = cluster
        .groups
        .iter()
        .map(|g| format!("{}×{}[{}/{}]", g.name, g.count, g.scheduler.label(), g.policy.label()))
        .collect::<Vec<_>>()
        .join(" + ");
    println!(
        "served {} requests, {} tokens total across {} shard(s) [{cluster_label}]",
        report.results.len(),
        report.total_tokens,
        cluster.total_shards(),
    );
    for r in &report.results {
        println!(
            "  req {}: ttft {} total {}  tokens {:?}…{}",
            r.id,
            fmt_ns(r.ttft_ns()),
            fmt_ns(r.e2e_ns()),
            &r.tokens[..4.min(r.tokens.len())],
            if r.failed {
                "  [failed]"
            } else if r.shed {
                "  [shed]"
            } else {
                ""
            }
        );
    }
    for s in &report.shards {
        println!(
            "  shard {} ({}/{}): {} reqs, {} tokens, {} decode iters, {} prefill steps, \
             occupancy {:.0}%, busy {:.0}%{}{}",
            s.shard,
            s.group,
            s.role.label(),
            s.requests,
            s.tokens,
            s.decode_iterations,
            s.prefill_chunks,
            s.occupancy * 100.0,
            s.utilization() * 100.0,
            if s.handoffs > 0 {
                format!(", {} handoffs, kv transfer {}", s.handoffs, fmt_ns(s.kv_transfer_ns))
            } else {
                String::new()
            },
            if s.shed > 0 || s.preemptions > 0 {
                format!(", {} shed, {} preempted", s.shed, s.preemptions)
            } else {
                String::new()
            }
        );
    }
    if open_loop || cluster.is_disaggregated() {
        let slo = SloSummary::from_report(&report);
        let mut t = racam::report::Table::new("SLO summary", &SloSummary::table_headers());
        t.row(slo.table_row(&cluster_label));
        println!("{}", t.render());
        // The readable view of a disaggregated run: one row per shard
        // group (prefill vs decode), KV-link totals included.
        if cluster.is_disaggregated() {
            println!("{}", slo.utilization_table("group utilization", false).render());
        }
    }
    if faults.is_some() {
        let slo = SloSummary::from_report(&report);
        println!("{}", slo.availability_table("availability under faults").render());
    }
    if let Some((tracks, workers)) = &telemetry {
        if let Some(path) = &trace_out {
            let trace = chrome_trace(tracks, workers);
            let check = racam::telemetry::validate_trace(&trace)?;
            std::fs::write(path, trace.pretty())?;
            println!(
                "wrote Chrome trace to {path}: {} events on {} tracks ({} spans); \
                 open in chrome://tracing or ui.perfetto.dev",
                check.events, check.tracks, check.spans
            );
        }
        if show_metrics {
            // Report-derived counters/latency histograms, then the
            // event-derived samples (queue depth at admission, batch
            // occupancy per decode iteration) from the recorded streams,
            // then the mapping-cache counters from the shard services.
            let mut m = SloSummary::from_report(&report).metrics;
            for (_, events) in tracks {
                m.absorb_events(events);
            }
            m.absorb_mapping(mapping_counters(&services));
            println!("{}", m.table("telemetry metrics").render());
        }
    }
    println!(
        "mapping cache (shard 0): {} unique shapes searched, {} cache-served",
        services[0].misses(),
        services[0].hits()
    );
    if cluster.mapping_store.is_some() {
        let (hits, misses, warm) = mapping_counters(&services);
        println!(
            "warm store: {warm} entries loaded, {misses} searched fresh, {hits} cache-served; \
             merged back on exit"
        );
    }
    println!(
        "simulated {:.0} tok/s on RACAM ({}); {:.0} tok/s host wall",
        report.sim_tokens_per_s, spec.name, report.wall_tokens_per_s
    );
    Ok(())
}

fn cmd_experiments(args: Vec<String>) -> Result<()> {
    let id = args.first().map(String::as_str).unwrap_or("all");
    let ids: Vec<&str> = if id == "all" { experiments::ALL_IDS.to_vec() } else { vec![id] };
    for id in ids {
        println!("=== {id} ===");
        for t in experiments::run(id)? {
            println!("{}", t.render());
        }
    }
    Ok(())
}
