//! Report rendering: aligned text tables (the paper-style rows every
//! experiment prints), CSV emission under `results/`, and the in-tree
//! micro-benchmark harness used by `cargo bench` (criterion is unavailable
//! in this offline environment — see DESIGN.md "Substitutions").

mod bench;
pub mod schema;
mod table;

pub use bench::{bench, BenchResult};
pub use table::Table;

use std::fs;
use std::path::Path;

/// Write a report file under `results/` (created on demand).
pub fn save(name: &str, contents: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = Path::new("results");
    fs::create_dir_all(dir)?;
    let path = dir.join(name);
    fs::write(&path, contents)?;
    Ok(path)
}
