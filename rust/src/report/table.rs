//! Aligned text tables + CSV + JSON.

use crate::config::json::Value;

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Column headers (for tests and tooling that index into rows).
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Structured form for machine-readable bench artifacts
    /// (`BENCH_<name>.json`): title, headers, and rows as JSON strings —
    /// cells keep their rendered formatting so the JSON matches the text
    /// table exactly.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", Value::Str(self.title.clone())),
            (
                "headers",
                Value::Arr(self.headers.iter().map(|h| Value::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::Arr(r.iter().map(|c| Value::Str(c.clone())).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Render as CSV (quotes cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "speedup"]);
        t.row(vec!["GPT-3 175B".into(), "102.4".into()]);
        t.row(vec!["Llama-3 8B".into(), "9.1".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.lines().count() >= 4);
        // Columns align: both data lines have the speedup at same offset.
        let lines: Vec<&str> = s.lines().skip(2).collect();
        let off0 = lines[1].find("102.4").unwrap();
        let off1 = lines[2].find("9.1").unwrap();
        assert_eq!(off0, off1);
    }

    #[test]
    fn json_form_round_trips_through_the_parser() {
        use crate::config::json;
        let mut t = Table::new("bench", &["model", "latency"]);
        t.row(vec!["GPT-3".into(), "1.2ms".into()]);
        let v = t.to_json();
        let parsed = json::parse(&v.pretty()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str().unwrap(), "bench");
        let json::Value::Arr(rows) = parsed.get("rows").unwrap() else { panic!() };
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        Table::new("", &["a", "b"]).row(vec!["only one".into()]);
    }
}
