//! Bench-artifact schema regression checks.
//!
//! Every `exp` run writes a machine-readable `results/BENCH_<id>.json`
//! that CI uploads so the perf trajectory diffs across PRs.  That
//! trajectory is only diffable while the artifacts keep their fields: a
//! refactor that silently drops a config entry or a table column breaks
//! every downstream comparison without failing a single test.  This
//! module extracts a *schema signature* from a bench artifact —
//!
//! * every JSON key path (`config.rates_per_s[]`, `tables[].rows[][]`,
//!   ...), with `[]` marking array descent, and
//! * every table column as `column:<header>` (titles carry run
//!   parameters and are intentionally excluded),
//!
//! — and compares it against a committed manifest
//! (`rust/bench_schema.json`).  The `benchcheck` binary wraps this for
//! CI: `check` fails with a readable per-experiment diff when any
//! manifest field disappears from a fresh artifact; `write` regenerates
//! the manifest after an intentional schema change.

use crate::config::json::Value;
use crate::Result;
use std::collections::BTreeSet;
use std::path::Path;

/// The sorted schema signature of one bench artifact.
pub fn schema_of(v: &Value) -> Vec<String> {
    let mut out = BTreeSet::new();
    walk(v, "", &mut out);
    if let Ok(Value::Arr(tables)) = v.get("tables") {
        for t in tables {
            if let Ok(Value::Arr(headers)) = t.get("headers") {
                for h in headers {
                    if let Value::Str(s) = h {
                        out.insert(format!("column:{s}"));
                    }
                }
            }
        }
    }
    out.into_iter().collect()
}

fn walk(v: &Value, prefix: &str, out: &mut BTreeSet<String>) {
    match v {
        Value::Obj(m) => {
            for (k, val) in m {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                walk(val, &p, out);
            }
        }
        Value::Arr(items) => {
            let p = format!("{prefix}[]");
            if items.is_empty() {
                out.insert(p);
            } else {
                for it in items {
                    walk(it, &p, out);
                }
            }
        }
        _ => {
            out.insert(prefix.to_string());
        }
    }
}

fn experiment_name(file_name: &str) -> Option<&str> {
    file_name.strip_prefix("BENCH_")?.strip_suffix(".json")
}

/// Snapshot the schema of every `BENCH_*.json` in `dir` into a manifest
/// value (`{"version": 1, "experiments": {<id>: [<field>, ...]}}`).
pub fn manifest_from_dir(dir: &Path) -> Result<Value> {
    let mut experiments: Vec<(String, Value)> = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let file_name = entry.file_name();
        let Some(name) = file_name.to_str().and_then(experiment_name) else { continue };
        let text = std::fs::read_to_string(entry.path())?;
        let v = crate::config::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e:?}", entry.path().display()))?;
        experiments.push((
            name.to_string(),
            Value::Arr(schema_of(&v).into_iter().map(Value::Str).collect()),
        ));
    }
    anyhow::ensure!(!experiments.is_empty(), "no BENCH_*.json found in {}", dir.display());
    Ok(Value::Obj(
        [
            ("version".to_string(), Value::Num(1.0)),
            (
                "experiments".to_string(),
                Value::Obj(experiments.into_iter().collect()),
            ),
        ]
        .into_iter()
        .collect(),
    ))
}

/// Check every experiment in `manifest` against the artifacts in `dir`.
/// Returns the list of human-readable problems — empty means the schema
/// held.  Fields *added* since the manifest are fine (the trajectory only
/// breaks when fields disappear); they are reported via `notes` so the
/// manifest can be refreshed deliberately.
pub fn check_dir(dir: &Path, manifest: &Value) -> Result<(Vec<String>, Vec<String>)> {
    anyhow::ensure!(
        manifest.get("version")?.as_f64()? == 1.0,
        "unknown bench-schema manifest version"
    );
    let Value::Obj(experiments) = manifest.get("experiments")? else {
        anyhow::bail!("manifest 'experiments' must be an object")
    };
    let mut problems = Vec::new();
    let mut notes = Vec::new();
    for (name, fields) in experiments {
        let Value::Arr(fields) = fields else {
            anyhow::bail!("manifest entry '{name}' must be an array of fields")
        };
        let expected: BTreeSet<String> = fields
            .iter()
            .filter_map(|f| match f {
                Value::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        let path = dir.join(format!("BENCH_{name}.json"));
        if !path.exists() {
            problems.push(format!(
                "{name}: artifact {} is missing — every manifest experiment must be \
                 regenerated before the check runs",
                path.display()
            ));
            continue;
        }
        let v = crate::config::json::parse(&std::fs::read_to_string(&path)?)
            .map_err(|e| anyhow::anyhow!("{}: {e:?}", path.display()))?;
        let actual: BTreeSet<String> = schema_of(&v).into_iter().collect();
        for missing in expected.difference(&actual) {
            problems.push(format!(
                "{name}: field '{missing}' disappeared from BENCH_{name}.json \
                 (perf-trajectory consumers depend on it; if the removal is \
                 intentional, regenerate the manifest with `benchcheck write`)"
            ));
        }
        for added in actual.difference(&expected) {
            notes.push(format!("{name}: new field '{added}' (not yet in the manifest)"));
        }
    }
    Ok((problems, notes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::parse;

    fn artifact() -> Value {
        parse(
            r#"{"name": "demo",
                "wall_ms": 4.5,
                "config": {"preset": "racam_paper", "rates_per_s": [1.0, 2.0]},
                "tables": [{"title": "t — run at 5/s",
                            "headers": ["run", "ttft_p99"],
                            "rows": [["a", "1"], ["b", "2"]]}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn schema_extracts_paths_and_columns() {
        let s = schema_of(&artifact());
        for field in [
            "name",
            "wall_ms",
            "config.preset",
            "config.rates_per_s[]",
            "tables[].title",
            "tables[].headers[]",
            "tables[].rows[][]",
            "column:run",
            "column:ttft_p99",
        ] {
            assert!(s.iter().any(|f| f == field), "missing '{field}' in {s:?}");
        }
        // Table titles are parameterized — only `column:` entries pin them.
        assert!(!s.iter().any(|f| f.contains("run at 5/s")), "{s:?}");
    }

    #[test]
    fn check_flags_disappeared_fields_and_tolerates_new_ones() {
        let dir = std::env::temp_dir().join("racam_benchcheck_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("BENCH_demo.json"), artifact().pretty()).unwrap();

        // Manifest == current schema: clean check.
        let manifest = manifest_from_dir(&dir).unwrap();
        let (problems, notes) = check_dir(&dir, &manifest).unwrap();
        assert!(problems.is_empty(), "{problems:?}");
        assert!(notes.is_empty(), "{notes:?}");

        // A column disappears from the artifact: readable failure.
        let mut broken = artifact();
        if let Value::Obj(m) = &mut broken {
            if let Some(Value::Arr(tables)) = m.get_mut("tables") {
                if let Value::Obj(t) = &mut tables[0] {
                    t.insert(
                        "headers".into(),
                        Value::Arr(vec![Value::Str("run".into())]),
                    );
                    t.insert("rows".into(), Value::Arr(vec![Value::Arr(vec![Value::Str("a".into())])]));
                }
            }
        }
        std::fs::write(dir.join("BENCH_demo.json"), broken.pretty()).unwrap();
        let (problems, _) = check_dir(&dir, &manifest).unwrap();
        assert!(
            problems.iter().any(|p| p.contains("column:ttft_p99")),
            "expected the dropped column in {problems:?}"
        );

        // A new field appears: note, not failure.
        let mut extended = artifact();
        if let Value::Obj(m) = &mut extended {
            m.insert("extra".into(), Value::Num(1.0));
        }
        std::fs::write(dir.join("BENCH_demo.json"), extended.pretty()).unwrap();
        let (problems, notes) = check_dir(&dir, &manifest).unwrap();
        assert!(problems.is_empty(), "{problems:?}");
        assert!(notes.iter().any(|n| n.contains("extra")), "{notes:?}");

        // A manifest experiment whose artifact vanished: failure.
        std::fs::remove_file(dir.join("BENCH_demo.json")).unwrap();
        let (problems, _) = check_dir(&dir, &manifest).unwrap();
        assert!(problems.iter().any(|p| p.contains("missing")), "{problems:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn live_bench_artifacts_satisfy_the_committed_manifest() {
        // The real guard, run against freshly generated artifacts: build
        // one cheap experiment's artifact in-process and verify the
        // committed manifest's entry for it is a subset of its schema.
        // (CI runs the full `benchcheck check` over every serving bench
        // after regenerating them in release mode.)
        let manifest = parse(include_str!("../../bench_schema.json")).unwrap();
        let Value::Obj(experiments) = manifest.get("experiments").unwrap() else {
            panic!("experiments must be an object")
        };
        // Serving experiments CI regenerates must all be listed.
        for id in ["traffic", "prefill", "disagg", "scale", "map"] {
            assert!(experiments.contains_key(id), "manifest must cover '{id}'");
        }
    }
}
