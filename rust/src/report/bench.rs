//! In-tree micro-benchmark harness (criterion replacement for the offline
//! build): warmup + timed iterations, reporting min/mean/p50/max.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  min {:>12}  p50 {:>12}  max {:>12}",
            self.name,
            self.iters,
            crate::metrics::fmt_ns(self.mean_ns),
            crate::metrics::fmt_ns(self.min_ns),
            crate::metrics::fmt_ns(self.p50_ns),
            crate::metrics::fmt_ns(self.max_ns),
        )
    }
}

/// Run `f` for `iters` timed iterations (after 10% warmup) and print a
/// summary line.  Returns the stats so benches can assert regressions.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    let warmup = (iters / 10).max(1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        #[allow(clippy::disallowed_methods)] // bench harness owns wall timing (detcheck allowlist)
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        min_ns: samples[0],
        p50_ns: samples[iters / 2],
        max_ns: samples[iters - 1],
    };
    println!("{}", res.line());
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let r = bench("noop", 50, || 1 + 1);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.max_ns);
        assert!(r.mean_ns >= r.min_ns && r.mean_ns <= r.max_ns);
    }

    #[test]
    fn measures_real_work() {
        let fast = bench("fast", 30, || std::hint::black_box(0u64));
        // black_box the bound so release builds can't fold the loop away.
        let n = std::hint::black_box(200_000u64);
        let slow = bench("slow", 30, || {
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            acc
        });
        assert!(slow.p50_ns > fast.p50_ns);
    }
}
