//! Zero-cost telemetry: simulated-time event tracing, a deterministic
//! metrics registry, and a Chrome-trace exporter.
//!
//! The serving engines ([`Server::run_to_completion`] and the coordinator
//! above it) are instrumented with a [`Recorder`] — a sink for structured
//! [`Event`]s stamped on the *simulated* clock.  The default sink is
//! [`NopRecorder`], a zero-sized type whose `record` is an empty inline
//! function: the hooks monomorphize away entirely, so the allocation-free
//! hot loop is untouched and a recorder-enabled run is **bit-identical**
//! to a disabled one (hooks are pure observers — they never feed back
//! into a scheduling or pricing decision; `tests/engine_equivalence.rs`
//! pins this with [`ServerReport::sim_divergence`]).
//!
//! On top of the events sits a deterministic metrics registry
//! ([`Metrics`]): counters plus fixed-memory log-bucketed [`Histogram`]s
//! (TTFT, TPOT, queue depth, batch occupancy).  Histograms quantize to
//! integer nanoseconds and merge with pure integer arithmetic, so merging
//! is *exactly associative* and per-shard metrics merged in shard order
//! report identically however many worker threads ran the shards
//! (`tests/proptests.rs` pins both properties).
//!
//! [`chrome_trace`] exports recorded events as Chrome-trace/Perfetto JSON
//! (`racam serve --trace-out trace.json`): one track per shard, one for
//! the KV link, one per executor worker.  See `docs/observability.md` for
//! the event taxonomy and a trace-viewer walkthrough.
//!
//! [`Server::run_to_completion`]: crate::coordinator::Server::run_to_completion
//! [`ServerReport::sim_divergence`]: crate::coordinator::ServerReport::sim_divergence

use crate::config::json::Value;
use crate::config::ShardRole;
use crate::coordinator::ServerReport;
use crate::metrics::fmt_ns;
use crate::report::Table;
use crate::runtime::executor::WorkerStats;

/// `Event::req` value for events not tied to a request (idle jumps,
/// decode stretches).
pub const NO_REQ: u64 = u64::MAX;

/// What happened (see `docs/observability.md` for the full taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A future arrival crossed the simulated clock and was released to
    /// the scheduler (`value` = the request's arrival timestamp, ns —
    /// release minus arrival is time spent invisible in the future heap).
    ArrivalRelease,
    /// The scheduler admitted a request into a batch slot (`value` =
    /// requests still pending after this round's admissions).
    Admit,
    /// One prefill step — a bounded chunk or a whole prompt (span;
    /// `value` = prompt tokens consumed by this step).
    PrefillChunk,
    /// A lockstep decode stretch (span; `value` = decoding members,
    /// `count` = iterations fast-forwarded — 1 per event on the oracle).
    DecodeStretch,
    /// A member's context crossed a pricing-bucket edge and its decode
    /// schedule was refreshed (`value` = the new bucket).  Calendar
    /// engine only: the oracle prices per iteration and never
    /// materializes an edge.
    BucketEdge,
    /// A running request was preempted back to the queue (`value` =
    /// tokens it had generated).
    Preempt,
    /// A running request was shed (`value` = tokens it had generated).
    Shed,
    /// A finished prefill left its shard for the KV link (`value` =
    /// prompt tokens).
    HandoffDispatch,
    /// A KV cache crossed the serialized link (span, on the link track;
    /// `value` = KV bytes).
    KvWire,
    /// A transferred KV cache landed on its decode shard (`value` = the
    /// destination shard index).
    DecodeRelease,
    /// The idle clock jump to the next future arrival (span).
    IdleJump,
    /// A shard died permanently (`value` = requests evacuated with it).
    ShardCrash,
    /// A brownout window opened on a shard (`value` = the slowdown
    /// factor applied while the window is active).
    Brownout,
    /// A shard group's DRAM-channel loss took effect on this shard
    /// (`value` = channels remaining after the loss).
    ChannelLoss,
    /// A KV transfer was interrupted by a link outage and is re-sent
    /// after deterministic backoff (`value` = the attempt number).
    KvRetry,
    /// An evacuated request was re-dispatched to a surviving shard
    /// (`value` = the re-dispatch attempt number).
    FaultRequeue,
    /// An evacuated request exhausted its retry budget (or no eligible
    /// shard survived) and terminated as `failed` (`value` = attempts).
    RequestFailed,
    /// The degradation controller shed an evacuated request because
    /// surviving capacity fell below the utilization ceiling (`value` =
    /// the surviving-capacity fraction).
    DegradeShed,
}

impl EventKind {
    /// Stable lowercase label (trace-event name).
    pub fn label(self) -> &'static str {
        match self {
            EventKind::ArrivalRelease => "arrival_release",
            EventKind::Admit => "admit",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::DecodeStretch => "decode_stretch",
            EventKind::BucketEdge => "bucket_edge",
            EventKind::Preempt => "preempt",
            EventKind::Shed => "shed",
            EventKind::HandoffDispatch => "handoff_dispatch",
            EventKind::KvWire => "kv_wire",
            EventKind::DecodeRelease => "decode_release",
            EventKind::IdleJump => "idle_jump",
            EventKind::ShardCrash => "shard_crash",
            EventKind::Brownout => "brownout",
            EventKind::ChannelLoss => "channel_loss",
            EventKind::KvRetry => "kv_retry",
            EventKind::FaultRequeue => "fault_requeue",
            EventKind::RequestFailed => "request_failed",
            EventKind::DegradeShed => "degrade_shed",
        }
    }

    /// Whether this kind spans simulated time (exported as a B/E pair)
    /// or marks an instant.
    pub fn is_span(self) -> bool {
        matches!(
            self,
            EventKind::PrefillChunk
                | EventKind::DecodeStretch
                | EventKind::KvWire
                | EventKind::IdleJump
        )
    }

    /// Whether this kind belongs to the fault/recovery family — exported
    /// on the dedicated `faults` trace track instead of its shard's (all
    /// instants, so the merged track needs no span nesting).
    pub fn is_fault(self) -> bool {
        matches!(
            self,
            EventKind::ShardCrash
                | EventKind::Brownout
                | EventKind::ChannelLoss
                | EventKind::KvRetry
                | EventKind::FaultRequeue
                | EventKind::RequestFailed
                | EventKind::DegradeShed
        )
    }
}

/// One telemetry event on the simulated clock.  `Copy` and
/// allocation-free by design: constructing one in a hot loop costs a few
/// register moves, and under [`NopRecorder`] the construction is dead
/// code the optimizer removes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Simulated start time, ns.
    pub at_ns: f64,
    /// Simulated duration, ns (0 for instants).
    pub dur_ns: f64,
    /// Request id, or [`NO_REQ`].
    pub req: u64,
    /// Kind-specific scalar (see [`EventKind`]).
    pub value: f64,
    /// Kind-specific multiplicity (decode iterations in a stretch; 1
    /// otherwise).
    pub count: u64,
}

impl Event {
    /// An instantaneous event.
    pub fn instant(kind: EventKind, at_ns: f64, req: u64, value: f64) -> Event {
        Event { kind, at_ns, dur_ns: 0.0, req, value, count: 1 }
    }

    /// An event spanning `[at_ns, at_ns + dur_ns]`.
    pub fn span(kind: EventKind, at_ns: f64, dur_ns: f64, req: u64, value: f64) -> Event {
        Event { kind, at_ns, dur_ns, req, value, count: 1 }
    }

    /// Simulated end time, ns.
    pub fn end_ns(&self) -> f64 {
        self.at_ns + self.dur_ns
    }
}

/// A telemetry sink threaded through the serving engines.
///
/// Implementations must be **pure observers**: a recorder sees every
/// event but must never influence scheduling, pricing, or the simulated
/// clock — the engine-equivalence suite asserts that a recorder-enabled
/// run is bit-identical to a disabled one.
pub trait Recorder {
    /// Record one event.  Called from the serving hot loop: keep it
    /// cheap, and never panic.
    fn record(&mut self, ev: Event);
}

/// The default sink: a zero-sized recorder whose `record` compiles to
/// nothing, so the instrumented hot loop is exactly the uninstrumented
/// one after monomorphization.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NopRecorder;

impl Recorder for NopRecorder {
    #[inline(always)]
    fn record(&mut self, _ev: Event) {}
}

/// A recorder that collects every event in order (the `--trace-out`
/// sink).  Memory grows with the event count; use it for runs you intend
/// to inspect, not for the million-request `exp scale` sweep.
#[derive(Debug, Default)]
pub struct TraceRecorder {
    /// Recorded events, in emission order (per shard this is
    /// non-decreasing in simulated time).
    pub events: Vec<Event>,
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }
}

impl Recorder for TraceRecorder {
    #[inline]
    fn record(&mut self, ev: Event) {
        self.events.push(ev);
    }
}

/// Number of log2 buckets in a [`Histogram`] (covers the whole `u64`
/// range: bucket *b* holds values in `[2^b, 2^(b+1))`, bucket 0 holds
/// `{0, 1}`).
pub const HISTOGRAM_BUCKETS: usize = 64;

/// Fixed-memory log2-bucketed histogram over `u64` samples.
///
/// Everything is integer arithmetic — counts, total, sum, min, max — so
/// [`Histogram::merge`] is *exactly associative and commutative*:
/// per-shard histograms merged in shard order produce bit-identical
/// registries regardless of how many worker threads ran the shards.
/// Simulated times quantize to integer nanoseconds via
/// [`Histogram::record_ns`] (sub-nanosecond rounding is far below the
/// resolution any percentile here reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; HISTOGRAM_BUCKETS],
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: [0; HISTOGRAM_BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

/// Quantize a simulated duration to integer nanoseconds (negative,
/// NaN, and infinite inputs clamp to 0 / `u64::MAX` saturation).
pub fn quantize_ns(ns: f64) -> u64 {
    if ns.is_nan() || ns <= 0.0 {
        0
    } else {
        ns.round() as u64 // saturates at u64::MAX
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index of a sample: the position of its highest set bit
    /// (`v | 1` folds 0 into bucket 0).
    pub fn bucket_of(v: u64) -> usize {
        63 - (v | 1).leading_zeros() as usize
    }

    /// Inclusive upper bound of a bucket's value range.
    fn bucket_hi(b: usize) -> u64 {
        if b >= 63 {
            u64::MAX
        } else {
            (2u64 << b) - 1
        }
    }

    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` identical samples in O(1) — how the calendar engine's
    /// fast-forwarded stretches match the oracle's per-iteration samples
    /// without replaying the stretch.
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.counts[Self::bucket_of(v)] += n;
        self.total += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a simulated duration (see [`quantize_ns`]).
    pub fn record_ns(&mut self, ns: f64) {
        self.record(quantize_ns(ns));
    }

    /// Merge another histogram in (exactly associative — integer adds
    /// and min/max only).
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded.
    pub fn len(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Smallest sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Per-bucket counts (bucket *b* holds `[2^b, 2^(b+1))`).
    pub fn counts(&self) -> &[u64; HISTOGRAM_BUCKETS] {
        &self.counts
    }

    /// Upper bound of the bucket containing the q-th quantile sample
    /// (`q` in `[0, 1]`; 0 when empty).  Log2 buckets bound the relative
    /// error at 2× — coarse, but deterministic and fixed-memory, which
    /// is the point: exact percentiles live in [`SloSummary`].
    ///
    /// [`SloSummary`]: crate::traffic::SloSummary
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_hi(b).min(self.max);
            }
        }
        self.max
    }

    /// Summary JSON: `{total, mean, min, max, p50, p99}` (the counts
    /// array stays out of `BENCH_*.json` — the trajectory diff wants
    /// stable summary fields, not 64 mostly-zero buckets).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("total", Value::Num(self.total as f64)),
            ("mean", Value::Num(self.mean())),
            ("min", Value::Num(self.min() as f64)),
            ("max", Value::Num(self.max() as f64)),
            ("p50", Value::Num(self.quantile(0.50) as f64)),
            ("p99", Value::Num(self.quantile(0.99) as f64)),
        ])
    }
}

/// Deterministic metrics registry for one serving run: counters plus the
/// four tentpole histograms.  Per-shard registries [`Metrics::merge`] in
/// shard order; every operation is commutative-associative integer
/// arithmetic, so the merged registry is identical for every worker
/// interleaving.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Metrics {
    pub requests: u64,
    /// Requests that delivered tokens (not shed, not failed).
    pub delivered: u64,
    pub shed: u64,
    /// Requests terminated as `failed`: evacuated from a crashed shard
    /// and never completed (retry budget exhausted or no survivor).
    pub failed: u64,
    /// Crash-evacuation re-dispatches across the cluster.
    pub retries: u64,
    /// KV transfers re-sent after a link-outage interruption.
    pub kv_retries: u64,
    /// Evacuated requests shed by the degradation controller.
    pub degrade_shed: u64,
    pub preemptions: u64,
    pub prefill_chunks: u64,
    pub decode_iterations: u64,
    /// Prefill→decode handoffs (sending side, once per transfer).
    pub handoffs: u64,
    pub total_tokens: u64,
    /// Mapping-service cache hits across the cluster's distinct services
    /// (fed from `Coordinator::mapping_counters` via
    /// [`Metrics::absorb_mapping`], zero otherwise).
    pub map_cache_hits: u64,
    /// Mapping-service cache misses — each one is a full best-first
    /// search some shard had to run.
    pub map_cache_misses: u64,
    /// Cache entries pre-seeded from a warm mapping store
    /// (`ClusterSpec::mapping_store`) at construction.
    pub map_warm_loads: u64,
    /// Arrival → first token, ns (delivered requests).
    pub ttft_ns: Histogram,
    /// Mean inter-token gap, ns (delivered requests with ≥ 2 tokens).
    pub tpot_ns: Histogram,
    /// Requests still pending after each admission round (recorder-fed:
    /// populated from [`EventKind::Admit`] events, empty otherwise).
    pub queue_depth: Histogram,
    /// Decoding batch members per decode iteration (recorder-fed:
    /// populated from [`EventKind::DecodeStretch`] events).
    pub batch_occupancy: Histogram,
}

impl Metrics {
    /// Build the report-derived portion (request counters and the
    /// TTFT/TPOT histograms) from a merged [`ServerReport`].  Integer
    /// accumulation only, so the result is independent of result order.
    pub fn from_report(report: &ServerReport) -> Metrics {
        let mut m = Metrics { requests: report.results.len() as u64, ..Metrics::default() };
        for r in &report.results {
            m.total_tokens += r.tokens.len() as u64;
            if r.failed {
                m.failed += 1;
                continue;
            }
            if r.shed {
                m.shed += 1;
                continue;
            }
            m.delivered += 1;
            m.ttft_ns.record_ns(r.ttft_ns());
            if r.tokens.len() >= 2 {
                m.tpot_ns.record_ns(r.tpot_ns());
            }
        }
        for s in &report.shards {
            m.preemptions += s.preemptions as u64;
            m.prefill_chunks += s.prefill_chunks as u64;
            m.decode_iterations += s.decode_iterations as u64;
            if s.role != ShardRole::Decode {
                m.handoffs += s.handoffs as u64;
            }
        }
        m.retries += report.faults.retries as u64;
        m.kv_retries += report.faults.kv_retries as u64;
        m.degrade_shed += report.faults.degrade_shed as u64;
        m
    }

    /// Fold recorded events into the event-fed histograms (queue depth
    /// from admissions, batch occupancy from decode stretches).  Feed
    /// shard event streams in shard order for a canonical registry —
    /// though the fold is order-independent by construction.
    pub fn absorb_events(&mut self, events: &[Event]) {
        for ev in events {
            match ev.kind {
                EventKind::Admit => self.queue_depth.record(ev.value as u64),
                EventKind::DecodeStretch => {
                    self.batch_occupancy.record_n(ev.value as u64, ev.count)
                }
                _ => {}
            }
        }
    }

    /// Fold in cluster-wide mapping-cache counters (the deduplicated
    /// `(hits, misses, warm_loads)` triple from
    /// `Coordinator::mapping_counters`).
    pub fn absorb_mapping(&mut self, counters: (u64, u64, u64)) {
        let (hits, misses, warm_loads) = counters;
        self.map_cache_hits += hits;
        self.map_cache_misses += misses;
        self.map_warm_loads += warm_loads;
    }

    /// Merge another registry in (exactly associative).
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.delivered += other.delivered;
        self.shed += other.shed;
        self.failed += other.failed;
        self.retries += other.retries;
        self.kv_retries += other.kv_retries;
        self.degrade_shed += other.degrade_shed;
        self.preemptions += other.preemptions;
        self.prefill_chunks += other.prefill_chunks;
        self.decode_iterations += other.decode_iterations;
        self.handoffs += other.handoffs;
        self.total_tokens += other.total_tokens;
        self.map_cache_hits += other.map_cache_hits;
        self.map_cache_misses += other.map_cache_misses;
        self.map_warm_loads += other.map_warm_loads;
        self.ttft_ns.merge(&other.ttft_ns);
        self.tpot_ns.merge(&other.tpot_ns);
        self.queue_depth.merge(&other.queue_depth);
        self.batch_occupancy.merge(&other.batch_occupancy);
    }

    /// Fold registries in iteration (shard) order.
    pub fn merged<'a>(items: impl IntoIterator<Item = &'a Metrics>) -> Metrics {
        let mut out = Metrics::default();
        for m in items {
            out.merge(m);
        }
        out
    }

    /// The `metrics` block of `BENCH_*.json` (benchcheck-gated fields —
    /// see `rust/bench_schema.json`).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("requests", Value::Num(self.requests as f64)),
            ("delivered", Value::Num(self.delivered as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("failed", Value::Num(self.failed as f64)),
            ("retries", Value::Num(self.retries as f64)),
            ("kv_retries", Value::Num(self.kv_retries as f64)),
            ("degrade_shed", Value::Num(self.degrade_shed as f64)),
            ("preemptions", Value::Num(self.preemptions as f64)),
            ("prefill_chunks", Value::Num(self.prefill_chunks as f64)),
            ("decode_iterations", Value::Num(self.decode_iterations as f64)),
            ("handoffs", Value::Num(self.handoffs as f64)),
            ("total_tokens", Value::Num(self.total_tokens as f64)),
            ("map_cache_hits", Value::Num(self.map_cache_hits as f64)),
            ("map_cache_misses", Value::Num(self.map_cache_misses as f64)),
            ("map_warm_loads", Value::Num(self.map_warm_loads as f64)),
            ("ttft_ns", self.ttft_ns.to_json()),
            ("tpot_ns", self.tpot_ns.to_json()),
            ("queue_depth", self.queue_depth.to_json()),
            ("batch_occupancy", self.batch_occupancy.to_json()),
        ])
    }

    /// The `racam serve --metrics` table: one row per histogram, one per
    /// counter.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "count", "mean", "p50", "p99", "max"]);
        let ns_row = |name: &str, h: &Histogram| {
            vec![
                name.to_string(),
                h.len().to_string(),
                fmt_ns(h.mean()),
                fmt_ns(h.quantile(0.50) as f64),
                fmt_ns(h.quantile(0.99) as f64),
                fmt_ns(h.max() as f64),
            ]
        };
        let n_row = |name: &str, h: &Histogram| {
            vec![
                name.to_string(),
                h.len().to_string(),
                format!("{:.2}", h.mean()),
                h.quantile(0.50).to_string(),
                h.quantile(0.99).to_string(),
                h.max().to_string(),
            ]
        };
        let counter = |name: &str, v: u64| {
            vec![name.to_string(), v.to_string(), "-".into(), "-".into(), "-".into(), "-".into()]
        };
        t.row(ns_row("ttft_ns", &self.ttft_ns));
        t.row(ns_row("tpot_ns", &self.tpot_ns));
        t.row(n_row("queue_depth", &self.queue_depth));
        t.row(n_row("batch_occupancy", &self.batch_occupancy));
        t.row(counter("requests", self.requests));
        t.row(counter("delivered", self.delivered));
        t.row(counter("shed", self.shed));
        t.row(counter("failed", self.failed));
        t.row(counter("retries", self.retries));
        t.row(counter("kv_retries", self.kv_retries));
        t.row(counter("degrade_shed", self.degrade_shed));
        t.row(counter("preemptions", self.preemptions));
        t.row(counter("prefill_chunks", self.prefill_chunks));
        t.row(counter("decode_iterations", self.decode_iterations));
        t.row(counter("handoffs", self.handoffs));
        t.row(counter("total_tokens", self.total_tokens));
        t.row(counter("map_cache_hits", self.map_cache_hits));
        t.row(counter("map_cache_misses", self.map_cache_misses));
        t.row(counter("map_warm_loads", self.map_warm_loads));
        t
    }
}

/// Export recorded event streams as Chrome-trace JSON ("JSON Array
/// Format" with metadata, loadable in `chrome://tracing` and Perfetto).
///
/// * `sim_tracks` — one `(name, events)` per simulated track, in track
///   order: the shards, then the KV link.  `ts` on these tracks is
///   **simulated nanoseconds** (the viewer labels the axis µs; read it
///   as ns — simulated time has no wall unit).
/// * `workers` — per-worker host-side counters; each worker becomes one
///   span on a `pid 1` track whose `ts` is **host wall nanoseconds**,
///   with the counters attached as args.
///
/// Spans export as balanced `B`/`E` pairs, instants as `i`; each track's
/// entries are sorted by timestamp, so per-track `ts` is monotonic — the
/// two invariants `tracecheck` enforces in CI.
pub fn chrome_trace(sim_tracks: &[(String, Vec<Event>)], workers: &[WorkerStats]) -> Value {
    let mut out: Vec<Value> = Vec::new();
    let meta = |name: &str, pid: f64, tid: f64, label: &str| {
        Value::obj(vec![
            ("name", Value::Str(name.into())),
            ("ph", Value::Str("M".into())),
            ("pid", Value::Num(pid)),
            ("tid", Value::Num(tid)),
            ("args", Value::obj(vec![("name", Value::Str(label.into()))])),
        ])
    };
    out.push(meta("process_name", 0.0, 0.0, "racam simulation (ts = simulated ns)"));
    if !workers.is_empty() {
        out.push(meta("process_name", 1.0, 0.0, "host executor (ts = wall ns)"));
    }
    for (tid, (name, events)) in sim_tracks.iter().enumerate() {
        let tid = tid as f64;
        out.push(meta("thread_name", 0.0, tid, name));
        // (ts, payload): spans emit a B and an E entry, instants one i.
        // Per-track stable sort by ts keeps timestamps monotonic even if
        // a hook ever records marginally out of order; generation order
        // breaks ties, so a B always precedes its own E.
        let mut entries: Vec<(f64, Value)> = Vec::with_capacity(events.len() * 2);
        for ev in events {
            let mut args = vec![("value", Value::Num(ev.value))];
            if ev.req != NO_REQ {
                args.push(("req", Value::Num(ev.req as f64)));
            }
            if ev.count != 1 {
                args.push(("count", Value::Num(ev.count as f64)));
            }
            let base = |ph: &str, ts: f64| {
                vec![
                    ("name", Value::Str(ev.kind.label().into())),
                    ("cat", Value::Str("sim".into())),
                    ("ph", Value::Str(ph.into())),
                    ("pid", Value::Num(0.0)),
                    ("tid", Value::Num(tid)),
                    ("ts", Value::Num(ts)),
                ]
            };
            if ev.kind.is_span() {
                let mut b = base("B", ev.at_ns);
                b.push(("args", Value::obj(args)));
                entries.push((ev.at_ns, Value::obj(b)));
                entries.push((ev.end_ns(), Value::obj(base("E", ev.end_ns()))));
            } else {
                let mut i = base("i", ev.at_ns);
                i.push(("s", Value::Str("t".into())));
                i.push(("args", Value::obj(args)));
                entries.push((ev.at_ns, Value::obj(i)));
            }
        }
        entries.sort_by(|a, b| a.0.total_cmp(&b.0));
        out.extend(entries.into_iter().map(|(_, v)| v));
    }
    for (w, stats) in workers.iter().enumerate() {
        let tid = w as f64;
        out.push(meta("thread_name", 1.0, tid, &format!("worker {w}")));
        let args = Value::obj(vec![
            ("polls", Value::Num(stats.polls as f64)),
            ("steals", Value::Num(stats.steals as f64)),
            ("blocked_streaks", Value::Num(stats.blocked_streaks as f64)),
            ("idle_sleeps", Value::Num(stats.idle_sleeps as f64)),
        ]);
        out.push(Value::obj(vec![
            ("name", Value::Str("worker".into())),
            ("cat", Value::Str("host".into())),
            ("ph", Value::Str("B".into())),
            ("pid", Value::Num(1.0)),
            ("tid", Value::Num(tid)),
            ("ts", Value::Num(0.0)),
            ("args", args),
        ]));
        out.push(Value::obj(vec![
            ("name", Value::Str("worker".into())),
            ("cat", Value::Str("host".into())),
            ("ph", Value::Str("E".into())),
            ("pid", Value::Num(1.0)),
            ("tid", Value::Num(tid)),
            ("ts", Value::Num(stats.wall_ns as f64)),
        ]));
    }
    Value::obj(vec![
        ("traceEvents", Value::Arr(out)),
        ("displayTimeUnit", Value::Str("ns".into())),
    ])
}

/// What [`validate_trace`] counted on a well-formed trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCheck {
    /// Non-metadata events.
    pub events: usize,
    /// Distinct (pid, tid) tracks with at least one event.
    pub tracks: usize,
    /// Balanced B/E span pairs.
    pub spans: usize,
}

/// Validate a parsed Chrome trace: `traceEvents` present, every entry
/// carries `ph`/`pid`/`tid` (+ `ts` for non-metadata), per-track
/// timestamps are monotonic in array order, and every `B` has a matching
/// same-name `E` (fully balanced at end of input).  The `tracecheck`
/// binary runs this in CI against the bench trace artifact.
pub fn validate_trace(trace: &Value) -> crate::Result<TraceCheck> {
    use std::collections::BTreeMap;
    let Ok(Value::Arr(events)) = trace.get("traceEvents") else {
        anyhow::bail!("trace has no traceEvents array");
    };
    // Track key → (last ts, open span-name stack).  A BTreeMap so the
    // end-of-trace unclosed-span scan below reports in a deterministic
    // track order (detcheck's map-iteration rule).
    let mut tracks: BTreeMap<(u64, u64), (f64, Vec<String>)> = BTreeMap::new();
    let mut counted = 0usize;
    let mut spans = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .ok()
            .and_then(|v| v.as_str().ok())
            .ok_or_else(|| anyhow::anyhow!("event {i}: missing ph"))?
            .to_string();
        if ph == "M" {
            continue; // metadata carries no timestamp
        }
        let num = |key: &str| -> crate::Result<f64> {
            ev.get(key)
                .ok()
                .and_then(|v| v.as_f64().ok())
                .ok_or_else(|| anyhow::anyhow!("event {i}: missing numeric '{key}'"))
        };
        let pid = num("pid")? as u64;
        let tid = num("tid")? as u64;
        let ts = num("ts")?;
        if !ts.is_finite() {
            anyhow::bail!("event {i}: non-finite ts");
        }
        let name = ev
            .get("name")
            .ok()
            .and_then(|v| v.as_str().ok())
            .unwrap_or_default()
            .to_string();
        let entry = tracks.entry((pid, tid)).or_insert((f64::NEG_INFINITY, Vec::new()));
        if ts < entry.0 {
            anyhow::bail!(
                "event {i} ('{name}'): ts {ts} goes backwards on track ({pid}, {tid}) \
                 (last {})",
                entry.0
            );
        }
        entry.0 = ts;
        counted += 1;
        match ph.as_str() {
            "B" => entry.1.push(name),
            "E" => {
                let open = entry.1.pop().ok_or_else(|| {
                    anyhow::anyhow!("event {i} ('{name}'): E with no open span on ({pid}, {tid})")
                })?;
                if !name.is_empty() && open != name {
                    anyhow::bail!(
                        "event {i}: E('{name}') closes B('{open}') on track ({pid}, {tid})"
                    );
                }
                spans += 1;
            }
            "i" | "I" => {}
            other => anyhow::bail!("event {i}: unsupported ph '{other}'"),
        }
    }
    for ((pid, tid), (_, stack)) in &tracks {
        if !stack.is_empty() {
            anyhow::bail!(
                "track ({pid}, {tid}) ends with {} unclosed span(s): {stack:?}",
                stack.len()
            );
        }
    }
    Ok(TraceCheck { events: counted, tracks: tracks.len(), spans })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_powers_of_two() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(1023), 9);
        assert_eq!(Histogram::bucket_of(1024), 10);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
    }

    #[test]
    fn histogram_records_and_summarizes() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        for v in [1u64, 2, 3, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.len(), 5);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        assert_eq!(h.mean(), (1.0 + 2.0 + 3.0 + 100.0 + 1000.0) / 5.0);
        // p50 = 3rd sample (value 3) → bucket [2,4) upper bound 3.
        assert_eq!(h.quantile(0.5), 3);
        // p99 lands in the last occupied bucket, clamped to max.
        assert_eq!(h.quantile(0.99), 1000);
    }

    #[test]
    fn record_n_matches_repeated_record() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_n(37, 1000);
        for _ in 0..1000 {
            b.record(37);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn quantize_handles_degenerate_inputs() {
        assert_eq!(quantize_ns(-5.0), 0);
        assert_eq!(quantize_ns(f64::NAN), 0);
        assert_eq!(quantize_ns(f64::INFINITY), u64::MAX);
        assert_eq!(quantize_ns(1.4), 1);
        assert_eq!(quantize_ns(1.6), 2);
    }

    #[test]
    fn merge_is_exact_and_handles_empty() {
        let mut a = Histogram::new();
        a.record(5);
        let empty = Histogram::new();
        let mut merged = a;
        merged.merge(&empty);
        assert_eq!(merged, a, "merging an empty histogram is the identity");
        let mut e2 = empty;
        e2.merge(&a);
        assert_eq!(e2, a);
    }

    #[test]
    fn metrics_absorbs_admit_and_stretch_events() {
        let mut m = Metrics::default();
        m.absorb_events(&[
            Event::instant(EventKind::Admit, 0.0, 1, 7.0),
            Event { kind: EventKind::DecodeStretch, at_ns: 0.0, dur_ns: 10.0, req: NO_REQ, value: 4.0, count: 25 },
            Event::instant(EventKind::Shed, 5.0, 2, 0.0), // ignored
        ]);
        assert_eq!(m.queue_depth.len(), 1);
        assert_eq!(m.queue_depth.max(), 7);
        assert_eq!(m.batch_occupancy.len(), 25, "a stretch fans out to per-iteration samples");
        assert_eq!(m.batch_occupancy.max(), 4);
    }

    #[test]
    fn metrics_table_and_json_cover_every_registry_entry() {
        let mut m = Metrics::default();
        m.requests = 3;
        m.ttft_ns.record(1_000_000);
        m.absorb_mapping((5, 2, 1));
        let t = m.table("metrics");
        assert_eq!(t.num_rows(), 19);
        let v = m.to_json();
        assert_eq!(v.get("requests").unwrap().as_u32().unwrap(), 3);
        assert_eq!(v.get("map_cache_hits").unwrap().as_u32().unwrap(), 5);
        assert_eq!(v.get("map_cache_misses").unwrap().as_u32().unwrap(), 2);
        assert_eq!(v.get("map_warm_loads").unwrap().as_u32().unwrap(), 1);
        assert_eq!(v.get("ttft_ns").unwrap().get("total").unwrap().as_u32().unwrap(), 1);
        // The summary JSON round-trips through the strict parser.
        let parsed = crate::config::json::parse(&v.pretty()).unwrap();
        assert_eq!(parsed.get("shed").unwrap().as_u32().unwrap(), 0);
    }

    #[test]
    fn chrome_trace_exports_balanced_monotonic_tracks() {
        let shard0 = vec![
            Event::span(EventKind::PrefillChunk, 0.0, 10.0, 1, 64.0),
            Event { kind: EventKind::DecodeStretch, at_ns: 10.0, dur_ns: 40.0, req: NO_REQ, value: 2.0, count: 8 },
            Event::instant(EventKind::Admit, 50.0, 2, 1.0),
        ];
        let link = vec![
            Event::span(EventKind::KvWire, 12.0, 6.0, 1, 4096.0),
            Event::instant(EventKind::DecodeRelease, 18.0, 1, 1.0),
        ];
        let workers = vec![WorkerStats { polls: 10, steals: 2, blocked_streaks: 0, idle_sleeps: 1, wall_ns: 5_000 }];
        let trace = chrome_trace(
            &[("shard 0".to_string(), shard0), ("kv link".to_string(), link)],
            &workers,
        );
        let check = validate_trace(&trace).unwrap();
        assert_eq!(check.tracks, 3, "two sim tracks + one worker track");
        assert_eq!(check.spans, 4, "prefill + stretch + wire + worker");
        // And the emitted JSON survives the strict parser.
        let reparsed = crate::config::json::parse(&trace.pretty()).unwrap();
        assert!(validate_trace(&reparsed).is_ok());
    }

    #[test]
    fn validate_trace_rejects_malformed_traces() {
        use crate::config::json::parse;
        // Not a trace at all.
        assert!(validate_trace(&parse("{\"a\": 1}").unwrap()).is_err());
        // Backwards timestamps on one track.
        let bad_ts = r#"{"traceEvents": [
            {"name": "x", "ph": "i", "pid": 0, "tid": 0, "ts": 10.0, "s": "t"},
            {"name": "y", "ph": "i", "pid": 0, "tid": 0, "ts": 5.0, "s": "t"}
        ]}"#;
        assert!(validate_trace(&parse(bad_ts).unwrap()).is_err());
        // Same timestamps on *different* tracks are fine.
        let two_tracks = r#"{"traceEvents": [
            {"name": "x", "ph": "i", "pid": 0, "tid": 0, "ts": 10.0, "s": "t"},
            {"name": "y", "ph": "i", "pid": 0, "tid": 1, "ts": 5.0, "s": "t"}
        ]}"#;
        assert!(validate_trace(&parse(two_tracks).unwrap()).is_ok());
        // Unbalanced span.
        let unbalanced = r#"{"traceEvents": [
            {"name": "x", "ph": "B", "pid": 0, "tid": 0, "ts": 1.0}
        ]}"#;
        assert!(validate_trace(&parse(unbalanced).unwrap()).is_err());
        // E closing the wrong span name.
        let crossed = r#"{"traceEvents": [
            {"name": "x", "ph": "B", "pid": 0, "tid": 0, "ts": 1.0},
            {"name": "y", "ph": "E", "pid": 0, "tid": 0, "ts": 2.0}
        ]}"#;
        assert!(validate_trace(&parse(crossed).unwrap()).is_err());
    }

    #[test]
    fn nop_recorder_is_zero_sized() {
        assert_eq!(std::mem::size_of::<NopRecorder>(), 0);
    }
}
