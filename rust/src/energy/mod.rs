//! Energy model — the paper motivates PIM by "energy per transferred byte"
//! (§1) and in-DRAM broadcast by avoiding "costly off-chip transfers"; this
//! module quantifies those claims per kernel with standard DDR5 energy
//! constants, mirroring how the latency model prices the same events.
//!
//! Events accounted per kernel (from the mapping evaluation):
//! * DRAM row activations/precharges (ACT+PRE pair energy),
//! * locality-buffer accesses + PE switching (per SIMD pass),
//! * popcount reduction unit cycles,
//! * off-chip channel transfer energy (pJ/bit, the §1 bottleneck),
//! * internal-fabric transfer energy (an order of magnitude cheaper).

use crate::config::Precision;
use crate::mapping::Evaluation;

/// Energy constants (pJ).  DDR5-class numbers from public spec analyses;
/// logic energies from the same 14 nm synthesis point as the area model.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// One ACT+PRE pair on one subarray row, pJ.
    pub act_pre_pj: f64,
    /// One locality-buffer row access (1024 bits), pJ.
    pub lb_access_pj: f64,
    /// One PE bit-serial cycle (per PE), pJ.
    pub pe_cycle_pj: f64,
    /// One popcount-unit cycle (1024-input tree + accumulate), pJ.
    pub popcount_cycle_pj: f64,
    /// Off-chip channel transfer, pJ per bit (the expensive path, §1).
    pub channel_pj_per_bit: f64,
    /// Internal global-bitline / broadcast-fabric transfer, pJ per bit.
    pub internal_pj_per_bit: f64,
    /// Host-side reduction, pJ per int32 add.
    pub host_add_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            act_pre_pj: 909.0,         // DDR5 row ACT+PRE (per-device row segment)
            lb_access_pj: 15.0,        // SRAM row of 1024 bits
            pe_cycle_pj: 0.08,         // 1-bit FA + latches at 14 nm
            popcount_cycle_pj: 45.0,   // 1024-input popcount tree
            channel_pj_per_bit: 22.0,  // off-chip DDR5 I/O + termination
            internal_pj_per_bit: 1.2,  // on-die global bitline
            host_add_pj: 8.0,
        }
    }
}

/// Per-kernel energy estimate, nJ.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyEstimate {
    pub row_nj: f64,
    pub compute_nj: f64,
    pub channel_nj: f64,
    pub internal_nj: f64,
    pub host_nj: f64,
}

impl EnergyEstimate {
    pub fn total_nj(&self) -> f64 {
        self.row_nj + self.compute_nj + self.channel_nj + self.internal_nj + self.host_nj
    }

    /// Energy per useful MAC, pJ.
    pub fn pj_per_mac(&self, macs: u64) -> f64 {
        self.total_nj() * 1e3 / macs.max(1) as f64
    }
}

impl EnergyModel {
    /// Price a mapped kernel from its evaluation (the same event counts the
    /// latency model produced) at `prec` with PE width `pe_width`.
    pub fn kernel_energy(
        &self,
        eval: &Evaluation,
        prec: Precision,
        pe_width: u64,
        macs: u64,
    ) -> EnergyEstimate {
        let n = prec.bits() as f64;
        // Row traffic: the evaluation's row-access count are streamed
        // buffer fills — price each as an LB access plus an amortized
        // fraction of an ACT (SALP keeps rows open across a block's
        // passes; ~1 full ACT+PRE per 16 streamed rows).
        let row_nj =
            (eval.row_accesses * (self.lb_access_pj + self.act_pre_pj / 16.0)) / 1e3;
        // PE switching: every pass clocks the full PE width for n²+4 cycles.
        let pe_cycles = eval.passes * (n * n + 4.0) * pe_width as f64;
        // Popcount: 2n slices per pass (when the reduction ran in-DRAM).
        let pop_cycles = eval.passes * 2.0 * n;
        let compute_nj =
            (pe_cycles * self.pe_cycle_pj + pop_cycles * self.popcount_cycle_pj) / 1e3;
        // External vs internal data movement.
        let channel_nj =
            ((eval.io_in_bytes + eval.io_out_bytes) as f64 * 8.0 * self.channel_pj_per_bit) / 1e3;
        // Internal relayout ≈ input bytes once over the internal fabric.
        let internal_nj = (eval.io_in_bytes.max(1) as f64 * 8.0 * self.internal_pj_per_bit) / 1e3;
        let host_nj = eval.host_reduce_ns * self.host_add_pj / 1e3; // ≈ adds × pJ (1 add/ns-model)
        let _ = macs;
        EnergyEstimate { row_nj, compute_nj, channel_nj, internal_nj, host_nj }
    }

    /// Energy of moving `bytes` across the off-chip channel `copies` times
    /// vs. broadcasting internally — the §1 replication argument.
    pub fn replication_energy_nj(&self, bytes: u64, copies: u64, with_bu: bool) -> f64 {
        let bits = (bytes * 8) as f64;
        if with_bu {
            (bits * self.channel_pj_per_bit + bits * (copies.saturating_sub(1)) as f64 * self.internal_pj_per_bit)
                / 1e3
        } else {
            bits * copies as f64 * self.channel_pj_per_bit / 1e3
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, MatmulShape, Precision};
    use crate::mapping::{HwModel, MappingEngine};

    fn eval(shape: &MatmulShape) -> Evaluation {
        MappingEngine::new(HwModel::new(&racam_paper())).search(shape).expect("evaluates").best
    }

    #[test]
    fn broadcast_saves_an_order_of_magnitude() {
        let m = EnergyModel::default();
        let with_bu = m.replication_energy_nj(12_288, 1024, true);
        let without = m.replication_energy_nj(12_288, 1024, false);
        assert!(without / with_bu > 10.0, "ratio {}", without / with_bu);
    }

    #[test]
    fn compute_dominates_large_gemm_energy() {
        // Weights never move; for a big GEMM the PE/row energy should
        // dwarf channel energy (the PIM thesis).
        let shape = MatmulShape::new(8192, 8192, 8192, Precision::Int8);
        let e = eval(&shape);
        let m = EnergyModel::default();
        let est = m.kernel_energy(&e, shape.prec, 1024, shape.macs());
        assert!(est.compute_nj + est.row_nj > 5.0 * est.channel_nj, "{est:?}");
        // Bit-serial int8 MACs land in a plausible pJ/MAC band.
        let pj = est.pj_per_mac(shape.macs());
        assert!((0.1..100.0).contains(&pj), "pJ/MAC {pj}");
    }

    #[test]
    fn lower_precision_costs_less_energy() {
        let s8 = MatmulShape::new(1024, 4096, 4096, Precision::Int8);
        let s4 = MatmulShape { prec: Precision::Int4, ..s8 };
        let m = EnergyModel::default();
        let e8 = m.kernel_energy(&eval(&s8), s8.prec, 1024, s8.macs()).total_nj();
        let e4 = m.kernel_energy(&eval(&s4), s4.prec, 1024, s4.macs()).total_nj();
        assert!(e4 < e8, "int4 {e4} vs int8 {e8}");
    }

    #[test]
    fn totals_add_up() {
        let est = EnergyEstimate {
            row_nj: 1.0,
            compute_nj: 2.0,
            channel_nj: 3.0,
            internal_nj: 4.0,
            host_nj: 5.0,
        };
        assert_eq!(est.total_nj(), 15.0);
        assert!((est.pj_per_mac(3000) - 5.0).abs() < 1e-12);
    }
}
