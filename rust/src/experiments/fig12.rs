//! Fig. 12 — architecture ablation: progressively disable the popcount
//! reduction units (PR), broadcast units (BU) and locality buffers (LB),
//! re-search the mapping space under each feature set, and report latency
//! normalized to the complete design.

use super::common::{racam_stage_latency, racam_with};
use crate::config::{paper_models, Features, Stage};
use crate::report::Table;

pub const ABLATION_POINTS: [Features; 4] =
    [Features::ALL, Features::NO_PR, Features::NO_PR_BU, Features::NO_PR_BU_LB];

pub fn run() -> Vec<Table> {
    let mut out = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        let mut t = Table::new(
            &format!("Fig.12 — ablation, {} latency normalized to complete RACAM", stage.label()),
            &["model", "complete", "-PR", "-PR-BU", "-PR-BU-LB"],
        );
        for spec in paper_models() {
            let mut cells = vec![spec.name.clone()];
            let base =
                racam_stage_latency(&racam_with(Features::ALL), &spec, stage).total_ns();
            for f in ABLATION_POINTS {
                let ns = racam_stage_latency(&racam_with(f), &spec, stage).total_ns();
                cells.push(format!("{:.2}", ns / base));
            }
            t.row(cells);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(t: &Table) -> Vec<Vec<f64>> {
        t.to_csv()
            .lines()
            .skip(1)
            .map(|l| l.split(',').skip(1).map(|c| c.parse().unwrap()).collect())
            .collect()
    }

    #[test]
    fn ablation_is_monotone_and_lb_matters_most() {
        for t in run() {
            for r in rows(&t) {
                assert!((r[0] - 1.0).abs() < 1e-9);
                // Each removed unit hurts (weakly monotone).
                assert!(r[1] >= 1.0 - 1e-9, "-PR {}", r[1]);
                assert!(r[2] >= r[1] - 1e-9, "-PR-BU {} vs -PR {}", r[2], r[1]);
                assert!(r[3] >= r[2] - 1e-9, "-LB {} vs -PR-BU {}", r[3], r[2]);
                // LB removal is the largest jump (paper: 4.7–8x overall).
                assert!(r[3] > 2.0, "full ablation must cost >2x, got {}", r[3]);
            }
        }
    }
}
