//! Extension experiments beyond the paper's figures (DESIGN.md step 5):
//!
//! * `ext-energy` — per-kernel energy of RACAM inference (the §1 "energy
//!   per transferred byte" motivation quantified) + broadcast-unit energy
//!   savings.
//! * `ext-reliability` — §7's RowHammer-style activation-pressure analysis:
//!   RACAM vs a reuse-free PUD at equal throughput, and the throttle the
//!   scheduler must apply.
//! * `ext-trace` — trace-driven validation: FSM-expanded DRAM command
//!   streams vs the closed-form analytical model (the Ramulator-validation
//!   analogue of §5.1).

use crate::config::{ddr5_5200_timing, gpt3_6_7b, racam_paper, Precision};
use crate::dram::ReliabilityModel;
use crate::energy::EnergyModel;
use crate::pim::trace::validate_against_analytical;
use crate::report::Table;
use crate::workloads::{decode_kernels, RacamSystem};

pub fn run_energy() -> Vec<Table> {
    let model = EnergyModel::default();
    let sys = RacamSystem::new(&racam_paper());
    let spec = gpt3_6_7b();

    let mut t = Table::new(
        "Ext — energy of GPT-3 6.7B decode kernels (ctx 1024) on RACAM",
        &["kernel", "shape", "total_nJ", "pJ/MAC", "compute%", "channel%"],
    );
    for k in decode_kernels(&spec, 1024) {
        let r = sys.search(&k.shape).expect("decode kernels always map");
        let e = model.kernel_energy(&r.best, k.shape.prec, 1024, k.shape.macs());
        t.row(vec![
            k.label.into(),
            k.shape.label(),
            format!("{:.1}", e.total_nj()),
            format!("{:.2}", e.pj_per_mac(k.shape.macs())),
            format!("{:.0}", 100.0 * (e.compute_nj + e.row_nj) / e.total_nj()),
            format!("{:.0}", 100.0 * e.channel_nj / e.total_nj()),
        ]);
    }

    let mut bu = Table::new(
        "Ext — broadcast-unit energy saving (12 KB activation vector)",
        &["copies", "with_BU_nJ", "without_BU_nJ", "saving"],
    );
    for copies in [16u64, 128, 1024, 8192] {
        let with = model.replication_energy_nj(12_288, copies, true);
        let without = model.replication_energy_nj(12_288, copies, false);
        bu.row(vec![
            copies.to_string(),
            format!("{with:.0}"),
            format!("{without:.0}"),
            format!("{:.1}x", without / with),
        ]);
    }
    vec![t, bu]
}

pub fn run_reliability() -> Vec<Table> {
    let m = ReliabilityModel::default();
    let mut t = Table::new(
        "Ext — §7 activation pressure at equal throughput (1 TMAC/s, 1 MiB-row footprint)",
        &["design", "row_accesses/mult", "acts/row/tREFW", "budget", "throttle"],
    );
    for (name, accesses) in [
        ("RACAM (LB, 4n)", 4 * 8u64),
        ("no-reuse PUD (3n²+2n)", 3 * 64 + 16),
    ] {
        let v = m.pressure(1e12, 1024, accesses, 1 << 20);
        t.row(vec![
            name.into(),
            accesses.to_string(),
            format!("{:.0}", v.peak_row_acts_per_window),
            format!("{:.3}", v.budget_fraction),
            format!("{:.2}x", v.required_throttle),
        ]);
    }
    vec![t]
}

pub fn run_trace() -> Vec<Table> {
    let t_params = ddr5_5200_timing();
    let mut t = Table::new(
        "Ext — trace-driven vs analytical multiply latency (128-subarray SALP)",
        &["precision", "analytical_row_acts", "traced_row_acts", "analytical_ns", "trace_ns", "error"],
    );
    for prec in [Precision::Int2, Precision::Int4, Precision::Int8] {
        let (a_acts, t_acts, a_ns, t_ns) =
            validate_against_analytical(prec, 128, &t_params).expect("trace replay");
        t.row(vec![
            prec.label().into(),
            a_acts.to_string(),
            t_acts.to_string(),
            format!("{a_ns:.1}"),
            format!("{t_ns:.1}"),
            format!("{:.1}%", 100.0 * (a_ns - t_ns).abs() / a_ns),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn extension_experiments_run() {
        assert_eq!(super::run_energy().len(), 2);
        assert_eq!(super::run_reliability().len(), 1);
        let trace = super::run_trace();
        // Every traced row matches the analytical count exactly.
        for line in trace[0].to_csv().lines().skip(1) {
            let c: Vec<&str> = line.split(',').collect();
            assert_eq!(c[1], c[2], "{line}");
        }
    }
}
