//! `exp traffic` — SLO-graded serving under open-loop load: admission
//! policies (FCFS, length-bucketed, EDF) × arrival rates on the paper's
//! model presets, reporting TTFT/TPOT tails, goodput under a deadline, and
//! per-shard utilization.
//!
//! Shards price against their honest share of the paper device's DRAM
//! channels (the [`ClusterBuilder`]'s channel partition), and the
//! per-shard [`MappingService`]s are shared across every cell of the
//! matrix, so the
//! comparison isolates *scheduling* — every policy prices identical kernel
//! shapes from identical caches on identical hardware shares.  The streams
//! are seed-deterministic: at a given rate, every scheduler sees the same
//! arrivals, prompts and deadlines.

use crate::config::json::Value;
use crate::config::{
    gpt3_6_7b, llama3_8b, racam_paper, ArrivalProcess, ClusterSpec, LengthDist, LlmSpec,
    SchedulerKind, TrafficSpec,
};
use crate::coordinator::{ClusterBuilder, SyntheticEngine};
use crate::mapping::MappingService;
use crate::report::Table;
use crate::telemetry::Metrics;
use crate::traffic::{generate, SloSummary};

/// Shards per run (2 keeps the per-shard utilization table meaningful
/// without doubling pricing work).
const SHARDS: usize = 2;
const MAX_BATCH: usize = 4;
const DEADLINE_NS: u64 = 80_000_000; // 80 ms end-to-end SLO
const SEED: u64 = 0x5EED_7A_FF1C;
/// Admission policies compared, in row order within each rate.
const SCHEDULERS: &[&str] = &["fcfs", "bucketed", "edf"];
/// Rates straddle the 2-shard service capacity so the tables show the
/// whole story: queueing-free, near-saturation, and overload.
const GPT_RATES: &[f64] = &[50.0, 200.0, 800.0];
const GPT_REQUESTS: u64 = 36;
const LLAMA_RATES: &[f64] = &[200.0];
const LLAMA_REQUESTS: u64 = 24;

/// Experiment-specific entries for the `BENCH_traffic.json` config block:
/// scheduler names and arrival rates, so the perf trajectory is diffable
/// without parsing table titles.
pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    vec![
        (
            "schedulers",
            Value::Arr(SCHEDULERS.iter().map(|s| Value::Str(s.to_string())).collect()),
        ),
        ("rates_per_s", Value::Arr(GPT_RATES.iter().map(|r| Value::Num(*r)).collect())),
        (
            "llama_rates_per_s",
            Value::Arr(LLAMA_RATES.iter().map(|r| Value::Num(*r)).collect()),
        ),
        ("requests", Value::Num(GPT_REQUESTS as f64)),
        ("deadline_ms", Value::Num(DEADLINE_NS as f64 / 1e6)),
    ]
}

fn spec_at(rate_per_s: f64, requests: u64) -> TrafficSpec {
    TrafficSpec {
        seed: SEED,
        requests,
        arrival: ArrivalProcess::Poisson { rate_per_s },
        // A few prompt buckets (256-token granularity) so prefill pricing
        // stays bounded while lengths still spread across buckets.
        prompt: LengthDist::Uniform { lo: 64, hi: 768 },
        output: LengthDist::Uniform { lo: 4, hi: 12 },
        deadline_ns: Some(DEADLINE_NS),
    }
}

/// Run one (scheduler, rate) cell and grade it.  `services` is one
/// (channel-partitioned) mapping service per shard, shared across cells so
/// pricing amortizes.
fn run_cell(
    services: &[MappingService],
    model: &LlmSpec,
    traffic: &TrafficSpec,
    scheduler: SchedulerKind,
) -> crate::Result<SloSummary> {
    let mut spec = ClusterSpec::unified(services.len(), MAX_BATCH);
    spec.groups[0].scheduler = scheduler;
    let mut coord =
        ClusterBuilder::with_spec_and_services(spec, model.clone(), services.to_vec())?
            .build(|_| SyntheticEngine::new(64, 256));
    for req in generate(traffic) {
        coord.submit(req);
    }
    let report = coord.run_to_completion()?;
    Ok(SloSummary::from_report(&report))
}

/// The scheduler × rate matrix for one model, plus the telemetry
/// [`Metrics`] registry merged over every cell in row order (so the
/// bench artifact's counters are deterministic across thread counts).
pub(crate) fn matrix(
    model: &LlmSpec,
    rates: &[f64],
    requests: u64,
) -> crate::Result<(Table, Table, Metrics)> {
    // Honest per-shard bandwidth: each shard prices against its own share
    // of the paper device's channels (4 of 8 at SHARDS = 2), reused across
    // every cell of the matrix.
    let services: Vec<MappingService> = ClusterBuilder::new(
        ClusterSpec::unified(SHARDS, MAX_BATCH),
        &racam_paper(),
        model.clone(),
    )?
    .services()
    .to_vec();
    let headers = SloSummary::table_headers();
    let mut t = Table::new(
        &format!(
            "Traffic — {} serving, {SHARDS} shards (channel-partitioned) × batch {MAX_BATCH}, Poisson arrivals, {}ms e2e SLO",
            model.name,
            DEADLINE_NS / 1_000_000
        ),
        &headers,
    );
    let mut util_summary = None;
    let mut metrics = Metrics::default();
    for &rate in rates {
        let traffic = spec_at(rate, requests);
        // The SCHEDULERS roster bench_config() reports drives the rows,
        // so the BENCH json and the table cannot drift apart: a roster
        // entry the SchedulerKind registry does not know fails loudly
        // instead of silently reporting schedulers that have no rows.
        for &sched in SCHEDULERS {
            let kind = SchedulerKind::from_label(sched)
                .ok_or_else(|| anyhow::anyhow!("no scheduler kind named '{sched}'"))?;
            let cell = run_cell(&services, model, &traffic, kind)?;
            metrics.merge(&cell.metrics);
            if kind == SchedulerKind::Fcfs {
                util_summary = Some(cell.clone());
            }
            t.row(cell.table_row(&format!("{sched}@{rate}/s")));
        }
    }
    let util = util_summary
        .expect("at least one rate")
        .shard_table(&format!("Traffic — per-shard utilization ({}, FCFS, highest rate)", model.name));
    metrics.absorb_mapping(super::common::mapping_counters(&services));
    Ok((t, util, metrics))
}

pub fn run() -> crate::Result<(Vec<Table>, Metrics)> {
    let (gpt, gpt_util, mut metrics) = matrix(&gpt3_6_7b(), GPT_RATES, GPT_REQUESTS)?;
    // One mid rate on a Llama preset: GQA + gated FFN change the kernel
    // mix, not the scheduling conclusions.
    let (llama, _, llama_metrics) = matrix(&llama3_8b(), LLAMA_RATES, LLAMA_REQUESTS)?;
    metrics.merge(&llama_metrics);
    Ok((vec![gpt, gpt_util, llama], metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    #[test]
    fn matrix_compares_all_three_schedulers() {
        let (t, util, metrics) = matrix(&tiny_spec(), &[1000.0], 6).unwrap();
        assert_eq!(t.num_rows(), 3, "fcfs + bucketed + edf");
        let rendered = t.render();
        assert!(rendered.contains("fcfs@1000"), "{rendered}");
        assert!(rendered.contains("bucketed@1000"), "{rendered}");
        assert!(rendered.contains("edf@1000"), "{rendered}");
        assert_eq!(util.num_rows(), SHARDS);
        assert_eq!(metrics.requests, 3 * 6, "3 cells x 6 requests merge into the registry");
        assert!(metrics.ttft_ns.len() > 0);
    }

    #[test]
    fn schedulers_see_identical_streams() {
        // The generator is scheduler-agnostic: the spec alone fixes the
        // stream.
        let a = generate(&spec_at(100.0, 12));
        let b = generate(&spec_at(100.0, 12));
        assert_eq!(a, b);
    }
}
