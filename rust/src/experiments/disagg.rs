//! `exp disagg` — unified vs prefill/decode-disaggregated serving under a
//! long-prompt mixed workload, declared through [`ClusterSpec`] and built
//! by [`ClusterBuilder`].
//!
//! The comparison holds hardware and traffic fixed: every cluster covers
//! the same 4 shards' worth of the paper device (auto-partitioned, so the
//! 4-unified and 2-prefill + 2-decode layouts price against identical
//! 2-channel shards from one shared mapping cache), and every cell replays
//! the same seed-deterministic stream.  What changes is the *topology*:
//! unified shards interleave prefill with decode on one clock, while the
//! disaggregated cluster runs prompts on dedicated prefill shards and
//! ships each finished KV cache to a decode shard over the cluster's
//! simulated KV link ([`ShardStats::kv_transfer_ns`]).
//!
//! Headline columns: the **p95 TTFT** (whole population and the
//! short-request slice) and the **decode stall** — the time decoders sat
//! behind prefill steps, which disaggregation eliminates by construction
//! and whole-prompt unified serving pays in full — next to the KV-link
//! cost the disaggregated topology pays instead.
//!
//! [`ShardStats::kv_transfer_ns`]: crate::coordinator::ShardStats

use crate::config::json::Value;
use crate::config::{
    gpt3_6_7b, racam_paper, ArrivalProcess, ClusterSpec, LengthDist, LlmSpec, ServingPolicy,
    TrafficSpec,
};
use crate::coordinator::{ClusterBuilder, Request, SyntheticEngine};
use crate::mapping::MappingService;
use crate::metrics::fmt_ns;
use crate::report::Table;
use crate::telemetry::Metrics;
use crate::traffic::{generate, ttft_percentiles_where, SloSummary};

/// Total shards per cluster (channel partition: 4 × 2 of the paper's 8).
const SHARDS: usize = 4;
const MAX_BATCH: usize = 4;
const SEED: u64 = 0xD15A_66;
/// Rates straddling the 4-shard capacity under the long-prompt mix.
const RATES: &[f64] = &[150.0, 600.0];
const SHORT_REQUESTS: u64 = 28;
const LONG_REQUESTS: u64 = 6;
const LONG_PROMPT: u64 = 2048;
/// Prompt-length boundary between the short and long populations.
const SHORT_MAX_PROMPT: usize = 256;
const DEADLINE_NS: u64 = 150_000_000; // 150 ms mean e2e SLO
/// Prefill chunk of the chunked-unified middle point.
const CHUNK: u64 = 256;

/// The cluster layouts compared, in row order (label, spec).
fn clusters() -> Vec<(&'static str, ClusterSpec)> {
    let mut chunked = ClusterSpec::unified(SHARDS, MAX_BATCH);
    chunked.groups[0].policy = ServingPolicy::chunked(CHUNK);
    vec![
        ("unified", ClusterSpec::unified(SHARDS, MAX_BATCH)),
        ("unified/chunk256", chunked),
        ("disagg 2p+2d", ClusterSpec::disaggregated(2, 2, MAX_BATCH)),
    ]
}

/// Experiment-specific entries for the `BENCH_disagg.json` config block.
pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    vec![
        (
            "clusters",
            Value::Arr(clusters().iter().map(|(l, _)| Value::Str(l.to_string())).collect()),
        ),
        ("schedulers", Value::Arr(vec![Value::Str("fcfs".into())])),
        ("rates_per_s", Value::Arr(RATES.iter().map(|r| Value::Num(*r)).collect())),
        ("requests", Value::Num((SHORT_REQUESTS + LONG_REQUESTS) as f64)),
        ("long_prompt_tokens", Value::Num(LONG_PROMPT as f64)),
        ("deadline_ms", Value::Num(DEADLINE_NS as f64 / 1e6)),
        (
            "kv_link_gbps",
            Value::Num(ClusterSpec::disaggregated(2, 2, MAX_BATCH).kv_link_gbps),
        ),
    ]
}

/// The mixed workload: mostly short prompts at `rate_per_s`, plus long
/// prompts at a proportional trickle, merged into one arrival-ordered
/// stream with sequential ids.
fn mixed_stream(rate_per_s: f64, shorts: u64, longs: u64) -> Vec<Request> {
    let short = generate(&TrafficSpec {
        seed: SEED,
        requests: shorts,
        arrival: ArrivalProcess::Poisson { rate_per_s },
        prompt: LengthDist::Uniform { lo: 16, hi: 96 },
        output: LengthDist::Uniform { lo: 6, hi: 12 },
        deadline_ns: Some(DEADLINE_NS),
    });
    let long = generate(&TrafficSpec {
        seed: SEED ^ 0x9e37,
        requests: longs,
        arrival: ArrivalProcess::Poisson {
            rate_per_s: rate_per_s * longs.max(1) as f64 / shorts.max(1) as f64,
        },
        prompt: LengthDist::Fixed(LONG_PROMPT),
        output: LengthDist::Uniform { lo: 2, hi: 6 },
        deadline_ns: Some(DEADLINE_NS),
    });
    let mut all: Vec<Request> = short.into_iter().chain(long).collect();
    all.sort_by_key(|r| r.arrival_ns);
    for (i, r) in all.iter_mut().enumerate() {
        r.id = i as u64;
    }
    all
}

/// One graded cell plus the headline slices the table leads with.
struct Cell {
    summary: SloSummary,
    ttft_p95: f64,
    short_ttft_p95: f64,
}

impl Cell {
    fn headers() -> Vec<&'static str> {
        vec![
            "run",
            "reqs",
            "ttft_p95",
            "short_ttft_p95",
            "decode_stall",
            "kv_transfer",
            "handoffs",
            "e2e_p99",
            "goodput_tok/s",
            "slo_met",
            "util",
        ]
    }

    fn row(&self, label: &str) -> Vec<String> {
        let s = &self.summary;
        let busy = if s.shard_utilization.is_empty() {
            0.0
        } else {
            s.shard_utilization.iter().map(|u| u.busy).sum::<f64>()
                / s.shard_utilization.len() as f64
        };
        vec![
            label.to_string(),
            s.requests.to_string(),
            fmt_ns(self.ttft_p95),
            fmt_ns(self.short_ttft_p95),
            fmt_ns(s.chunk_stall_ns),
            fmt_ns(s.kv_transfer_ns),
            s.handoffs.to_string(),
            fmt_ns(s.e2e.p99),
            format!("{:.0}", s.goodput_tokens_per_s),
            format!("{:.0}%", 100.0 * s.slo_attainment),
            format!("{:.0}%", 100.0 * busy),
        ]
    }
}

/// Serve one (cluster, rate) cell over `stream` and grade it.
fn run_cell(
    services: &[MappingService],
    model: &LlmSpec,
    spec: ClusterSpec,
    stream: &[Request],
) -> crate::Result<Cell> {
    let mut coord =
        ClusterBuilder::with_spec_and_services(spec, model.clone(), services.to_vec())?
            .build(|_| SyntheticEngine::new(64, 256));
    for req in stream {
        coord.submit(req.clone());
    }
    let report = coord.run_to_completion()?;
    let short = ttft_percentiles_where(&report, |r| r.prompt_tokens <= SHORT_MAX_PROMPT);
    let all = ttft_percentiles_where(&report, |_| true);
    Ok(Cell {
        summary: SloSummary::from_report(&report),
        ttft_p95: all.p95,
        short_ttft_p95: short.p95,
    })
}

/// The cluster × rate matrix, plus the per-group utilization view of the
/// disaggregated cluster at the highest rate and the telemetry
/// [`Metrics`] registry merged over every cell in row order.
fn matrix(
    services: &[MappingService],
    model: &LlmSpec,
    rates: &[f64],
    shorts: u64,
    longs: u64,
) -> crate::Result<(Table, Table, Metrics)> {
    let mut t = Table::new(
        &format!(
            "Disaggregation — unified vs prefill/decode split, {} on {SHARDS} shards × batch \
             {MAX_BATCH}; {longs} long ({LONG_PROMPT} tok) per {shorts} short requests, \
             {}ms e2e SLO",
            model.name,
            DEADLINE_NS / 1_000_000
        ),
        &Cell::headers(),
    );
    let mut disagg_summary = None;
    let mut metrics = Metrics::default();
    for &rate in rates {
        let stream = mixed_stream(rate, shorts, longs);
        for (label, spec) in clusters() {
            let disaggregated = spec.is_disaggregated();
            let cell = run_cell(services, model, spec, &stream)?;
            metrics.merge(&cell.summary.metrics);
            if disaggregated {
                disagg_summary = Some(cell.summary.clone());
            }
            t.row(cell.row(&format!("{label}@{rate}/s")));
        }
    }
    let util = disagg_summary
        .expect("the roster contains a disaggregated cluster")
        .utilization_table(
            &format!(
                "Disaggregation — per-group utilization ({}, disaggregated, highest rate)",
                model.name
            ),
            false,
        );
    metrics.absorb_mapping(super::common::mapping_counters(services));
    Ok((t, util, metrics))
}

pub fn run() -> crate::Result<(Vec<Table>, Metrics)> {
    // All clusters in the roster total SHARDS shards, so one shared
    // 2-channel-per-shard partition prices every cell from the same caches.
    let services = ClusterBuilder::new(
        ClusterSpec::unified(SHARDS, MAX_BATCH),
        &racam_paper(),
        gpt3_6_7b(),
    )?
    .services()
    .to_vec();
    let (t, util, metrics) =
        matrix(&services, &gpt3_6_7b(), RATES, SHORT_REQUESTS, LONG_REQUESTS)?;
    Ok((vec![t, util], metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Precision, ShardRole};

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn services() -> Vec<MappingService> {
        vec![MappingService::for_config(&racam_paper()); SHARDS]
    }

    #[test]
    fn disaggregated_cell_charges_kv_transfer_and_no_decode_stall() {
        let stream = mixed_stream(400.0, 10, 2);
        let cell = run_cell(
            &services(),
            &tiny_spec(),
            ClusterSpec::disaggregated(2, 2, MAX_BATCH),
            &stream,
        )
        .unwrap();
        assert_eq!(cell.summary.requests, 12);
        assert!(cell.summary.kv_transfer_ns > 0.0, "decode shards must pay the KV link");
        assert_eq!(cell.summary.handoffs, 12, "every decoding request crosses the link once");
        // The KV cost lands specifically on the decode group's shards.
        let decode_kv: f64 = cell
            .summary
            .shard_utilization
            .iter()
            .filter(|u| u.role == ShardRole::Decode)
            .map(|u| u.kv_transfer_ns)
            .sum();
        assert_eq!(decode_kv, cell.summary.kv_transfer_ns);
        assert_eq!(cell.summary.shed_requests, 0);
    }

    #[test]
    fn unified_cell_never_touches_the_kv_link() {
        let stream = mixed_stream(400.0, 6, 1);
        let cell = run_cell(
            &services(),
            &tiny_spec(),
            ClusterSpec::unified(SHARDS, MAX_BATCH),
            &stream,
        )
        .unwrap();
        assert_eq!(cell.summary.kv_transfer_ns, 0.0);
        assert_eq!(cell.summary.handoffs, 0);
        assert!(cell.summary.requests == 7);
    }

    #[test]
    fn matrix_covers_every_cluster_and_rate() {
        let (t, util, metrics) = matrix(&services(), &tiny_spec(), &[800.0], 6, 2).unwrap();
        assert_eq!(t.num_rows(), clusters().len());
        assert_eq!(metrics.requests as usize, clusters().len() * 8, "3 cells x 8 requests");
        assert!(metrics.handoffs > 0, "the disaggregated cell crosses the KV link");
        let rendered = t.render();
        for (label, _) in clusters() {
            assert!(rendered.contains(&format!("{label}@800")), "missing {label}:\n{rendered}");
        }
        // The per-group view has one row per group of the disaggregated
        // cluster (prefill + decode), not one per shard.
        assert_eq!(util.num_rows(), 2);
    }

    #[test]
    fn mixed_stream_is_deterministic() {
        let a = mixed_stream(200.0, 8, 2);
        assert_eq!(a, mixed_stream(200.0, 8, 2));
        assert_eq!(a.len(), 10);
        assert_eq!(a.iter().filter(|r| r.prompt.len() == LONG_PROMPT as usize).count(), 2);
        assert!(a.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
    }

    #[test]
    fn bench_config_names_clusters_and_rates() {
        let keys: Vec<&str> = bench_config().iter().map(|(k, _)| *k).collect();
        for k in ["clusters", "rates_per_s", "kv_link_gbps"] {
            assert!(keys.contains(&k), "missing {k}");
        }
    }
}
