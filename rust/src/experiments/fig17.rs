//! Fig. 17 — PIM vs. I/O latency breakdown of the GEMM-1×49152×12288
//! prefill kernel under progressive hardware ablation.

use super::common::racam_with;
use super::fig12::ABLATION_POINTS;
use crate::config::{MatmulShape, Precision};
use crate::mapping::{HwModel, MappingEngine};
use crate::metrics::fmt_ns;
use crate::report::Table;

pub fn shape() -> MatmulShape {
    MatmulShape::new(1, 49152, 12288, Precision::Int8)
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "Fig.17 — latency breakdown of GEMM-1x49152x12288 under ablation",
        &["config", "pim_ns", "io_ns", "pim", "io", "pim_frac"],
    );
    for f in ABLATION_POINTS {
        let engine = MappingEngine::new(HwModel::new(&racam_with(f)));
        let e = engine.search(&shape()).expect("ablation shapes evaluate").best;
        let pim = e.compute_ns;
        let io = e.io_ns();
        t.row(vec![
            f.label(),
            format!("{pim:.0}"),
            format!("{io:.0}"),
            fmt_ns(pim),
            fmt_ns(io),
            format!("{:.3}", pim / (pim + io)),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_shift_the_breakdown() {
        let t = &run()[0];
        let rows: Vec<(f64, f64)> = t
            .to_csv()
            .lines()
            .skip(1)
            .map(|l| {
                let c: Vec<&str> = l.split(',').collect();
                (c[1].parse().unwrap(), c[2].parse().unwrap())
            })
            .collect();
        let (pim0, io0) = rows[0];
        // Removing PR/BU increases I/O latency (host reduction + explicit
        // replication)...
        let (_, io_nopr_bu) = rows[2];
        assert!(io_nopr_bu > io0, "-PR-BU io {io_nopr_bu} vs complete {io0}");
        // ...and removing LB blows up PIM latency (no bit reuse).
        let (pim_nolb, _) = rows[3];
        assert!(pim_nolb > 2.0 * pim0, "-LB pim {pim_nolb} vs complete {pim0}");
    }
}
