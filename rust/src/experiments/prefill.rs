//! `exp prefill` — chunked vs whole-prompt prefill under a long-prompt
//! mixed workload: a stream of short interactive requests with occasional
//! very long prompts, served with and without bounded prefill chunks
//! ([`ServingPolicy`]) and with EDF deadline preemption on top.
//!
//! The comparison isolates the *iteration schedule*: every cell sees the
//! same seed-deterministic stream, prices identical kernel shapes from the
//! same channel-partitioned [`MappingService`]s, and differs only in
//! admission policy and serving policy.  The headline column is the p95
//! TTFT of the short-request population — the latency whole-prompt prefill
//! sacrifices whenever a long prompt lands — next to shed/preemption
//! counts and the decode time stalled behind prefill steps.

use crate::config::json::Value;
use crate::config::{
    gpt3_6_7b, racam_paper, ArrivalProcess, ClusterSpec, LengthDist, LlmSpec, SchedulerKind,
    ServingPolicy, TrafficSpec,
};
use crate::coordinator::{ClusterBuilder, Request, SyntheticEngine};
use crate::mapping::MappingService;
use crate::metrics::fmt_ns;
use crate::report::Table;
use crate::telemetry::Metrics;
use crate::traffic::{generate, ttft_percentiles_where, SloSummary};

const SHARDS: usize = 2;
const MAX_BATCH: usize = 4;
const SEED: u64 = 0xC4_0C_4A_11;
/// Arrival rates straddling the 2-shard capacity under the long-prompt mix.
const RATES: &[f64] = &[100.0, 400.0];
const SHORT_REQUESTS: u64 = 24;
const LONG_REQUESTS: u64 = 6;
/// Long prompts span 8 pricing buckets — one of them stalls a whole-prompt
/// shard for many decode iterations' worth of time.
const LONG_PROMPT: u64 = 2048;
/// Prompt-length boundary between the short and long populations.
const SHORT_MAX_PROMPT: usize = 256;
const DEADLINE_NS: u64 = 150_000_000; // 150 ms mean e2e SLO
const CHUNK: u64 = 256;
/// Admission policies compared, in row order within each rate — the same
/// roster the `BENCH_prefill.json` config block reports.
const SCHEDULERS: &[&str] = &["fcfs", "edf"];

/// The serving policies each scheduler is run under, in row order.
fn policies() -> Vec<ServingPolicy> {
    vec![
        ServingPolicy::whole_prefill(),
        ServingPolicy::chunked(CHUNK),
        ServingPolicy::chunked(CHUNK).with_preemption(),
    ]
}

/// Experiment-specific entries for the `BENCH_prefill.json` config block.
pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    vec![
        (
            "schedulers",
            Value::Arr(SCHEDULERS.iter().map(|s| Value::Str(s.to_string())).collect()),
        ),
        ("rates_per_s", Value::Arr(RATES.iter().map(|r| Value::Num(*r)).collect())),
        (
            "policies",
            Value::Arr(policies().iter().map(|p| Value::Str(p.label())).collect()),
        ),
        ("requests", Value::Num((SHORT_REQUESTS + LONG_REQUESTS) as f64)),
        ("long_prompt_tokens", Value::Num(LONG_PROMPT as f64)),
        ("deadline_ms", Value::Num(DEADLINE_NS as f64 / 1e6)),
    ]
}

/// Merge independently generated streams into one arrival-ordered stream
/// with sequential ids (the generator numbers each stream 0..n itself).
fn merge_streams(streams: Vec<Vec<Request>>) -> Vec<Request> {
    let mut all: Vec<Request> = streams.into_iter().flatten().collect();
    // Stable sort: ties keep earlier-stream requests first, deterministic.
    all.sort_by_key(|r| r.arrival_ns);
    for (i, r) in all.iter_mut().enumerate() {
        r.id = i as u64;
    }
    all
}

/// The mixed workload: mostly short prompts at `rate_per_s`, plus long
/// prompts arriving at a proportional trickle, both under the same mean
/// e2e deadline.
fn mixed_stream(rate_per_s: f64, shorts: u64, longs: u64) -> Vec<Request> {
    let short_spec = TrafficSpec {
        seed: SEED,
        requests: shorts,
        arrival: ArrivalProcess::Poisson { rate_per_s },
        prompt: LengthDist::Uniform { lo: 16, hi: 96 },
        output: LengthDist::Uniform { lo: 6, hi: 12 },
        deadline_ns: Some(DEADLINE_NS),
    };
    let long_rate = rate_per_s * longs.max(1) as f64 / shorts.max(1) as f64;
    let long_spec = TrafficSpec {
        seed: SEED ^ 0x1046,
        requests: longs,
        arrival: ArrivalProcess::Poisson { rate_per_s: long_rate },
        prompt: LengthDist::Fixed(LONG_PROMPT),
        output: LengthDist::Uniform { lo: 2, hi: 6 },
        deadline_ns: Some(DEADLINE_NS),
    };
    merge_streams(vec![generate(&short_spec), generate(&long_spec)])
}

/// One graded cell plus the short-request TTFT tail the table leads with.
struct Cell {
    summary: SloSummary,
    short_ttft_p95: f64,
}

impl Cell {
    fn headers() -> Vec<&'static str> {
        vec![
            "run",
            "reqs",
            "short_ttft_p95",
            "ttft_p95",
            "e2e_p99",
            "goodput_tok/s",
            "slo_met",
            "shed",
            "preempts",
            "prefill_steps",
            "decode_stall",
        ]
    }

    fn row(&self, label: &str) -> Vec<String> {
        let s = &self.summary;
        vec![
            label.to_string(),
            s.requests.to_string(),
            fmt_ns(self.short_ttft_p95),
            fmt_ns(s.ttft.p95),
            fmt_ns(s.e2e.p99),
            format!("{:.0}", s.goodput_tokens_per_s),
            format!("{:.0}%", 100.0 * s.slo_attainment),
            s.shed_requests.to_string(),
            s.preemptions.to_string(),
            s.prefill_chunks.to_string(),
            fmt_ns(s.chunk_stall_ns),
        ]
    }
}

/// Serve one (scheduler, policy) cell over `stream` and grade it.
fn run_cell(
    services: &[MappingService],
    model: &LlmSpec,
    stream: &[Request],
    policy: ServingPolicy,
    scheduler: SchedulerKind,
) -> crate::Result<Cell> {
    let mut spec = ClusterSpec::unified(services.len(), MAX_BATCH);
    spec.groups[0].scheduler = scheduler;
    spec.groups[0].policy = policy;
    let mut coord =
        ClusterBuilder::with_spec_and_services(spec, model.clone(), services.to_vec())?
            .build(|_| SyntheticEngine::new(64, 256));
    for req in stream {
        coord.submit(req.clone());
    }
    let report = coord.run_to_completion()?;
    let short = ttft_percentiles_where(&report, |r| r.prompt_tokens <= SHORT_MAX_PROMPT);
    Ok(Cell { summary: SloSummary::from_report(&report), short_ttft_p95: short.p95 })
}

/// The (scheduler × policy) × rate matrix over `services` (one mapping
/// service per shard, shared across every cell), plus the telemetry
/// [`Metrics`] registry merged over every cell in row order.
fn matrix(
    services: &[MappingService],
    model: &LlmSpec,
    rates: &[f64],
    shorts: u64,
    longs: u64,
) -> crate::Result<(Table, Metrics)> {
    let mut t = Table::new(
        &format!(
            "Prefill — chunked ({CHUNK} tok) vs whole-prompt prefill, {} on {} shard(s) × batch \
             {MAX_BATCH}; {longs} long ({LONG_PROMPT} tok) per {shorts} short requests, \
             {}ms e2e SLO",
            model.name,
            services.len(),
            DEADLINE_NS / 1_000_000
        ),
        &Cell::headers(),
    );
    let mut metrics = Metrics::default();
    for &rate in rates {
        let stream = mixed_stream(rate, shorts, longs);
        // The SCHEDULERS roster bench_config() reports drives the rows,
        // so the BENCH json and the table cannot drift apart: a roster
        // entry the SchedulerKind registry does not know fails loudly
        // instead of silently reporting schedulers that have no rows.
        for &sched in SCHEDULERS {
            let kind = SchedulerKind::from_label(sched)
                .ok_or_else(|| anyhow::anyhow!("no scheduler kind named '{sched}'"))?;
            for policy in policies() {
                let cell = run_cell(services, model, &stream, policy, kind)?;
                metrics.merge(&cell.summary.metrics);
                t.row(cell.row(&format!("{sched}/{}@{rate}/s", policy.label())));
            }
        }
    }
    metrics.absorb_mapping(super::common::mapping_counters(services));
    Ok((t, metrics))
}

pub fn run() -> crate::Result<(Vec<Table>, Metrics)> {
    let services: Vec<MappingService> = ClusterBuilder::new(
        ClusterSpec::unified(SHARDS, MAX_BATCH),
        &racam_paper(),
        gpt3_6_7b(),
    )?
    .services()
    .to_vec();
    let (t, metrics) = matrix(&services, &gpt3_6_7b(), RATES, SHORT_REQUESTS, LONG_REQUESTS)?;
    Ok((vec![t], metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn one_service() -> Vec<MappingService> {
        vec![MappingService::for_config(&racam_paper())]
    }

    #[test]
    fn chunked_prefill_lowers_short_request_ttft_p95() {
        // Adversarial stream on one shard: each short request arrives
        // together with a long prompt that FCFS admits first.  Whole-
        // prompt prefill parks every short first token behind an entire
        // long prefill; chunked prefill does not.
        let mut stream = Vec::new();
        for i in 0..3u64 {
            let at = 1 + i * 1_000_000_000; // pairs 1 s apart: no overlap
            stream.push(Request::new(2 * i, vec![1; LONG_PROMPT as usize], 2).at(at));
            stream.push(Request::new(2 * i + 1, vec![2; 32], 2).at(at));
        }
        let services = one_service();
        let whole = run_cell(
            &services,
            &tiny_spec(),
            &stream,
            ServingPolicy::whole_prefill(),
            SchedulerKind::Fcfs,
        )
        .unwrap();
        let chunked = run_cell(
            &services,
            &tiny_spec(),
            &stream,
            ServingPolicy::chunked(CHUNK),
            SchedulerKind::Fcfs,
        )
        .unwrap();
        assert!(
            chunked.short_ttft_p95 < whole.short_ttft_p95 * 0.5,
            "chunked short p95 TTFT {} must undercut whole-prefill {}",
            chunked.short_ttft_p95,
            whole.short_ttft_p95
        );
        // Same stream, same completions.
        assert_eq!(chunked.summary.requests, whole.summary.requests);
        assert_eq!(chunked.summary.shed_requests, 0);
    }

    #[test]
    fn preemption_sheds_expired_deadlines_and_reports_them() {
        // Deadlines that expire after the first simulated step: EDF with
        // preemption sheds all three instead of running them out.
        let stream: Vec<Request> = (0..3u64)
            .map(|id| Request::new(id, vec![3; 32], 8).with_deadline(1))
            .collect();
        let cell = run_cell(
            &one_service(),
            &tiny_spec(),
            &stream,
            ServingPolicy::chunked(CHUNK).with_preemption(),
            SchedulerKind::Edf,
        )
        .unwrap();
        assert_eq!(cell.summary.shed_requests, 3);
        assert_eq!(cell.summary.slo_attainment, 0.0);
        let row = cell.row("edf/preempt");
        let shed_col = Cell::headers().iter().position(|h| *h == "shed").unwrap();
        assert_eq!(row[shed_col], "3", "shed count must appear in the SLO report row");
    }

    #[test]
    fn matrix_covers_schedulers_and_policies() {
        let (t, metrics) = matrix(&one_service(), &tiny_spec(), &[800.0], 6, 2).unwrap();
        assert_eq!(t.num_rows(), 6, "2 schedulers x 3 policies");
        assert_eq!(metrics.requests, 6 * 8, "6 cells x 8 requests");
        assert!(metrics.prefill_chunks > 0);
        let rendered = t.render();
        for label in
            ["fcfs/whole@800", "fcfs/chunk256@800", "edf/chunk256+preempt@800"]
        {
            assert!(rendered.contains(label), "missing row {label} in:\n{rendered}");
        }
        assert_eq!(t.headers().len(), Cell::headers().len());
    }

    #[test]
    fn mixed_stream_is_deterministic_and_mixed() {
        let a = mixed_stream(200.0, 8, 2);
        let b = mixed_stream(200.0, 8, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        assert_eq!(a.iter().filter(|r| r.prompt.len() == LONG_PROMPT as usize).count(), 2);
        assert!(a.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
    }

    #[test]
    fn bench_config_names_schedulers_rates_and_policies() {
        let pairs = bench_config();
        let keys: Vec<&str> = pairs.iter().map(|(k, _)| *k).collect();
        for k in ["schedulers", "rates_per_s", "policies"] {
            assert!(keys.contains(&k), "missing {k}");
        }
    }
}
