//! Fig. 14 — precision sensitivity: int8 → int4 → int2.  Bit-serial
//! latency is ideally linear in operand width; the fixed bit-parallel
//! reduction keeps the scaling slightly sub-linear (paper: ≈2× at int4,
//! 3.5–3.8× at int2).

use super::common::{racam_stage_latency, racam_with};
use crate::config::{paper_models, Features, Precision, Stage};
use crate::report::Table;

pub fn run() -> Vec<Table> {
    let mut out = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        let mut t = Table::new(
            &format!("Fig.14 — speedup vs int8 when lowering precision, {}", stage.label()),
            &["model", "int8", "int4", "int2"],
        );
        for mut spec in paper_models() {
            let mut cells = vec![spec.name.clone()];
            spec.prec = Precision::Int8;
            let base = racam_stage_latency(&racam_with(Features::ALL), &spec, stage).total_ns();
            for prec in [Precision::Int8, Precision::Int4, Precision::Int2] {
                spec.prec = prec;
                let ns = racam_stage_latency(&racam_with(Features::ALL), &spec, stage).total_ns();
                cells.push(format!("{:.2}", base / ns));
            }
            t.row(cells);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaling_is_near_linear_but_sub_ideal() {
        for t in run() {
            for line in t.to_csv().lines().skip(1) {
                let v: Vec<f64> = line.split(',').skip(1).map(|c| c.parse().unwrap()).collect();
                assert!((v[0] - 1.0).abs() < 1e-9);
                // int4 ≈ 2x (paper), with modelling slack.
                assert!((1.3..3.0).contains(&v[1]), "int4 speedup {}", v[1]);
                // int2: 3.5–3.8x in the paper — sub-4x but clearly super-int4.
                assert!(v[2] > v[1], "int2 {} must beat int4 {}", v[2], v[1]);
                assert!(v[2] < 4.6, "int2 speedup must stay sub-linear-ish: {}", v[2]);
            }
        }
    }
}
