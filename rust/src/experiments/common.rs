//! Shared machinery for the evaluation experiments: the three evaluated
//! systems (paper Table 4) and stage-latency helpers.
//!
//! The helpers here `expect` a priceable kernel set: paper workloads (all
//! hyper-parameters non-zero) always evaluate, so a `None` from the cost
//! model would indicate a bug, not a user error.

use crate::baselines::{H100Model, ProteusModel};
use crate::config::{racam_paper, Features, HwConfig, LlmSpec, Scenario, Stage};
use crate::metrics::LatencyBreakdown;
use crate::workloads::{
    decode_kernels, e2e_latency, prefill_kernels, stage_latency, CostModel, RacamSystem,
};

/// Prompt length used for standalone prefill numbers (paper §5.3).
pub const PREFILL_TOKENS: u64 = 1024;
/// Context length at which standalone decode throughput is sampled.
pub const DECODE_CTX: u64 = 1024;

/// Cluster-wide mapping-cache counters `(hits, misses, warm_loads)` over
/// a shard service list, counting every distinct service once (shards
/// with equal channel counts alias one service) — the triple the serving
/// experiments feed to [`crate::telemetry::Metrics::absorb_mapping`].
pub(crate) fn mapping_counters(services: &[crate::mapping::MappingService]) -> (u64, u64, u64) {
    let mut distinct: Vec<&crate::mapping::MappingService> = Vec::new();
    for svc in services {
        if !distinct.iter().any(|d| d.shares_cache_with(svc)) {
            distinct.push(svc);
        }
    }
    distinct.iter().fold((0, 0, 0), |(h, m, w), s| {
        (h + s.hits(), m + s.misses(), w + s.warm_loads())
    })
}

/// The three evaluated systems for one LLM.
pub struct SystemSet {
    pub h100: H100Model,
    pub proteus: ProteusModel,
    pub racam: RacamSystem,
}

impl SystemSet {
    pub fn for_model(spec: &LlmSpec) -> Self {
        SystemSet {
            h100: H100Model::for_model(spec),
            proteus: ProteusModel::for_model(spec),
            racam: RacamSystem::new(&racam_paper()),
        }
    }
}

/// Latency of one stage (one forward pass for prefill, one token for
/// decode) on any system.
pub fn system_stage_latency(
    sys: &dyn CostModel,
    spec: &LlmSpec,
    stage: Stage,
) -> LatencyBreakdown {
    let kernels = match stage {
        Stage::Prefill => prefill_kernels(spec, PREFILL_TOKENS),
        Stage::Decode => decode_kernels(spec, DECODE_CTX),
    };
    stage_latency(sys, &kernels).expect("paper workload kernels always map")
}

/// End-to-end scenario latency on any system.
pub fn system_e2e_latency(sys: &dyn CostModel, spec: &LlmSpec, sc: &Scenario) -> LatencyBreakdown {
    e2e_latency(sys, spec, sc).expect("paper workload kernels always map")
}

/// RACAM stage latency under an arbitrary feature set / hardware config.
pub fn racam_stage_latency(hw: &HwConfig, spec: &LlmSpec, stage: Stage) -> LatencyBreakdown {
    let sys = RacamSystem::new(hw);
    system_stage_latency(&sys, spec, stage)
}

/// (RACAM speedup, Proteus speedup) over H100 for a stage.
pub fn stage_speedups(spec: &LlmSpec, stage: Stage) -> (f64, f64) {
    let s = SystemSet::for_model(spec);
    let h = system_stage_latency(&s.h100, spec, stage).total_ns();
    let p = system_stage_latency(&s.proteus, spec, stage).total_ns();
    let r = system_stage_latency(&s.racam, spec, stage).total_ns();
    (h / r, h / p)
}

/// RACAM hardware with a feature subset (ablations).
pub fn racam_with(features: Features) -> HwConfig {
    let mut hw = racam_paper();
    hw.features = features;
    hw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{gpt3_175b, gpt3_6_7b};

    #[test]
    fn racam_beats_h100_on_decode() {
        // The paper's headline: decode is where PIM wins big.
        let (racam_speedup, _) = stage_speedups(&gpt3_175b(), Stage::Decode);
        assert!(racam_speedup > 5.0, "decode speedup {racam_speedup}");
    }

    #[test]
    fn proteus_underperforms_h100() {
        let (_, proteus_speedup) = stage_speedups(&gpt3_6_7b(), Stage::Prefill);
        assert!(proteus_speedup < 0.1, "Proteus prefill 'speedup' {proteus_speedup}");
    }

    #[test]
    fn offloaded_model_gains_more() {
        // GPT-3 175B doesn't fit in HBM → H100 suffers → larger RACAM win.
        let (big, _) = stage_speedups(&gpt3_175b(), Stage::Decode);
        let (small, _) = stage_speedups(&gpt3_6_7b(), Stage::Decode);
        assert!(big > small, "175B {big} vs 6.7B {small}");
    }
}
