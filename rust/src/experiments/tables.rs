//! Tables 1, 4 and 5 — the PIM ISA encodings, the evaluated system
//! configurations (with computed peak TOPS), and the architecture
//! comparison.

use crate::baselines::ProteusModel;
use crate::config::{paper_models, racam_paper, Precision};
use crate::dram::{decode, encode, DramCommand};
use crate::pim::isa::mul_row_accesses;
use crate::report::Table;

/// Table 1: the extended PIM command encodings, round-tripped through the
/// wire format.
pub fn run_tab1() -> Vec<Table> {
    let mut t = Table::new(
        "Table 1 — extended PIM commands and encodings",
        &["instruction", "opcode", "wire_word", "roundtrip"],
    );
    let cmds: Vec<(&str, DramCommand)> = vec![
        ("pim_enable", DramCommand::PimEnable),
        ("pim_disable", DramCommand::PimDisable),
        ("broadcast_enable", DramCommand::BroadcastEnable { bank_bc: true, col_bc: true }),
        ("broadcast_disable", DramCommand::BroadcastDisable),
        ("pim_add", DramCommand::PimAdd { r_dst: 2, r_src1: 0, r_src2: 1, prec: 8 }),
        ("pim_mul", DramCommand::PimMul { r_dst: 2, r_src1: 0, r_src2: 1, prec: 8 }),
        ("pim_mul_red", DramCommand::PimMulRed { r_dst: 2, r_src1: 0, r_src2: 1, prec: 8 }),
        ("pim_add_parallel", DramCommand::PimAddParallel { r_dst: 2, r_src1: 0, r_src2: 1 }),
    ];
    for (name, cmd) in cmds {
        let word = encode(&cmd).unwrap();
        let ok = decode(word) == Some(cmd);
        t.row(vec![
            name.into(),
            format!("{:06b}", word & 0x3F),
            format!("{word:#x}"),
            ok.to_string(),
        ]);
    }
    vec![t]
}

/// Table 4: system configurations with model-computed peak int8 TOPS.
pub fn run_tab4() -> Vec<Table> {
    let racam = racam_paper();
    let proteus = ProteusModel::default();
    let mut t = Table::new(
        "Table 4 — evaluated systems (computed peaks)",
        &["system", "int8_tops", "capacity_gb", "parallel_units"],
    );
    t.row(vec!["H100 (PCIe)".into(), "1978.9 (datasheet)".into(), "80 (HBM3)".into(), "528 tensor cores".into()]);
    t.row(vec![
        "Proteus".into(),
        format!("{:.2}", proteus.peak_tops(Precision::Int8)),
        "16 (PIM DDR5)".into(),
        format!("{} banks", proteus.banks),
    ]);
    t.row(vec![
        "RACAM".into(),
        format!("{:.1}", racam.peak_tops(Precision::Int8)),
        format!("{}", racam.capacity_bytes() / (1 << 30)),
        format!("{} PEs", racam.total_pes()),
    ]);

    let mut models = Table::new(
        "Table 3 — evaluated LLMs",
        &["model", "layers", "hidden", "heads", "weight_GB_int8"],
    );
    for m in paper_models() {
        models.row(vec![
            m.name.clone(),
            m.layers.to_string(),
            m.hidden.to_string(),
            m.heads.to_string(),
            format!("{:.1}", m.weight_bytes() as f64 / (1u64 << 30) as f64),
        ]);
    }
    vec![t, models]
}

/// Table 5: architecture comparison — row ACTs of an n-bit multiply and
/// mapping methodology.
pub fn run_tab5() -> Vec<Table> {
    let n = 8u64;
    let mut t = Table::new(
        "Table 5 — comparison (n = 8-bit multiply)",
        &["system", "scheme", "row_acts", "reuse", "broadcast", "mapping"],
    );
    let quad = ProteusModel::mul_row_ops(n).to_string();
    t.row(vec!["Neural Cache".into(), "SRAM bit-serial".into(), "-".into(), "yes".into(), "no".into(), "manual".into()]);
    t.row(vec!["PIMSAB".into(), "SRAM bit-serial".into(), "-".into(), "yes".into(), "yes".into(), "heuristics".into()]);
    t.row(vec!["Newton".into(), "DRAM bit-parallel".into(), "O(n^2)".into(), "yes".into(), "yes".into(), "manual".into()]);
    t.row(vec!["SIMDRAM".into(), "DRAM bit-serial".into(), quad.clone(), "no".into(), "no".into(), "manual".into()]);
    t.row(vec!["MIMDRAM".into(), "DRAM bit-serial".into(), quad.clone(), "no".into(), "no".into(), "heuristics".into()]);
    t.row(vec!["Proteus".into(), "DRAM bit-serial".into(), quad, "no".into(), "no".into(), "manual".into()]);
    t.row(vec![
        "RACAM (ours)".into(),
        "DRAM bit-serial".into(),
        mul_row_accesses(n, true).to_string(),
        "yes".into(),
        "yes".into(),
        "exhaustive search".into(),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_all_roundtrip() {
        let t = &run_tab1()[0];
        assert!(t.to_csv().lines().skip(1).all(|l| l.ends_with("true")));
        assert_eq!(t.num_rows(), 8);
    }

    #[test]
    fn tab5_racam_row_acts_linear() {
        let t = &run_tab5()[0];
        let csv = t.to_csv();
        let racam_line = csv.lines().find(|l| l.starts_with("RACAM")).unwrap();
        assert!(racam_line.contains("32")); // 4n at n=8
        let proteus_line = csv.lines().find(|l| l.starts_with("Proteus")).unwrap();
        assert!(proteus_line.contains("208")); // 3n²+2n at n=8
    }

    #[test]
    fn tab4_has_three_systems_and_four_models() {
        let tables = run_tab4();
        assert_eq!(tables[0].num_rows(), 3);
        assert_eq!(tables[1].num_rows(), 4);
    }
}
