//! `exp scale` — the serving engine's own hot path under heavy traffic,
//! in two parts:
//!
//! 1. **Engine cells**: 10k- and 100k-request Poisson streams driven
//!    through every scheduler on both serving-loop implementations (the
//!    per-iteration oracle and the event-calendar engine with decode
//!    fast-forward), timing **engine wall time** and **steps/second** —
//!    the scheduler-step throughput vLLM-style continuous-batching
//!    engines treat as a first-class metric.
//! 2. **Host-executor sweep**: a 1M-request stream over an 8-shard
//!    unified FCFS cluster on the calendar engine, swept across
//!    work-stealing worker-pool sizes (1/2/4/max; see
//!    `runtime::executor`), recording requests/second, speedup over the
//!    single-thread run, and the process peak RSS.  Every thread count's
//!    merged report is asserted bit-identical to the single-thread
//!    baseline before its timing is published.
//!
//! The token engine is [`NullEngine`] (zero-cost token emission), so the
//! measurement isolates the serving loop itself: admission, arrival
//! release, preemption scans, prefill selection, bucket pricing, retire
//! scans.  Every engine cell's simulated results are asserted identical
//! between the two engines before the timing is reported — a cell that
//! diverges fails the experiment instead of publishing a wrong speedup.
//!
//! `results/BENCH_scale.json` carries the engine-wall-time trajectory:
//! the headline columns are the calendar engine's speedup over the oracle
//! on the 100k-request stream (acceptance floor 5x) and the max-thread
//! speedup over one thread on the 1M-request sweep.

use crate::config::json::Value;
use crate::config::{
    gpt3_6_7b, racam_paper, ArrivalProcess, ClusterSpec, EngineKind, LengthDist, SchedulerKind,
    ServingPolicy, TrafficSpec,
};
use crate::coordinator::{
    ClusterBuilder, EdfScheduler, FcfsBatcher, LengthBucketed, NullEngine, Request, Scheduler,
    Server, ServerReport,
};
use crate::mapping::MappingService;
use crate::report::Table;
use crate::runtime::executor::{self, WorkerStats};
use crate::runtime::peak_rss_bytes;
use crate::telemetry::Metrics;
use crate::traffic::generate;
use crate::workloads::RacamSystem;
use std::time::Instant;

const SEED: u64 = 0x5CA1_AB1E;
/// Stream sizes for the oracle-vs-calendar cells; the last one carries
/// the engine-speedup headline.
const STREAMS: &[u64] = &[10_000, 100_000];
/// Arrival rate, req/s — far past one shard's service capacity, so the
/// batch stays saturated and the run measures steady-state stepping.
const RATE_PER_S: f64 = 20_000.0;
const MAX_BATCH: usize = 32;
/// Admission policies compared (the roster `bench_config()` reports).
const SCHEDULERS: &[&str] = &["fcfs", "bucketed", "edf"];
/// Loose 2 s end-to-end deadline: EDF has deadlines to order and shed by
/// without the run degenerating into shedding everything.
const DEADLINE_NS: u64 = 2_000_000_000;

/// Host-executor sweep: stream size, cluster width, and arrival rate.
/// One million requests over eight shards keeps ~125k requests per shard
/// — the same order as the largest engine cell — while exercising the
/// work-stealing pool with real cross-shard imbalance.
const SWEEP_REQUESTS: u64 = 1_000_000;
const SWEEP_SHARDS: usize = 8;
/// Cluster-wide arrival rate: eight shards' worth of the engine-cell
/// rate, so every shard stays saturated just like the single-shard cells.
const SWEEP_RATE_PER_S: f64 = 160_000.0;

pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    vec![
        (
            "schedulers",
            Value::Arr(SCHEDULERS.iter().map(|s| Value::Str(s.to_string())).collect()),
        ),
        ("rates_per_s", Value::Arr(vec![Value::Num(RATE_PER_S)])),
        ("requests", Value::Arr(STREAMS.iter().map(|n| Value::Num(*n as f64)).collect())),
        (
            "engines",
            Value::Arr(vec![Value::Str("oracle".into()), Value::Str("calendar".into())]),
        ),
        ("max_batch", Value::Num(MAX_BATCH as f64)),
        ("sweep_requests", Value::Num(SWEEP_REQUESTS as f64)),
        ("sweep_shards", Value::Num(SWEEP_SHARDS as f64)),
        (
            "sweep_threads",
            Value::Arr(sweep_threads().into_iter().map(|t| Value::Num(t as f64)).collect()),
        ),
    ]
}

fn stream_spec(requests: u64) -> TrafficSpec {
    TrafficSpec {
        seed: SEED,
        requests,
        arrival: ArrivalProcess::Poisson { rate_per_s: RATE_PER_S },
        // A few prompt buckets; decode lengths long enough that lockstep
        // stretches dominate (the hot path the calendar engine attacks).
        prompt: LengthDist::Uniform { lo: 16, hi: 512 },
        output: LengthDist::Uniform { lo: 32, hi: 192 },
        deadline_ns: Some(DEADLINE_NS),
    }
}

/// The million-request sweep stream.  Lengths are kept short — all
/// prompts and contexts inside the first 256-token pricing bucket — so
/// the resident set stays bounded by the request records themselves, not
/// by token payloads, and the run measures host scheduling rather than
/// allocator churn.
fn sweep_spec() -> TrafficSpec {
    TrafficSpec {
        seed: SEED,
        requests: SWEEP_REQUESTS,
        arrival: ArrivalProcess::Poisson { rate_per_s: SWEEP_RATE_PER_S },
        prompt: LengthDist::Uniform { lo: 8, hi: 64 },
        output: LengthDist::Uniform { lo: 4, hi: 32 },
        deadline_ns: None,
    }
}

/// Worker-pool sizes the sweep visits: 1, 2, 4, and the host's available
/// parallelism, deduplicated and sorted (on a 2-core runner this is
/// [1, 2, 4]: oversubscribed pools are still valid — and still must be
/// bit-identical).  Always starts at 1, the speedup baseline.
fn sweep_threads() -> Vec<usize> {
    let mut v = vec![1, 2, 4, executor::available_parallelism()];
    v.sort_unstable();
    v.dedup();
    v
}

fn scheduler_for(kind: SchedulerKind) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Fcfs => Box::new(FcfsBatcher::new(MAX_BATCH)),
        SchedulerKind::Bucketed => Box::new(LengthBucketed::new()),
        SchedulerKind::Edf => Box::new(EdfScheduler::new()),
    }
}

fn policy_for(kind: SchedulerKind, engine: EngineKind) -> ServingPolicy {
    // EDF runs with its deadline-shedding preemption on, which also
    // exercises the fast-forward preemption-horizon path at scale.
    let base = match kind {
        SchedulerKind::Edf => ServingPolicy::whole_prefill().with_preemption(),
        _ => ServingPolicy::whole_prefill(),
    };
    base.with_engine(engine)
}

/// One (stream, scheduler, engine) cell on a single shard.  A single
/// shard keeps the wall measurement free of thread-scheduling noise; the
/// shared service keeps kernel pricing amortized across every cell.
fn run_cell(
    service: &MappingService,
    requests: u64,
    kind: SchedulerKind,
    engine: EngineKind,
) -> crate::Result<ServerReport> {
    let mut server = Server::with_scheduler(
        NullEngine,
        RacamSystem::with_service(service.clone()),
        gpt3_6_7b(),
        MAX_BATCH,
        scheduler_for(kind),
    );
    server.set_policy(policy_for(kind, engine));
    for req in generate(&stream_spec(requests)) {
        server.submit(req);
    }
    server.run_to_completion()
}

/// One thread count of the host-executor sweep: the full million-request
/// stream over a fresh 8-shard unified FCFS cluster, returning the merged
/// report, the host wall time of the run itself (submission excluded —
/// the sweep times the executor, not the traffic generator), and the
/// per-worker host-side counters of the pool that ran it.
fn run_sweep_cell(
    service: &MappingService,
    requests: u64,
    threads: usize,
) -> crate::Result<(ServerReport, f64, Vec<WorkerStats>)> {
    let mut coord = ClusterBuilder::with_spec_and_services(
        ClusterSpec::unified(SWEEP_SHARDS, MAX_BATCH),
        gpt3_6_7b(),
        vec![service.clone(); SWEEP_SHARDS],
    )?
    .build_with(|_| NullEngine, |_| FcfsBatcher::new(MAX_BATCH));
    coord.set_threads(threads);
    let mut spec = sweep_spec();
    spec.requests = requests;
    for req in generate(&spec) {
        coord.submit(req);
    }
    #[allow(clippy::disallowed_methods)] // experiment wall timing (detcheck allowlist)
    let start = Instant::now();
    let report = coord.run_to_completion()?;
    let wall_ns = start.elapsed().as_nanos() as f64;
    Ok((report, wall_ns, coord.worker_stats().to_vec()))
}

/// Fail loudly if the two engines' simulated results differ anywhere —
/// the speedup below is only meaningful for bit-identical serving.  The
/// field coverage is [`ServerReport::sim_divergence`], shared with the
/// unit and integration equivalence gates.
fn assert_equivalent(cell: &str, cal: &ServerReport, ora: &ServerReport) -> crate::Result<()> {
    if let Some(d) = cal.sim_divergence(ora) {
        anyhow::bail!("{cell}: engines diverged: {d}");
    }
    Ok(())
}

/// Pre-price every prompt/context bucket the streams can touch — prompt
/// buckets for 16..=512-token prompts, decode buckets up to ctx 512+192 —
/// so the timed cells measure the engine loop, not the one-time mapping
/// searches the first cell would otherwise absorb into its wall time
/// (both engines share the warm `MappingService` equally afterwards).
/// The sweep stream's lengths (prompt ≤ 64, ctx ≤ 96) live entirely
/// inside the first bucket, so this warms it too.
fn warm_pricing(service: &MappingService) -> crate::Result<()> {
    let mut server = Server::with_scheduler(
        NullEngine,
        RacamSystem::with_service(service.clone()),
        gpt3_6_7b(),
        MAX_BATCH,
        scheduler_for(SchedulerKind::Fcfs),
    );
    server.submit(Request::new(0, vec![1; 16], 240)); // bucket 256, ctx ≤ 256
    server.submit(Request::new(1, vec![1; 300], 240)); // bucket 512, ctx ≤ 540
    server.submit(Request::new(2, vec![1; 512], 192)); // bucket 512, ctx ≤ 704
    server.run_to_completion()?;
    Ok(())
}

fn row(label: &str, rep: &ServerReport, speedup: Option<f64>) -> Vec<String> {
    let s = &rep.shards[0];
    let steps = s.prefill_chunks + s.decode_iterations;
    let wall_ms = s.wall_ns / 1e6;
    let ksteps_per_s = steps as f64 / (s.wall_ns / 1e9).max(f64::MIN_POSITIVE) / 1e3;
    vec![
        label.to_string(),
        rep.results.len().to_string(),
        rep.total_tokens.to_string(),
        steps.to_string(),
        format!("{wall_ms:.1}"),
        format!("{ksteps_per_s:.0}"),
        format!("{:.0}", rep.wall_tokens_per_s / 1e3),
        match speedup {
            Some(x) => format!("{x:.2}x"),
            None => "1.00x".into(),
        },
    ]
}

/// VmHWM in MB at this point of the process, or `-` where procfs is
/// unavailable.  The high-water mark is monotone across the run, so the
/// column reads as "peak RSS so far" — the last sweep row is the
/// process-wide peak the issue asks for.
fn rss_mb() -> String {
    match peak_rss_bytes() {
        Some(b) => format!("{:.0}", b as f64 / (1024.0 * 1024.0)),
        None => "-".into(),
    }
}

fn sweep_row(
    threads: usize,
    rep: &ServerReport,
    wall_ns: f64,
    base_wall_ns: f64,
    stats: &[WorkerStats],
) -> Vec<String> {
    let wall_s = (wall_ns / 1e9).max(f64::MIN_POSITIVE);
    // Pool-wide executor counters: totals across workers, idle ratio over
    // the pooled poll/sleep counts.
    let mut pool = WorkerStats::default();
    for s in stats {
        pool.absorb(s);
    }
    vec![
        format!("sweep@{SWEEP_REQUESTS}/t{threads}"),
        threads.to_string(),
        rep.results.len().to_string(),
        rep.total_tokens.to_string(),
        format!("{:.0}", wall_ns / 1e6),
        format!("{:.1}", rep.results.len() as f64 / wall_s / 1e3),
        format!("{:.2}x", base_wall_ns / wall_ns.max(1.0)),
        rss_mb(),
        pool.polls.to_string(),
        pool.steals.to_string(),
        format!("{:.2}", pool.idle_ratio()),
    ]
}

/// The host-executor sweep table plus the max-thread speedup (for the
/// headline) and the telemetry registry of the single-thread baseline
/// (every other thread count is bit-identical to it by the assertion
/// below, so one report's metrics represent them all).  Every thread
/// count replays the identical stream.
fn run_sweep(service: &MappingService) -> crate::Result<(Table, f64, Metrics)> {
    let mut t = Table::new(
        &format!(
            "Scale — host-executor sweep: {SWEEP_REQUESTS} requests, {SWEEP_SHARDS}-shard \
             unified FCFS cluster x batch {MAX_BATCH}, Poisson {SWEEP_RATE_PER_S}/s, \
             calendar engine, work-stealing worker pool"
        ),
        &[
            "run",
            "threads",
            "reqs",
            "tokens",
            "wall_ms",
            "kreq/s",
            "speedup_vs_1t",
            "peak_rss_mb",
            "polls",
            "steals",
            "idle_ratio",
        ],
    );
    let threads = sweep_threads();
    let mut baseline: Option<(ServerReport, f64)> = None;
    let mut last_speedup = 1.0;
    let mut metrics = Metrics::default();
    for &n in &threads {
        let (rep, wall_ns, stats) = run_sweep_cell(service, SWEEP_REQUESTS, n)?;
        let (base_rep, base_wall) = match &baseline {
            Some((r, w)) => (r, *w),
            None => (&rep, wall_ns),
        };
        if let Some(d) = rep.sim_divergence(base_rep) {
            anyhow::bail!("sweep t{n}: diverged from single-thread baseline: {d}");
        }
        last_speedup = base_wall / wall_ns.max(1.0);
        t.row(sweep_row(n, &rep, wall_ns, base_wall, &stats));
        if baseline.is_none() {
            metrics = Metrics::from_report(&rep);
            baseline = Some((rep, wall_ns));
        }
    }
    Ok((t, last_speedup, metrics))
}

pub fn run() -> crate::Result<(Vec<Table>, Metrics)> {
    let service = MappingService::for_config(&racam_paper());
    warm_pricing(&service)?;
    let mut t = Table::new(
        &format!(
            "Scale — engine wall time, 1 shard x batch {MAX_BATCH}, Poisson {RATE_PER_S}/s, \
             null token engine (scheduler-step hot path)"
        ),
        &["run", "reqs", "tokens", "steps", "wall_ms", "ksteps/s", "ktok/s_wall", "speedup"],
    );
    let mut headline: Option<f64> = None;
    let mut metrics = Metrics::default();
    for &requests in STREAMS {
        for &sched in SCHEDULERS {
            let kind = SchedulerKind::from_label(sched)
                .ok_or_else(|| anyhow::anyhow!("no scheduler kind named '{sched}'"))?;
            let cell = format!("{sched}@{requests}");
            let ora = run_cell(&service, requests, kind, EngineKind::Oracle)?;
            let cal = run_cell(&service, requests, kind, EngineKind::Calendar)?;
            assert_equivalent(&cell, &cal, &ora)?;
            // Engines are bit-identical (checked above); count each cell
            // once, from the calendar report.
            metrics.merge(&Metrics::from_report(&cal));
            let speedup = ora.shards[0].wall_ns / cal.shards[0].wall_ns.max(1.0);
            t.row(row(&format!("{cell}/oracle"), &ora, None));
            t.row(row(&format!("{cell}/calendar"), &cal, Some(speedup)));
            if requests == *STREAMS.last().expect("non-empty") {
                headline = Some(headline.map_or(speedup, |h: f64| h.min(speedup)));
            }
        }
    }
    let (sweep, sweep_speedup, sweep_metrics) = run_sweep(&service)?;
    metrics.merge(&sweep_metrics);
    let mut h = Table::new(
        "Scale — headline: calendar-engine speedup on the 100k-request stream (min over \
         schedulers) and max-thread speedup on the 1M-request sweep",
        &["metric", "value"],
    );
    h.row(vec![
        "calendar_speedup_100k_min".into(),
        format!("{:.2}x", headline.unwrap_or(0.0)),
    ]);
    h.row(vec![
        "sweep_speedup_max_threads".into(),
        format!("{sweep_speedup:.2}x"),
    ]);
    metrics.absorb_mapping((service.hits(), service.misses(), service.warm_loads()));
    Ok((vec![t, sweep, h], metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_cells_agree_across_engines_and_schedulers() {
        // A miniature version of every cell: equivalence must hold for
        // all three schedulers (including EDF's preemption path).
        let service = MappingService::for_config(&racam_paper());
        for sched in SCHEDULERS {
            let kind = SchedulerKind::from_label(sched).unwrap();
            let ora = run_cell(&service, 120, kind, EngineKind::Oracle).unwrap();
            let cal = run_cell(&service, 120, kind, EngineKind::Calendar).unwrap();
            assert_equivalent(sched, &cal, &ora).unwrap();
            assert_eq!(ora.results.len(), 120);
            assert!(ora.total_tokens > 0);
        }
    }

    #[test]
    fn table_rows_cover_every_cell() {
        let rep = {
            let service = MappingService::for_config(&racam_paper());
            run_cell(&service, 40, SchedulerKind::Fcfs, EngineKind::Calendar).unwrap()
        };
        let r = row("fcfs@40/calendar", &rep, Some(7.5));
        assert_eq!(r.len(), 8);
        assert_eq!(r[1], "40");
        assert_eq!(r[7], "7.50x");
    }

    #[test]
    fn sweep_threads_start_at_one_and_are_unique() {
        let t = sweep_threads();
        assert_eq!(t[0], 1, "the speedup baseline must come first");
        let mut sorted = t.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(t, sorted, "must be sorted and deduplicated: {t:?}");
        assert!(t.contains(&executor::available_parallelism()));
    }

    #[test]
    fn small_sweep_is_bit_identical_across_thread_counts() {
        // A miniature sweep cell: the merged cluster report must not
        // depend on the worker-pool size, including oversubscribed pools
        // (more threads than this machine has cores).
        let service = MappingService::for_config(&racam_paper());
        let (base, _, base_stats) = run_sweep_cell(&service, 600, 1).unwrap();
        assert_eq!(base.results.len(), 600);
        assert!(!base_stats.is_empty(), "the pool must report worker stats");
        assert!(base_stats.iter().map(|s| s.polls).sum::<u64>() > 0);
        for threads in [2, executor::available_parallelism(), SWEEP_SHARDS * 2] {
            let (rep, _, _) = run_sweep_cell(&service, 600, threads).unwrap();
            assert!(
                rep.sim_divergence(&base).is_none(),
                "t{threads} diverged: {:?}",
                rep.sim_divergence(&base)
            );
        }
    }

    #[test]
    fn sweep_rows_have_every_column() {
        let service = MappingService::for_config(&racam_paper());
        let (rep, wall_ns, stats) = run_sweep_cell(&service, 100, 2).unwrap();
        let r = sweep_row(2, &rep, wall_ns, wall_ns * 2.0, &stats);
        assert_eq!(r.len(), 11);
        assert_eq!(r[1], "2");
        assert_eq!(r[2], "100");
        assert_eq!(r[6], "2.00x");
        let total_polls: u64 = stats.iter().map(|s| s.polls).sum();
        assert_eq!(r[8], total_polls.to_string(), "polls column is the pool total");
        assert!(r[10].parse::<f64>().unwrap() >= 0.0, "idle_ratio parses: {}", r[10]);
    }
}
