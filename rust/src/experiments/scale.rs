//! `exp scale` — the serving engine's own hot path under heavy traffic:
//! 10k- and 100k-request Poisson streams driven through every scheduler on
//! both serving-loop implementations (the per-iteration oracle and the
//! event-calendar engine with decode fast-forward), timing **engine wall
//! time** and **steps/second** — the scheduler-step throughput vLLM-style
//! continuous-batching engines treat as a first-class metric.
//!
//! The token engine is [`NullEngine`] (zero-cost token emission), so the
//! measurement isolates the serving loop itself: admission, arrival
//! release, preemption scans, prefill selection, bucket pricing, retire
//! scans.  Every cell's simulated results are asserted identical between
//! the two engines before the timing is reported — a cell that diverges
//! fails the experiment instead of publishing a wrong speedup.
//!
//! `results/BENCH_scale.json` starts the engine-wall-time trajectory: the
//! headline column is the calendar engine's speedup over the oracle on
//! the 100k-request stream (the acceptance floor is 5x).

use crate::config::json::Value;
use crate::config::{
    gpt3_6_7b, racam_paper, ArrivalProcess, EngineKind, LengthDist, SchedulerKind, ServingPolicy,
    TrafficSpec,
};
use crate::coordinator::{
    EdfScheduler, FcfsBatcher, LengthBucketed, NullEngine, Request, Scheduler, Server,
    ServerReport,
};
use crate::mapping::MappingService;
use crate::report::Table;
use crate::traffic::generate;
use crate::workloads::RacamSystem;

const SEED: u64 = 0x5CA1_AB1E;
/// Stream sizes; the last one carries the headline speedup.
const STREAMS: &[u64] = &[10_000, 100_000];
/// Arrival rate, req/s — far past one shard's service capacity, so the
/// batch stays saturated and the run measures steady-state stepping.
const RATE_PER_S: f64 = 20_000.0;
const MAX_BATCH: usize = 32;
/// Admission policies compared (the roster `bench_config()` reports).
const SCHEDULERS: &[&str] = &["fcfs", "bucketed", "edf"];
/// Loose 2 s end-to-end deadline: EDF has deadlines to order and shed by
/// without the run degenerating into shedding everything.
const DEADLINE_NS: u64 = 2_000_000_000;

pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    vec![
        (
            "schedulers",
            Value::Arr(SCHEDULERS.iter().map(|s| Value::Str(s.to_string())).collect()),
        ),
        ("rates_per_s", Value::Arr(vec![Value::Num(RATE_PER_S)])),
        ("requests", Value::Arr(STREAMS.iter().map(|n| Value::Num(*n as f64)).collect())),
        (
            "engines",
            Value::Arr(vec![Value::Str("oracle".into()), Value::Str("calendar".into())]),
        ),
        ("max_batch", Value::Num(MAX_BATCH as f64)),
    ]
}

fn stream_spec(requests: u64) -> TrafficSpec {
    TrafficSpec {
        seed: SEED,
        requests,
        arrival: ArrivalProcess::Poisson { rate_per_s: RATE_PER_S },
        // A few prompt buckets; decode lengths long enough that lockstep
        // stretches dominate (the hot path the calendar engine attacks).
        prompt: LengthDist::Uniform { lo: 16, hi: 512 },
        output: LengthDist::Uniform { lo: 32, hi: 192 },
        deadline_ns: Some(DEADLINE_NS),
    }
}

fn scheduler_for(kind: SchedulerKind) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Fcfs => Box::new(FcfsBatcher::new(MAX_BATCH)),
        SchedulerKind::Bucketed => Box::new(LengthBucketed::new()),
        SchedulerKind::Edf => Box::new(EdfScheduler::new()),
    }
}

fn policy_for(kind: SchedulerKind, engine: EngineKind) -> ServingPolicy {
    // EDF runs with its deadline-shedding preemption on, which also
    // exercises the fast-forward preemption-horizon path at scale.
    let base = match kind {
        SchedulerKind::Edf => ServingPolicy::whole_prefill().with_preemption(),
        _ => ServingPolicy::whole_prefill(),
    };
    base.with_engine(engine)
}

/// One (stream, scheduler, engine) cell on a single shard.  A single
/// shard keeps the wall measurement free of thread-scheduling noise; the
/// shared service keeps kernel pricing amortized across every cell.
fn run_cell(
    service: &MappingService,
    requests: u64,
    kind: SchedulerKind,
    engine: EngineKind,
) -> crate::Result<ServerReport> {
    let mut server = Server::with_scheduler(
        NullEngine,
        RacamSystem::with_service(service.clone()),
        gpt3_6_7b(),
        MAX_BATCH,
        scheduler_for(kind),
    );
    server.set_policy(policy_for(kind, engine));
    for req in generate(&stream_spec(requests)) {
        server.submit(req);
    }
    server.run_to_completion()
}

/// Fail loudly if the two engines' simulated results differ anywhere —
/// the speedup below is only meaningful for bit-identical serving.  The
/// field coverage is [`ServerReport::sim_divergence`], shared with the
/// unit and integration equivalence gates.
fn assert_equivalent(cell: &str, cal: &ServerReport, ora: &ServerReport) -> crate::Result<()> {
    if let Some(d) = cal.sim_divergence(ora) {
        anyhow::bail!("{cell}: engines diverged: {d}");
    }
    Ok(())
}

/// Pre-price every prompt/context bucket the streams can touch — prompt
/// buckets for 16..=512-token prompts, decode buckets up to ctx 512+192 —
/// so the timed cells measure the engine loop, not the one-time mapping
/// searches the first cell would otherwise absorb into its wall time
/// (both engines share the warm `MappingService` equally afterwards).
fn warm_pricing(service: &MappingService) -> crate::Result<()> {
    let mut server = Server::with_scheduler(
        NullEngine,
        RacamSystem::with_service(service.clone()),
        gpt3_6_7b(),
        MAX_BATCH,
        scheduler_for(SchedulerKind::Fcfs),
    );
    server.submit(Request::new(0, vec![1; 16], 240)); // bucket 256, ctx ≤ 256
    server.submit(Request::new(1, vec![1; 300], 240)); // bucket 512, ctx ≤ 540
    server.submit(Request::new(2, vec![1; 512], 192)); // bucket 512, ctx ≤ 704
    server.run_to_completion()?;
    Ok(())
}

fn row(label: &str, rep: &ServerReport, speedup: Option<f64>) -> Vec<String> {
    let s = &rep.shards[0];
    let steps = s.prefill_chunks + s.decode_iterations;
    let wall_ms = s.wall_ns / 1e6;
    let ksteps_per_s = steps as f64 / (s.wall_ns / 1e9).max(f64::MIN_POSITIVE) / 1e3;
    vec![
        label.to_string(),
        rep.results.len().to_string(),
        rep.total_tokens.to_string(),
        steps.to_string(),
        format!("{wall_ms:.1}"),
        format!("{ksteps_per_s:.0}"),
        format!("{:.0}", rep.wall_tokens_per_s / 1e3),
        match speedup {
            Some(x) => format!("{x:.2}x"),
            None => "1.00x".into(),
        },
    ]
}

pub fn run() -> crate::Result<Vec<Table>> {
    let service = MappingService::for_config(&racam_paper());
    warm_pricing(&service)?;
    let mut t = Table::new(
        &format!(
            "Scale — engine wall time, 1 shard x batch {MAX_BATCH}, Poisson {RATE_PER_S}/s, \
             null token engine (scheduler-step hot path)"
        ),
        &["run", "reqs", "tokens", "steps", "wall_ms", "ksteps/s", "ktok/s_wall", "speedup"],
    );
    let mut headline: Option<f64> = None;
    for &requests in STREAMS {
        for &sched in SCHEDULERS {
            let kind = SchedulerKind::from_label(sched)
                .ok_or_else(|| anyhow::anyhow!("no scheduler kind named '{sched}'"))?;
            let cell = format!("{sched}@{requests}");
            let ora = run_cell(&service, requests, kind, EngineKind::Oracle)?;
            let cal = run_cell(&service, requests, kind, EngineKind::Calendar)?;
            assert_equivalent(&cell, &cal, &ora)?;
            let speedup = ora.shards[0].wall_ns / cal.shards[0].wall_ns.max(1.0);
            t.row(row(&format!("{cell}/oracle"), &ora, None));
            t.row(row(&format!("{cell}/calendar"), &cal, Some(speedup)));
            if requests == *STREAMS.last().expect("non-empty") {
                headline = Some(headline.map_or(speedup, |h: f64| h.min(speedup)));
            }
        }
    }
    let mut h = Table::new(
        "Scale — headline: calendar-engine speedup on the 100k-request stream (min over schedulers)",
        &["metric", "value"],
    );
    h.row(vec![
        "calendar_speedup_100k_min".into(),
        format!("{:.2}x", headline.unwrap_or(0.0)),
    ]);
    Ok(vec![t, h])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_cells_agree_across_engines_and_schedulers() {
        // A miniature version of every cell: equivalence must hold for
        // all three schedulers (including EDF's preemption path).
        let service = MappingService::for_config(&racam_paper());
        for sched in SCHEDULERS {
            let kind = SchedulerKind::from_label(sched).unwrap();
            let ora = run_cell(&service, 120, kind, EngineKind::Oracle).unwrap();
            let cal = run_cell(&service, 120, kind, EngineKind::Calendar).unwrap();
            assert_equivalent(sched, &cal, &ora).unwrap();
            assert_eq!(ora.results.len(), 120);
            assert!(ora.total_tokens > 0);
        }
    }

    #[test]
    fn table_rows_cover_every_cell() {
        let rep = {
            let service = MappingService::for_config(&racam_paper());
            run_cell(&service, 40, SchedulerKind::Fcfs, EngineKind::Calendar).unwrap()
        };
        let r = row("fcfs@40/calendar", &rep, Some(7.5));
        assert_eq!(r.len(), 8);
        assert_eq!(r[1], "40");
        assert_eq!(r[7], "7.50x");
    }
}
