//! Fig. 15 — mapping sensitivity on the 1024×12288×12288 GEMM: evaluate
//! every candidate mapping, dump the scatter (CSV), and summarize the
//! spread and the best block ("array") mappings.

use crate::config::{racam_paper, MatmulShape, Precision};
use crate::mapping::{HwModel, MappingEngine};
use crate::report::Table;
use std::collections::BTreeMap;

pub fn shape() -> MatmulShape {
    MatmulShape::new(1024, 12288, 12288, Precision::Int8)
}

pub fn run() -> Vec<Table> {
    let engine = MappingEngine::new(HwModel::new(&racam_paper()));
    let shape = shape();
    let evals = engine.evaluate_all(&shape);

    // Scatter: every candidate (the figure's points).
    let mut scatter = Table::new(
        "Fig.15 — mapping scatter, 1024x12288x12288 GEMM",
        &["hier", "block", "latency_ns", "pe_util"],
    );
    for e in &evals {
        scatter.row(vec![
            e.mapping.hier.to_string(),
            e.mapping.block.label(),
            format!("{:.0}", e.total_ns()),
            format!("{:.4}", e.pe_util),
        ]);
    }

    // Per-block-mapping ("array mapping") bests + overall spread.
    let mut best_per_block: BTreeMap<String, f64> = BTreeMap::new();
    for e in &evals {
        let v = best_per_block.entry(e.mapping.block.label()).or_insert(f64::INFINITY);
        *v = v.min(e.total_ns());
    }
    let best = evals.iter().map(|e| e.total_ns()).fold(f64::INFINITY, f64::min);
    let worst = evals.iter().map(|e| e.total_ns()).fold(0.0, f64::max);

    let mut summary = Table::new(
        "Fig.15 — summary per array mapping (best latency each)",
        &["block_mapping", "best_ns", "vs_overall_best"],
    );
    for (label, ns) in &best_per_block {
        summary.row(vec![label.clone(), format!("{ns:.0}"), format!("{:.2}x", ns / best)]);
    }
    summary.row(vec![
        "max/min spread".into(),
        format!("{worst:.0}"),
        format!("{:.2}x", worst / best),
    ]);
    vec![summary, scatter]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_is_hundreds_x() {
        // Paper: 510.85x max-to-min ratio.  The whole-space spread needs
        // the exhaustive search (pruning skips the high-latency tail).
        let engine = MappingEngine::new(HwModel::new(&racam_paper()));
        let r = engine.search_exhaustive(&shape()).expect("GEMM space evaluates");
        // The paper reports 510.85x.  Our model prices pathological
        // mappings (e.g. K spread across every level with single-block
        // serialization) even more harshly — the qualitative claim (large
        // spread requiring automated search) is what's pinned here; see
        // EXPERIMENTS.md for the quantitative comparison.
        assert!(r.spread() > 100.0, "spread {:.1}", r.spread());
        assert!(r.spread() < 1_000_000.0, "spread {:.1} implausibly large", r.spread());
    }

    #[test]
    fn scatter_has_all_1458_candidates() {
        let tables = run();
        assert_eq!(tables[1].num_rows(), 1458);
        // 6 block mappings + the spread row.
        assert_eq!(tables[0].num_rows(), 7);
    }

    #[test]
    fn a_k_on_cols_mapping_wins() {
        // Paper: "RNCMK achieves notably higher performance … popcount".
        let engine = MappingEngine::new(HwModel::new(&racam_paper()));
        let r = engine.search(&shape()).expect("GEMM space evaluates");
        assert!(r.best.mapping.block.k_on_cols(), "winner {}", r.best.mapping);
    }
}
