//! Fig. 1 — integer multiplication latency vs. precision: SOTA PUD (no bit
//! reuse, O(n²) row activations), the full-reuse ideal, and RACAM.

use crate::baselines::ProteusModel;
use crate::config::{ddr5_5200_timing, racam_paper, Features, Precision};
use crate::dram::SalpScheduler;
use crate::pim::isa::{instr_latency, mul_row_accesses, InstrClass};
use crate::report::Table;

pub fn run() -> Vec<Table> {
    let hw = racam_paper();
    let t = ddr5_5200_timing();
    let salp = SalpScheduler::new(t, hw.dram.subarrays);
    let proteus = ProteusModel::default();

    let mut table = Table::new(
        "Fig.1 — n-bit multiply latency (one SIMD pass)",
        &["bits", "sota_pud_ns", "ideal_ns", "racam_ns", "pud_row_acts", "racam_row_acts"],
    );
    for bits in [2u32, 4, 8, 16] {
        // SOTA PUD: O(n²) row cycles, no reuse (Proteus-style).
        let pud_ns = ProteusModel::mul_row_ops(bits as u64) as f64 * proteus.t_rc_ns;
        // Ideal: every operand bit crosses the interface once, PE-pipelined.
        let n = bits as u64;
        let ideal_ns = ((n * n + 4) as f64 * t.pe_cycle_ns()).max(t.salp_stream_ns(2 * n + 1));
        // RACAM: the locality-buffer schedule (4n accesses, SALP streamed).
        let prec = match Precision::from_bits(bits) {
            Some(p) => p,
            None => continue,
        };
        let racam_ns = if bits <= 8 {
            instr_latency(InstrClass::Mul, prec, &t, &salp, &Features::ALL).total_ns()
        } else {
            // >8 bit exceeds the 17-row buffer: composed of 4 int8 passes.
            4.0 * instr_latency(InstrClass::Mul, Precision::Int8, &t, &salp, &Features::ALL)
                .total_ns()
        };
        table.row(vec![
            bits.to_string(),
            format!("{pud_ns:.1}"),
            format!("{ideal_ns:.1}"),
            format!("{racam_ns:.1}"),
            ProteusModel::mul_row_ops(n).to_string(),
            mul_row_accesses(n.min(8), true).to_string(),
        ]);
    }
    vec![table]
}

#[cfg(test)]
mod tests {
    #[test]
    fn racam_tracks_ideal_not_pud() {
        let t = &super::run()[0];
        let csv = t.to_csv();
        let rows: Vec<Vec<f64>> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').map(|c| c.parse().unwrap()).collect())
            .collect();
        for r in &rows {
            let (pud, ideal, racam) = (r[1], r[2], r[3]);
            assert!(racam < pud / 5.0, "RACAM must beat PUD by far: {racam} vs {pud}");
            assert!(racam < ideal * 4.0, "RACAM must approach ideal: {racam} vs {ideal}");
        }
        // PUD grows quadratically, RACAM ~linearly: compare n=4 → n=8.
        let g_pud = rows[2][1] / rows[1][1];
        let g_racam = rows[2][3] / rows[1][3];
        assert!(g_pud > 3.0 && g_racam < 3.0, "pud x{g_pud:.1}, racam x{g_racam:.1}");
    }
}
