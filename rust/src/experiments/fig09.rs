//! Figs. 9–11 — end-to-end throughput (two scenarios), standalone
//! prefill/decode throughput, and performance per mm², all across
//! {H100, Proteus, RACAM} × the four Table 3 models.

use super::common::{system_e2e_latency, system_stage_latency, SystemSet};
use crate::area::AreaModel;
use crate::config::{paper_models, racam_paper, Scenario, Stage};
use crate::metrics::geomean;
use crate::report::Table;

/// Fig. 9: normalized end-to-end request throughput per scenario.
pub fn run_fig9() -> Vec<Table> {
    let mut out = Vec::new();
    let mut racam_speedups = Vec::new();
    for sc in [Scenario::CODE_GENERATION, Scenario::CONTEXT_UNDERSTANDING] {
        let mut t = Table::new(
            &format!(
                "Fig.9 — end-to-end normalized throughput, {} ({} in / {} out)",
                sc.name, sc.prompt_tokens, sc.output_tokens
            ),
            &["model", "h100", "proteus", "racam"],
        );
        for spec in paper_models() {
            let s = SystemSet::for_model(&spec);
            let h = system_e2e_latency(&s.h100, &spec, &sc).total_ns();
            let p = system_e2e_latency(&s.proteus, &spec, &sc).total_ns();
            let r = system_e2e_latency(&s.racam, &spec, &sc).total_ns();
            racam_speedups.push(h / r);
            t.row(vec![
                spec.name.clone(),
                "1.00".into(),
                format!("{:.4}", h / p),
                format!("{:.2}", h / r),
            ]);
        }
        let g = geomean(&racam_speedups.split_off(racam_speedups.len() - 4));
        t.row(vec!["geomean(RACAM)".into(), "-".into(), "-".into(), format!("{g:.2}")]);
        out.push(t);
    }
    out
}

/// Fig. 10: standalone prefill and decode throughput, normalized to H100.
pub fn run_fig10() -> Vec<Table> {
    let mut out = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        let mut t = Table::new(
            &format!("Fig.10 — normalized {} throughput", stage.label()),
            &["model", "h100", "proteus", "racam"],
        );
        for spec in paper_models() {
            let s = SystemSet::for_model(&spec);
            let h = system_stage_latency(&s.h100, &spec, stage).total_ns();
            let p = system_stage_latency(&s.proteus, &spec, stage).total_ns();
            let r = system_stage_latency(&s.racam, &spec, stage).total_ns();
            t.row(vec![
                spec.name.clone(),
                "1.00".into(),
                format!("{:.5}", h / p),
                format!("{:.2}", h / r),
            ]);
        }
        out.push(t);
    }
    out
}

/// Fig. 11: performance per mm², normalized to H100 (areas at 15 nm; RACAM
/// counts its added peripherals, Proteus its 1% added circuitry).
pub fn run_fig11() -> Vec<Table> {
    let area = AreaModel::default();
    let h100_mm2 = area.h100_mm2_at_15nm();
    let racam_mm2 = area.report(&racam_paper()).added_mm2();
    let proteus_mm2 = area.proteus_added_mm2(16 * (1u64 << 30));

    let mut out = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        let mut t = Table::new(
            &format!("Fig.11 — performance per mm² vs H100, {}", stage.label()),
            &["model", "proteus", "racam"],
        );
        for spec in paper_models() {
            let s = SystemSet::for_model(&spec);
            let h = system_stage_latency(&s.h100, &spec, stage).total_ns();
            let p = system_stage_latency(&s.proteus, &spec, stage).total_ns();
            let r = system_stage_latency(&s.racam, &spec, stage).total_ns();
            let proteus_ppa = (h / p) * (h100_mm2 / proteus_mm2);
            let racam_ppa = (h / r) * (h100_mm2 / racam_mm2);
            t.row(vec![
                spec.name.clone(),
                format!("{proteus_ppa:.2}"),
                format!("{racam_ppa:.1}"),
            ]);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, idx: usize) -> Vec<f64> {
        t.to_csv()
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(idx).and_then(|c| c.parse().ok()))
            .collect()
    }

    #[test]
    fn fig9_racam_beats_h100_and_proteus_trails() {
        let tables = run_fig9();
        for t in &tables {
            let proteus = col(t, 2);
            let racam = col(t, 3);
            for (p, r) in proteus.iter().zip(&racam) {
                assert!(*r > 1.0, "RACAM must beat H100 end-to-end, got {r}");
                assert!(*p < 1.0, "Proteus must trail H100, got {p}");
            }
        }
    }

    #[test]
    fn fig10_decode_speedup_exceeds_prefill() {
        let tables = run_fig10();
        let prefill = col(&tables[0], 3);
        let decode = col(&tables[1], 3);
        for (p, d) in prefill.iter().zip(&decode) {
            assert!(d > p, "decode ({d}) must beat prefill ({p}) speedup");
        }
        // Decode hits tens-of-x like the paper's up-to-112x.
        assert!(decode.iter().cloned().fold(0.0, f64::max) > 20.0);
    }

    #[test]
    fn fig11_racam_ppa_dominates() {
        let tables = run_fig11();
        for t in &tables {
            let proteus = col(t, 1);
            let racam = col(t, 2);
            for (p, r) in proteus.iter().zip(&racam) {
                assert!(r > p, "RACAM perf/mm² must exceed Proteus ({r} vs {p})");
            }
        }
        // Decode perf/mm² in the hundreds (paper: up to 466.8x).
        let decode_max = col(&tables[1], 2).into_iter().fold(0.0, f64::max);
        assert!(decode_max > 50.0, "decode ppa {decode_max}");
    }
}
