//! Experiment registry: one entry point per table and figure of the
//! paper's evaluation (§6).  `run("fig9")` regenerates the corresponding
//! artifact as paper-style text tables + CSV under `results/`.

mod common;
mod disagg;
mod extensions;
mod faults;
mod fig01;
mod fig09;
mod fig12;
mod fig13;
mod fig14;
mod fig15;
mod fig16;
mod fig17;
mod map;
mod prefill;
mod scale;
mod tables;
mod traffic;

pub use common::{racam_stage_latency, stage_speedups, SystemSet};

use crate::config::json::Value;
use crate::config::{racam_paper, Precision};
use crate::report::Table;
use crate::telemetry::Metrics;
use crate::Result;
use std::time::Instant;

/// All experiment ids, in paper order (extensions last).
pub const ALL_IDS: &[&str] = &[
    "fig1", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17",
    "tab1", "tab4", "tab5", "ext-energy", "ext-reliability", "ext-trace", "traffic", "prefill",
    "disagg", "faults", "scale", "map",
];

/// Run one experiment; returns its tables (already saved under `results/`,
/// alongside a machine-readable `BENCH_<id>.json` for cross-PR tracking).
/// Serving experiments also fold their telemetry [`Metrics`] registry
/// into the bench artifact; static experiments carry an (all-zero)
/// default so the `metrics.*` schema fields are emitted unconditionally.
pub fn run(id: &str) -> Result<Vec<Table>> {
    #[allow(clippy::disallowed_methods)] // experiment wall timing (detcheck allowlist)
    let wall_start = Instant::now();
    let (tables, metrics) = match id {
        "fig1" => (fig01::run(), Metrics::default()),
        "fig9" => (fig09::run_fig9(), Metrics::default()),
        "fig10" => (fig09::run_fig10(), Metrics::default()),
        "fig11" => (fig09::run_fig11(), Metrics::default()),
        "fig12" => (fig12::run(), Metrics::default()),
        "fig13" => (fig13::run(), Metrics::default()),
        "fig14" => (fig14::run(), Metrics::default()),
        "fig15" => (fig15::run(), Metrics::default()),
        "fig16" => (fig16::run(), Metrics::default()),
        "fig17" => (fig17::run(), Metrics::default()),
        "tab1" => (tables::run_tab1(), Metrics::default()),
        "tab4" => (tables::run_tab4(), Metrics::default()),
        "tab5" => (tables::run_tab5(), Metrics::default()),
        "ext-energy" => (extensions::run_energy(), Metrics::default()),
        "ext-reliability" => (extensions::run_reliability(), Metrics::default()),
        "ext-trace" => (extensions::run_trace(), Metrics::default()),
        "traffic" => traffic::run()?,
        "prefill" => prefill::run()?,
        "disagg" => disagg::run()?,
        "faults" => faults::run()?,
        "scale" => scale::run()?,
        "map" => map::run()?,
        other => anyhow::bail!("unknown experiment '{other}' (known: {ALL_IDS:?})"),
    };
    let wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
    let mut text = String::new();
    let mut csv = String::new();
    for t in &tables {
        text.push_str(&t.render());
        text.push('\n');
        csv.push_str(&t.to_csv());
        csv.push('\n');
    }
    crate::report::save(&format!("{id}.txt"), &text)?;
    crate::report::save(&format!("{id}.csv"), &csv)?;
    crate::report::save(
        &format!("BENCH_{id}.json"),
        &bench_json(id, &tables, wall_ms, &metrics),
    )?;
    Ok(tables)
}

/// Machine-readable bench artifact: experiment name, the *baseline*
/// hardware preset of this build (experiments that sweep hardware — e.g.
/// fig13 — vary from this preset; their tables carry the swept values),
/// experiment-specific config (serving experiments add scheduler names and
/// arrival rates so the perf trajectory is diffable without parsing table
/// titles), its result tables (the latencies), the telemetry metrics
/// registry (zeros for static experiments), and the host wall time of
/// the run — one JSON per experiment so the trajectory diffs across PRs.
fn bench_json(id: &str, tables: &[Table], wall_ms: f64, metrics: &Metrics) -> String {
    let hw = racam_paper();
    let mut config = vec![
        ("preset", Value::Str("racam_paper".into())),
        ("channels", Value::Num(hw.dram.channels as f64)),
        ("ranks", Value::Num(hw.dram.ranks as f64)),
        ("total_pes", Value::Num(hw.total_pes() as f64)),
        ("int8_tops", Value::Num(hw.peak_tops(Precision::Int8))),
    ];
    config.extend(extra_bench_config(id));
    Value::obj(vec![
        ("name", Value::Str(id.to_string())),
        ("config", Value::obj(config)),
        ("wall_ms", Value::Num(wall_ms)),
        ("metrics", metrics.to_json()),
        ("tables", Value::Arr(tables.iter().map(|t| t.to_json()).collect())),
    ])
    .pretty()
}

/// Experiment-specific additions to the `BENCH_<id>.json` config block.
fn extra_bench_config(id: &str) -> Vec<(&'static str, Value)> {
    match id {
        "traffic" => traffic::bench_config(),
        "prefill" => prefill::bench_config(),
        "disagg" => disagg::bench_config(),
        "faults" => faults::bench_config(),
        "scale" => scale::bench_config(),
        "map" => map::bench_config(),
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_id_errors() {
        assert!(super::run("fig99").is_err());
    }

    #[test]
    fn bench_json_parses_and_names_the_experiment() {
        use crate::config::json;
        use crate::report::Table;
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into()]);
        let s = super::bench_json("fig9", &[t], 12.5, &crate::telemetry::Metrics::default());
        let v = json::parse(&s).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "fig9");
        assert_eq!(v.get("config").unwrap().get("channels").unwrap().as_u32().unwrap(), 8);
        assert!(v.get("wall_ms").unwrap().as_f64().unwrap() > 0.0);
        // The metrics registry is present even for static experiments.
        let m = v.get("metrics").unwrap();
        assert_eq!(m.get("requests").unwrap().as_u32().unwrap(), 0);
        assert_eq!(m.get("ttft_ns").unwrap().get("total").unwrap().as_u32().unwrap(), 0);
        // Non-serving experiments carry no scheduler/rate entries.
        assert!(v.get("config").unwrap().get("schedulers").is_err());
    }

    #[test]
    fn bench_schema_manifest_matches_what_bench_json_emits() {
        // The committed bench_schema.json names the fields `benchcheck`
        // guards in CI; every non-table field it lists must actually be
        // produced by `bench_json` for that experiment (tables need a
        // real run, which CI performs before the check).
        use crate::config::json::{self, Value};
        use crate::report::schema::schema_of;
        use std::collections::BTreeSet;
        let manifest = json::parse(include_str!("../../bench_schema.json")).unwrap();
        let Value::Obj(exps) = manifest.get("experiments").unwrap() else {
            panic!("experiments must be an object")
        };
        assert!(!exps.is_empty());
        for (id, fields) in exps {
            let Value::Arr(fields) = fields else { panic!("{id}: fields must be an array") };
            let emitted = super::bench_json(id, &[], 1.0, &crate::telemetry::Metrics::default());
            let actual: BTreeSet<String> =
                schema_of(&json::parse(&emitted).unwrap()).into_iter().collect();
            for f in fields {
                let f = f.as_str().unwrap();
                if f.starts_with("column:") || f.starts_with("tables") {
                    continue; // needs real tables; CI checks after a run
                }
                assert!(
                    actual.contains(f),
                    "{id}: manifest field '{f}' is not produced by bench_json \
                     (emitted: {actual:?})"
                );
            }
        }
    }

    #[test]
    fn serving_bench_json_names_schedulers_and_rates() {
        use crate::config::json::{self, Value};
        for id in ["traffic", "prefill", "disagg", "faults", "scale"] {
            let s = super::bench_json(id, &[], 1.0, &crate::telemetry::Metrics::default());
            let v = json::parse(&s).unwrap();
            let cfg = v.get("config").unwrap();
            let Value::Arr(scheds) = cfg.get("schedulers").unwrap() else {
                panic!("{id}: schedulers must be an array")
            };
            assert!(!scheds.is_empty(), "{id}");
            let Value::Arr(rates) = cfg.get("rates_per_s").unwrap() else {
                panic!("{id}: rates_per_s must be an array")
            };
            assert!(rates.iter().all(|r| r.as_f64().unwrap() > 0.0), "{id}");
        }
    }
}
