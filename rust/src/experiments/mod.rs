//! Experiment registry: one entry point per table and figure of the
//! paper's evaluation (§6).  `run("fig9")` regenerates the corresponding
//! artifact as paper-style text tables + CSV under `results/`.

mod common;
mod extensions;
mod fig01;
mod fig09;
mod fig12;
mod fig13;
mod fig14;
mod fig15;
mod fig16;
mod fig17;
mod tables;

pub use common::{racam_stage_latency, stage_speedups, SystemSet};

use crate::report::Table;
use crate::Result;

/// All experiment ids, in paper order.
pub const ALL_IDS: &[&str] = &[
    "fig1", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17",
    "tab1", "tab4", "tab5", "ext-energy", "ext-reliability", "ext-trace",
];

/// Run one experiment; returns its tables (already saved under `results/`).
pub fn run(id: &str) -> Result<Vec<Table>> {
    let tables = match id {
        "fig1" => fig01::run(),
        "fig9" => fig09::run_fig9(),
        "fig10" => fig09::run_fig10(),
        "fig11" => fig09::run_fig11(),
        "fig12" => fig12::run(),
        "fig13" => fig13::run(),
        "fig14" => fig14::run(),
        "fig15" => fig15::run(),
        "fig16" => fig16::run(),
        "fig17" => fig17::run(),
        "tab1" => tables::run_tab1(),
        "tab4" => tables::run_tab4(),
        "tab5" => tables::run_tab5(),
        "ext-energy" => extensions::run_energy(),
        "ext-reliability" => extensions::run_reliability(),
        "ext-trace" => extensions::run_trace(),
        other => anyhow::bail!("unknown experiment '{other}' (known: {ALL_IDS:?})"),
    };
    let mut text = String::new();
    let mut csv = String::new();
    for t in &tables {
        text.push_str(&t.render());
        text.push('\n');
        csv.push_str(&t.to_csv());
        csv.push('\n');
    }
    crate::report::save(&format!("{id}.txt"), &text)?;
    crate::report::save(&format!("{id}.csv"), &csv)?;
    Ok(tables)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_id_errors() {
        assert!(super::run("fig99").is_err());
    }
}
