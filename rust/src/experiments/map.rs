//! `exp map` — the mapping search itself as the experiment: how much of
//! the 1458-candidate GEMM space (192 for GEMV) each search strategy
//! actually evaluates, and how much a warm store removes.
//!
//! 1. **Strategy cells**: every distinct kernel shape of the GPT-3 6.7B
//!    and Llama-3 8B presets (prefill at seq 512, decode at ctx 1024) is
//!    searched three ways — `exhaustive` (whole space, the Fig. 15
//!    spread reference), `enum_pruned` (enumeration-order scan with
//!    incumbent-bound pruning, the pre-best-first default), and
//!    `best_first` (lazy generation + bound-ordered frontier, the
//!    serving default) — recording evaluated candidates, pruned
//!    candidates, bound calls, the frontier high-water mark and host
//!    wall time per cell.  All three winners are asserted bit-identical
//!    in-run, and best-first must evaluate strictly fewer candidates
//!    than enumeration-order pruning over the GPT-3 GEMM shapes (the
//!    headline ratio).
//! 2. **Warm-store pass**: the same shapes priced through the cached
//!    path against the persistent table at `results/mapping_store.json`.
//!    The pass is *cold* when the file is absent and *warm* when a
//!    previous run left it behind — CI runs the experiment twice and
//!    asserts the warm process evaluates strictly fewer candidates than
//!    the cold one (see `docs/mapping.md` for the store lifecycle).
//!    The service persists its cache on drop, so the table survives for
//!    the next process and uploads as a workflow artifact.
//!
//! `results/BENCH_map.json` carries the per-cell counters plus the
//! mapping-cache metrics (`map_cache_hits` / `map_cache_misses` /
//! `map_warm_loads`) of the store pass.

use crate::config::json::Value;
use crate::config::{gpt3_6_7b, llama3_8b, racam_paper, LlmSpec, MatmulShape};
use crate::mapping::{MappingService, SearchResult};
use crate::report::Table;
use crate::telemetry::Metrics;
use crate::workloads::{decode_kernels, prefill_kernels};
use std::path::Path;
use std::time::Instant;

/// Search strategies compared, in report order.
const STRATEGIES: &[&str] = &["exhaustive", "enum_pruned", "best_first"];
/// Prefill sequence length the kernel shapes are taken at.
const PREFILL_SEQ: u64 = 512;
/// Decode KV-context length the kernel shapes are taken at.
const DECODE_CTX: u64 = 1024;
/// The persistent warm table (relative to the repo's `rust/` directory,
/// like every other `results/` artifact).
const STORE_PATH: &str = "results/mapping_store.json";

pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    vec![
        (
            "models",
            Value::Arr(vec![
                Value::Str(gpt3_6_7b().name),
                Value::Str(llama3_8b().name),
            ]),
        ),
        (
            "strategies",
            Value::Arr(STRATEGIES.iter().map(|s| Value::Str(s.to_string())).collect()),
        ),
        ("prefill_seq", Value::Num(PREFILL_SEQ as f64)),
        ("decode_ctx", Value::Num(DECODE_CTX as f64)),
        ("store", Value::Str(STORE_PATH.into())),
    ]
}

/// The distinct kernel shapes of both presets, labeled
/// `model/stage/kernel` after the first kernel that produces each shape
/// (presets share e.g. `out_proj`, so deduplication keeps the cell count
/// and the cached-path hit accounting honest).
fn kernel_shapes() -> Vec<(String, MatmulShape)> {
    let mut v: Vec<(String, MatmulShape)> = Vec::new();
    let mut add = |model: &str, stage: &str, spec: &LlmSpec| {
        let kernels = match stage {
            "prefill" => prefill_kernels(spec, PREFILL_SEQ),
            _ => decode_kernels(spec, DECODE_CTX),
        };
        for k in kernels {
            if !v.iter().any(|(_, s)| *s == k.shape) {
                v.push((format!("{model}/{stage}/{}", k.label), k.shape));
            }
        }
    };
    add("gpt3", "prefill", &gpt3_6_7b());
    add("gpt3", "decode", &gpt3_6_7b());
    add("llama3", "prefill", &llama3_8b());
    add("llama3", "decode", &llama3_8b());
    v
}

fn search(service: &MappingService, strat: &str, shape: &MatmulShape) -> Option<SearchResult> {
    match strat {
        "exhaustive" => service.search_exhaustive(shape),
        "enum_pruned" => service.search_enumeration_pruned(shape),
        "best_first" => service.search_best_first(shape),
        other => unreachable!("unknown strategy '{other}'"),
    }
}

fn cell_row(label: &str, shape: &MatmulShape, strat: &str, r: &SearchResult, wall_ms: f64) -> Vec<String> {
    vec![
        label.to_string(),
        format!("{}x{}x{}", shape.m, shape.k, shape.n),
        strat.to_string(),
        r.candidates.to_string(),
        r.pruned.to_string(),
        r.bound_calls.to_string(),
        r.frontier_peak.to_string(),
        format!("{:.1}", r.best.total_ns()),
        format!("{wall_ms:.3}"),
    ]
}

/// The cached-path pass against the persistent store (see module docs):
/// returns its report row plus `(evaluated, warm_loads, misses)` for the
/// headline, with the service's counters folded into `metrics`.
fn run_store_pass(
    shapes: &[(String, MatmulShape)],
    metrics: &mut Metrics,
) -> crate::Result<(Vec<String>, usize, u64)> {
    let store = Path::new(STORE_PATH);
    // `report::save` creates results/ for the tables; the store pass may
    // run against a results/ that does not exist yet.
    if let Some(dir) = store.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let state = if store.exists() { "warm" } else { "cold" };
    let service = MappingService::for_config(&racam_paper());
    let loaded = service.set_warm_path(store)?;
    let mut evaluated = 0usize;
    #[allow(clippy::disallowed_methods)] // experiment wall timing (detcheck allowlist)
    let start = Instant::now();
    for (label, shape) in shapes {
        let before = service.misses();
        let r = service
            .search_cached(shape)
            .ok_or_else(|| anyhow::anyhow!("no valid mapping for kernel '{label}'"))?;
        if service.misses() > before {
            evaluated += r.candidates;
        }
    }
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    if state == "warm" {
        anyhow::ensure!(
            loaded > 0 && (service.misses() as usize) < shapes.len(),
            "warm store loaded {loaded} entries but {} of {} shapes still searched",
            service.misses(),
            shapes.len()
        );
    } else {
        anyhow::ensure!(
            service.misses() as usize == shapes.len(),
            "cold pass must search every shape"
        );
    }
    let row = vec![
        "store".into(),
        state.into(),
        shapes.len().to_string(),
        service.misses().to_string(),
        service.hits().to_string(),
        service.warm_loads().to_string(),
        evaluated.to_string(),
        format!("{wall_ms:.3}"),
    ];
    let warm_loads = service.warm_loads();
    metrics.absorb_mapping((service.hits(), service.misses(), warm_loads));
    // Dropping the service merges the cache back into the store file.
    drop(service);
    anyhow::ensure!(store.exists(), "the store pass must leave {STORE_PATH} behind");
    Ok((row, evaluated, warm_loads))
}

pub fn run() -> crate::Result<(Vec<Table>, Metrics)> {
    let shapes = kernel_shapes();
    let service = MappingService::for_config(&racam_paper());
    let mut cells = Table::new(
        &format!(
            "Mapping search — strategy comparison over the distinct GPT-3 6.7B / Llama-3 8B \
             kernel shapes (prefill seq {PREFILL_SEQ}, decode ctx {DECODE_CTX})"
        ),
        &[
            "kernel",
            "shape",
            "strategy",
            "evaluated",
            "pruned",
            "bound_calls",
            "frontier_peak",
            "best_ns",
            "wall_ms",
        ],
    );
    // Headline accumulators: evaluated candidates and wall time per
    // strategy over the GPT-3 GEMM shapes (m > 1 — the 1458-candidate
    // spaces best-first targets).
    let mut gemm_evals = [0usize; 3];
    let mut gemm_wall_ms = [0f64; 3];
    for (label, shape) in &shapes {
        let mut winners: Vec<u64> = Vec::with_capacity(STRATEGIES.len());
        for (si, &strat) in STRATEGIES.iter().enumerate() {
            #[allow(clippy::disallowed_methods)] // experiment wall timing (detcheck allowlist)
            let start = Instant::now();
            let r = search(&service, strat, shape)
                .ok_or_else(|| anyhow::anyhow!("no valid mapping for kernel '{label}'"))?;
            let wall_ms = start.elapsed().as_secs_f64() * 1e3;
            cells.row(cell_row(label, shape, strat, &r, wall_ms));
            winners.push(r.best.total_ns().to_bits());
            if label.starts_with("gpt3/") && shape.m > 1 {
                gemm_evals[si] += r.candidates;
                gemm_wall_ms[si] += wall_ms;
            }
        }
        anyhow::ensure!(
            winners.iter().all(|&w| w == winners[0]),
            "{label}: strategies disagree on the winner (total_ns bits {winners:?})"
        );
    }
    let (ep, bf) = (gemm_evals[1], gemm_evals[2]);
    anyhow::ensure!(
        bf < ep,
        "best-first evaluated {bf} candidates on the GPT-3 GEMM shapes, \
         enumeration-order pruning {ep} — best-first must evaluate strictly fewer"
    );
    let mut metrics = Metrics::default();
    let (store_row, store_evaluated, warm_loads) = run_store_pass(&shapes, &mut metrics)?;
    let mut store = Table::new(
        "Mapping search — cached pricing against the persistent warm store \
         (results/mapping_store.json; cold = file absent at start, warm = left by a previous run)",
        &["pass", "store_state", "shapes", "misses", "hits", "warm_loads", "evaluated", "wall_ms"],
    );
    store.row(store_row);
    let mut h = Table::new(
        "Mapping search — headline: best-first vs enumeration-order pruning on the GPT-3 GEMM \
         shapes, and what the warm store removed",
        &["metric", "value"],
    );
    h.row(vec!["best_first_evaluated".into(), bf.to_string()]);
    h.row(vec!["enum_pruned_evaluated".into(), ep.to_string()]);
    h.row(vec![
        "best_first_vs_enum_pruned".into(),
        format!("{:.3}", bf as f64 / ep.max(1) as f64),
    ]);
    h.row(vec!["best_first_wall_ms".into(), format!("{:.3}", gemm_wall_ms[2])]);
    h.row(vec!["enum_pruned_wall_ms".into(), format!("{:.3}", gemm_wall_ms[1])]);
    h.row(vec!["store_evaluated".into(), store_evaluated.to_string()]);
    h.row(vec!["store_warm_loads".into(), warm_loads.to_string()]);
    Ok((vec![cells, store, h], metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_shapes_are_distinct_and_cover_both_models() {
        let shapes = kernel_shapes();
        assert!(shapes.len() >= 10, "too few shapes: {}", shapes.len());
        for (i, (_, s)) in shapes.iter().enumerate() {
            assert!(!shapes[..i].iter().any(|(_, o)| o == s), "duplicate shape {s:?}");
        }
        assert!(shapes.iter().any(|(l, _)| l.starts_with("gpt3/")));
        assert!(shapes.iter().any(|(l, _)| l.starts_with("llama3/")));
        // The headline needs GPT-3 GEMM cells to aggregate over.
        assert!(shapes.iter().any(|(l, s)| l.starts_with("gpt3/") && s.m > 1));
    }

    #[test]
    fn strategies_agree_and_best_first_evaluates_fewer() {
        let service = MappingService::for_config(&racam_paper());
        let (label, shape) = &kernel_shapes()[0];
        let ex = search(&service, "exhaustive", shape).unwrap();
        let ep = search(&service, "enum_pruned", shape).unwrap();
        let bf = search(&service, "best_first", shape).unwrap();
        for r in [&ep, &bf] {
            assert_eq!(
                r.best.total_ns().to_bits(),
                ex.best.total_ns().to_bits(),
                "{label}: winner drifted"
            );
        }
        assert!(bf.candidates < ep.candidates, "bf {} vs ep {}", bf.candidates, ep.candidates);
        assert_eq!(bf.examined(), ex.candidates, "best-first must account for the whole space");
        let row = cell_row(label, shape, "best_first", &bf, 1.25);
        assert_eq!(row.len(), 9);
        assert_eq!(row[3], bf.candidates.to_string());
    }

    #[test]
    fn store_pass_is_cold_then_warm_across_services() {
        // A miniature of the CI flow against a scratch store: a cold
        // service searches everything and persists; a second service
        // warm-loads and evaluates nothing new.
        let path = std::env::temp_dir()
            .join(format!("racam_exp_map_store_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let all = kernel_shapes();
        let shapes = &all[..3];
        let evals = |svc: &MappingService| -> usize {
            let mut evaluated = 0;
            for (_, shape) in shapes {
                let before = svc.misses();
                let r = svc.search_cached(shape).unwrap();
                if svc.misses() > before {
                    evaluated += r.candidates;
                }
            }
            evaluated
        };
        let cold = MappingService::for_config(&racam_paper());
        cold.set_warm_path(&path).unwrap();
        let cold_evals = evals(&cold);
        assert!(cold_evals > 0);
        drop(cold);
        let warm = MappingService::for_config(&racam_paper());
        assert_eq!(warm.set_warm_path(&path).unwrap(), shapes.len());
        assert_eq!(evals(&warm), 0, "warm pass must evaluate strictly fewer (zero)");
        assert_eq!(warm.misses(), 0);
        let _ = std::fs::remove_file(&path);
    }
}
