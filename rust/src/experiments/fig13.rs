//! Fig. 13 — PE-count sensitivity: scale the system to 1/4, 1/16, 1/64
//! capacity (by reducing ranks, then channels) and report normalized
//! performance.  Prefill should track the capacity line (compute-bound);
//! decode should degrade far less (memory-bound, low PE utilization).

use super::common::racam_stage_latency;
use crate::config::{paper_models, racam_paper, scale_capacity, Stage};
use crate::report::Table;

pub const FACTORS: [u32; 4] = [1, 4, 16, 64];

pub fn run() -> Vec<Table> {
    let mut out = Vec::new();
    for stage in [Stage::Prefill, Stage::Decode] {
        let mut t = Table::new(
            &format!("Fig.13 — performance vs PE count, {} (normalized to full system)", stage.label()),
            &["model", "1/1", "1/4", "1/16", "1/64"],
        );
        for spec in paper_models() {
            let base = racam_stage_latency(&racam_paper(), &spec, stage).total_ns();
            let mut cells = vec![spec.name.clone()];
            for f in FACTORS {
                let hw = scale_capacity(&racam_paper(), f);
                let ns = racam_stage_latency(&hw, &spec, stage).total_ns();
                cells.push(format!("{:.3}", base / ns));
            }
            t.row(cells);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(t: &Table) -> Vec<Vec<f64>> {
        t.to_csv()
            .lines()
            .skip(1)
            .map(|l| l.split(',').skip(1).map(|c| c.parse().unwrap()).collect())
            .collect()
    }

    #[test]
    fn prefill_scales_decode_is_insensitive() {
        let tables = run();
        let prefill = rows(&tables[0]);
        let decode = rows(&tables[1]);
        for (p, d) in prefill.iter().zip(&decode) {
            // Prefill at 1/64 capacity: near-linear degradation (≤ ~1/16 of
            // full perf — paper shows it hugging the reference line).
            assert!(p[3] < 0.2, "prefill 1/64 perf {}", p[3]);
            // Decode keeps much more of its performance (weak scaling).
            assert!(d[3] > p[3], "decode {} vs prefill {} at 1/64", d[3], p[3]);
        }
    }

    #[test]
    fn performance_never_increases_meaningfully_when_shrinking() {
        // Shrinking can *slightly* help IO-bound kernels in the model (less
        // rank-level replication of broadcast inputs), mirroring the weak
        // decode scaling of the figure; allow ≤10% non-monotonicity.
        for t in run() {
            for r in rows(&t) {
                for w in r.windows(2) {
                    assert!(w[1] <= w[0] * 1.10, "{} -> {}", w[0], w[1]);
                }
            }
        }
    }
}
