//! Fig. 16 — GEMM/GEMV size sensitivity: latency scaling vs. compute
//! growth, correlated with PE utilization (paper: GEMMs approach 98%
//! utilization and near-ideal scaling; GEMVs are memory-bound with
//! single-digit utilization that improves with size).

use crate::config::{racam_paper, Precision};
use crate::mapping::{HwModel, MappingEngine};
use crate::metrics::fmt_ns;
use crate::report::Table;
use crate::workloads::{gemm_sweep, gemv_sweep};

pub fn run() -> Vec<Table> {
    let engine = MappingEngine::new(HwModel::new(&racam_paper()));
    let mut out = Vec::new();
    for (title, sweep) in [
        ("Fig.16a — GEMM size sweep", gemm_sweep(Precision::Int8)),
        ("Fig.16b — GEMV size sweep", gemv_sweep(Precision::Int8)),
    ] {
        let mut t = Table::new(
            title,
            &["group", "shape", "latency", "latency_ns", "pe_util", "io_frac", "macs_x"],
        );
        let base_macs = sweep[0].shape.macs() as f64;
        for p in &sweep {
            let r = engine.search(&p.shape).expect("sweep shapes evaluate");
            let e = &r.best;
            t.row(vec![
                p.group.to_string(),
                p.shape.label(),
                fmt_ns(e.total_ns()),
                format!("{:.0}", e.total_ns()),
                format!("{:.3}", e.pe_util),
                format!("{:.3}", e.io_ns() / e.total_ns()),
                format!("{:.0}", p.shape.macs() as f64 / base_macs),
            ]);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MatmulShape;

    fn best(shape: MatmulShape) -> crate::mapping::Evaluation {
        MappingEngine::new(HwModel::new(&racam_paper())).search(&shape).expect("evaluates").best
    }

    #[test]
    fn gemm_scaling_is_near_ideal() {
        // Paper: 4096x compute (2048³→32768³) costs only ~2985x latency.
        let small = best(MatmulShape::new(2048, 2048, 2048, Precision::Int8));
        let large = best(MatmulShape::new(32768, 32768, 32768, Precision::Int8));
        let growth = large.total_ns() / small.total_ns();
        assert!(growth < 4096.0 * 1.15, "latency growth {growth:.0}x for 4096x compute");
        assert!(growth > 100.0, "growth {growth:.0}x suspiciously small");
        assert!(large.pe_util > small.pe_util);
        assert!(large.pe_util > 0.5, "large-GEMM util {}", large.pe_util);
    }

    #[test]
    fn gemv_latency_grows_sublinearly() {
        // Paper: 256x size → only ~4x latency for GEMV.
        let small = best(MatmulShape::new(1, 2048, 2048, Precision::Int8));
        let large = best(MatmulShape::new(1, 32768, 32768, Precision::Int8));
        let size_growth = (32768.0 * 32768.0) / (2048.0 * 2048.0); // 256x
        let latency_growth = large.total_ns() / small.total_ns();
        assert!(
            latency_growth < size_growth / 4.0,
            "GEMV latency growth {latency_growth:.1}x for {size_growth:.0}x size"
        );
    }

    #[test]
    fn gemm_is_compute_dominated() {
        // Paper: >98% compute for the largest GEMM.
        let large = best(MatmulShape::new(32768, 32768, 32768, Precision::Int8));
        let io_frac = large.io_ns() / large.total_ns();
        assert!(io_frac < 0.1, "I/O fraction {io_frac}");
    }
}
