//! `exp faults` — goodput under deterministic partial failure on the
//! disaggregated cluster.
//!
//! Every cell replays the same seed-deterministic request stream on the
//! same 2-prefill + 2-decode cluster; what changes is the injected
//! [`FaultSpec`] (a seeded schedule of simulated-time fault events) and
//! the [`RecoveryPolicy`] the coordinator recovers with.  The fault-free
//! baseline row anchors the sweep, then each fault *intensity* — a
//! brownout/crash mix escalating to a two-shard crash with a KV-link
//! outage and DRAM channel loss — is graded under each recovery policy:
//! `balanced` (the default bounded retry budget), `failfast` (zero
//! retries: evacuated requests fail immediately), and `guarded` (a
//! degradation controller that sheds evacuees once surviving
//! fresh-prompt capacity drops below a utilization ceiling).
//!
//! Headline columns: **availability** (delivered / submitted), the
//! **failed / shed / retry** tallies from [`FaultTally`], and the
//! fault-free metrics they trade against — **p95 TTFT** and **goodput**.
//! The second table is the per-crash surviving-capacity timeline of the
//! heaviest cell under the balanced policy.
//!
//! [`FaultTally`]: crate::coordinator::FaultTally

use crate::config::json::Value;
use crate::config::{
    gpt3_6_7b, racam_paper, ArrivalProcess, ClusterSpec, FaultEvent, FaultSpec, LengthDist,
    LlmSpec, RecoveryPolicy, TrafficSpec,
};
use crate::coordinator::{ClusterBuilder, Request, SyntheticEngine};
use crate::mapping::MappingService;
use crate::metrics::fmt_ns;
use crate::report::Table;
use crate::telemetry::Metrics;
use crate::traffic::{generate, ttft_percentiles_where, SloSummary};

/// 2 prefill + 2 decode shards (channel partition: 4 × 2 of the paper's 8).
const PREFILL: usize = 2;
const DECODE: usize = 2;
const SHARDS: usize = PREFILL + DECODE;
const MAX_BATCH: usize = 4;
/// Schedule seed stamped into every [`FaultSpec`] and the bench config.
const SEED: u64 = 0xFA_017;
const REQUESTS: u64 = 32;
const RATE: f64 = 300.0;
const DEADLINE_NS: u64 = 150_000_000; // 150 ms mean e2e SLO
/// `guarded` policy ceiling: with 2 prefill shards, one crash leaves a
/// surviving fraction of 0.5 < 0.75, so evacuees are degrade-shed.
const CEILING: f64 = 0.75;

/// The fault intensities swept, in row order (label, events).  The
/// baseline (empty) schedule is prepended by [`matrix`].
fn intensities() -> Vec<(&'static str, Vec<FaultEvent>)> {
    vec![
        (
            "crash1+brownout",
            vec![
                // Prefill shard 0 dies at t=0: its whole admission share
                // is evacuated for re-dispatch onto prefill shard 1.
                FaultEvent::ShardCrash { shard: 0, at_ns: 0.0 },
                // The surviving prefill shard runs 1.5x slower throughout.
                FaultEvent::Brownout {
                    shard: 1,
                    start_ns: 0.0,
                    end_ns: 1e15,
                    slowdown: 1.5,
                },
            ],
        ),
        (
            "crash2+outage+chloss",
            vec![
                FaultEvent::ShardCrash { shard: 0, at_ns: 0.0 },
                // One decode shard dies too; handoffs route around it.
                FaultEvent::ShardCrash { shard: PREFILL + 1, at_ns: 0.0 },
                // The KV link is down for the first 5 ms; interrupted
                // transfers back off deterministically and retry.
                FaultEvent::LinkOutage { start_ns: 0.0, end_ns: 5e6 },
                // The decode group loses one of its 2 DRAM channels at
                // t=0 and is re-priced at the surviving channel count.
                FaultEvent::ChannelLoss {
                    group: "decode".into(),
                    at_ns: 0.0,
                    channels_lost: 1,
                },
            ],
        ),
    ]
}

/// The recovery policies each intensity is graded under.
fn policies() -> Vec<(&'static str, RecoveryPolicy)> {
    vec![
        ("balanced", RecoveryPolicy::default()),
        ("failfast", RecoveryPolicy { retry_budget: 0, ..RecoveryPolicy::default() }),
        ("guarded", RecoveryPolicy { utilization_ceiling: CEILING, ..RecoveryPolicy::default() }),
    ]
}

/// Experiment-specific entries for the `BENCH_faults.json` config block.
pub(crate) fn bench_config() -> Vec<(&'static str, Value)> {
    let policies = policies();
    vec![
        (
            "intensities",
            Value::Arr(intensities().iter().map(|(l, _)| Value::Str(l.to_string())).collect()),
        ),
        (
            "policies",
            Value::Arr(policies.iter().map(|(l, _)| Value::Str(l.to_string())).collect()),
        ),
        ("schedulers", Value::Arr(vec![Value::Str("fcfs".into())])),
        ("rates_per_s", Value::Arr(vec![Value::Num(RATE)])),
        ("requests", Value::Num(REQUESTS as f64)),
        ("fault_seed", Value::Num(SEED as f64)),
        ("retry_budget", Value::Num(RecoveryPolicy::default().retry_budget as f64)),
        ("utilization_ceiling", Value::Num(CEILING)),
        ("deadline_ms", Value::Num(DEADLINE_NS as f64 / 1e6)),
        (
            "kv_link_gbps",
            Value::Num(ClusterSpec::disaggregated(PREFILL, DECODE, MAX_BATCH).kv_link_gbps),
        ),
    ]
}

/// The seed-deterministic open-loop stream every cell replays.
fn stream(rate_per_s: f64, requests: u64) -> Vec<Request> {
    generate(&TrafficSpec {
        seed: SEED,
        requests,
        arrival: ArrivalProcess::Poisson { rate_per_s },
        prompt: LengthDist::Uniform { lo: 16, hi: 96 },
        output: LengthDist::Uniform { lo: 6, hi: 12 },
        deadline_ns: Some(DEADLINE_NS),
    })
}

/// One graded cell plus the headline TTFT slice.
struct Cell {
    summary: SloSummary,
    ttft_p95: f64,
}

impl Cell {
    fn headers() -> Vec<&'static str> {
        vec![
            "run",
            "reqs",
            "delivered",
            "failed",
            "shed",
            "retries",
            "kv_retries",
            "availability",
            "ttft_p95",
            "goodput_tok/s",
        ]
    }

    fn row(&self, label: &str) -> Vec<String> {
        let s = &self.summary;
        vec![
            label.to_string(),
            s.requests.to_string(),
            (s.requests - s.shed_requests - s.failed_requests).to_string(),
            s.failed_requests.to_string(),
            s.shed_requests.to_string(),
            s.retries.to_string(),
            s.kv_retries.to_string(),
            format!("{:.1}%", 100.0 * s.availability),
            fmt_ns(self.ttft_p95),
            format!("{:.0}", s.goodput_tokens_per_s),
        ]
    }
}

/// Serve one `(events, policy)` cell over `stream` and grade it.
fn run_cell(
    services: &[MappingService],
    model: &LlmSpec,
    events: &[FaultEvent],
    policy: RecoveryPolicy,
    stream: &[Request],
) -> crate::Result<Cell> {
    let spec = ClusterSpec::disaggregated(PREFILL, DECODE, MAX_BATCH);
    let mut coord =
        ClusterBuilder::with_spec_and_services(spec, model.clone(), services.to_vec())?
            .build(|_| SyntheticEngine::new(64, 256));
    coord.set_faults(&FaultSpec { seed: SEED, events: events.to_vec(), recovery: policy })?;
    for req in stream {
        coord.submit(req.clone());
    }
    let report = coord.run_to_completion()?;
    Ok(Cell {
        summary: SloSummary::from_report(&report),
        ttft_p95: ttft_percentiles_where(&report, |_| true).p95,
    })
}

/// The fault-free baseline plus the intensity × policy matrix, the
/// surviving-capacity timeline of the heaviest balanced cell, and the
/// telemetry [`Metrics`] registry merged over every cell in row order.
fn matrix(
    services: &[MappingService],
    model: &LlmSpec,
    rate_per_s: f64,
    requests: u64,
) -> crate::Result<(Table, Table, Metrics)> {
    let mut t = Table::new(
        &format!(
            "Fault injection — {} on {PREFILL}p+{DECODE}d shards × batch {MAX_BATCH}, \
             {requests} requests @ {rate_per_s}/s, {}ms e2e SLO; availability and goodput \
             per fault intensity × recovery policy (seed {SEED:#x})",
            model.name,
            DEADLINE_NS / 1_000_000
        ),
        &Cell::headers(),
    );
    let stream = stream(rate_per_s, requests);
    let mut metrics = Metrics::default();
    let baseline = run_cell(services, model, &[], RecoveryPolicy::default(), &stream)?;
    metrics.merge(&baseline.summary.metrics);
    t.row(baseline.row("baseline"));
    let mut heaviest_balanced = None;
    for (intensity, events) in intensities() {
        for (policy, recovery) in policies() {
            let cell = run_cell(services, model, &events, recovery, &stream)?;
            metrics.merge(&cell.summary.metrics);
            if policy == "balanced" {
                heaviest_balanced = Some(cell.summary.clone());
            }
            t.row(cell.row(&format!("{intensity}/{policy}")));
        }
    }
    let avail = heaviest_balanced
        .ok_or_else(|| anyhow::anyhow!("the intensity roster is empty"))?
        .availability_table(&format!(
            "Fault injection — availability detail (heaviest intensity, balanced policy, {})",
            model.name
        ));
    metrics.absorb_mapping(super::common::mapping_counters(services));
    Ok((t, avail, metrics))
}

pub fn run() -> crate::Result<(Vec<Table>, Metrics)> {
    // One shared 2-channel-per-shard partition prices every cell from the
    // same caches; the channel-loss event derates from these per shard.
    let services = ClusterBuilder::new(
        ClusterSpec::disaggregated(PREFILL, DECODE, MAX_BATCH),
        &racam_paper(),
        gpt3_6_7b(),
    )?
    .services()
    .to_vec();
    let (t, avail, metrics) = matrix(&services, &gpt3_6_7b(), RATE, REQUESTS)?;
    Ok((vec![t, avail], metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn services() -> Vec<MappingService> {
        vec![MappingService::for_config(&racam_paper()); SHARDS]
    }

    #[test]
    fn balanced_policy_survives_a_prefill_crash_with_full_availability() {
        let stream = stream(400.0, 12);
        let (_, events) = intensities().remove(0);
        let cell =
            run_cell(&services(), &tiny_spec(), &events, RecoveryPolicy::default(), &stream)
                .unwrap();
        let s = &cell.summary;
        assert_eq!(s.requests, 12);
        assert_eq!(s.failed_requests, 0);
        assert_eq!(s.shed_requests, 0);
        assert!(s.retries > 0, "the crashed prefill shard's share is requeued");
        assert_eq!(s.availability, 1.0);
    }

    #[test]
    fn failfast_policy_fails_the_evacuated_requests() {
        let stream = stream(400.0, 12);
        let (_, events) = intensities().remove(0);
        let policy = RecoveryPolicy { retry_budget: 0, ..RecoveryPolicy::default() };
        let cell = run_cell(&services(), &tiny_spec(), &events, policy, &stream).unwrap();
        let s = &cell.summary;
        assert!(s.failed_requests > 0, "zero retry budget fails every evacuee");
        assert_eq!(s.retries, 0);
        assert!(s.availability < 1.0);
        assert_eq!(s.requests, 12, "failed requests still appear in the report exactly once");
    }

    #[test]
    fn guarded_policy_degrade_sheds_below_the_ceiling() {
        let stream = stream(400.0, 12);
        let (_, events) = intensities().remove(0);
        let policy = RecoveryPolicy { utilization_ceiling: CEILING, ..RecoveryPolicy::default() };
        let cell = run_cell(&services(), &tiny_spec(), &events, policy, &stream).unwrap();
        let s = &cell.summary;
        assert!(s.degrade_shed > 0, "0.5 surviving fraction is below the 0.75 ceiling");
        assert_eq!(s.retries, 0, "the controller sheds instead of retrying");
        assert!(s.shed_requests > 0);
        assert!(s.availability < 1.0);
    }

    #[test]
    fn matrix_covers_baseline_and_every_intensity_policy_pair() {
        let (t, avail, metrics) = matrix(&services(), &tiny_spec(), 400.0, 8).unwrap();
        assert_eq!(t.num_rows(), 1 + intensities().len() * policies().len());
        let rendered = t.render();
        assert!(rendered.contains("baseline"), "{rendered}");
        for (intensity, _) in intensities() {
            for (policy, _) in policies() {
                assert!(
                    rendered.contains(&format!("{intensity}/{policy}")),
                    "missing {intensity}/{policy}:\n{rendered}"
                );
            }
        }
        // The detail table reports the heaviest intensity's two crashes.
        assert!(avail.render().contains("capacity["), "{}", avail.render());
        assert!(metrics.requests > 0);
        assert!(metrics.retries > 0 || metrics.failed > 0);
    }

    #[test]
    fn cells_are_deterministic_across_reruns() {
        let stream = stream(400.0, 10);
        let (_, events) = intensities().remove(1);
        let a = run_cell(&services(), &tiny_spec(), &events, RecoveryPolicy::default(), &stream)
            .unwrap();
        let b = run_cell(&services(), &tiny_spec(), &events, RecoveryPolicy::default(), &stream)
            .unwrap();
        assert_eq!(a.summary.requests, b.summary.requests);
        assert_eq!(a.summary.failed_requests, b.summary.failed_requests);
        assert_eq!(a.summary.retries, b.summary.retries);
        assert_eq!(a.summary.kv_retries, b.summary.kv_retries);
        assert_eq!(a.ttft_p95.to_bits(), b.ttft_p95.to_bits());
        assert_eq!(
            a.summary.goodput_tokens_per_s.to_bits(),
            b.summary.goodput_tokens_per_s.to_bits()
        );
    }

    #[test]
    fn bench_config_names_the_sweep_axes() {
        let keys: Vec<&str> = bench_config().iter().map(|(k, _)| *k).collect();
        for k in
            ["intensities", "policies", "schedulers", "rates_per_s", "fault_seed", "retry_budget"]
        {
            assert!(keys.contains(&k), "missing {k}");
        }
    }
}
