//! `detcheck` — the determinism & purity static-analysis gate.
//!
//! Scans Rust sources for patterns that break the repo's bit-identity
//! contracts (wall-clock reads in simulated paths, `HashMap` iteration
//! order leaking into results, stray threads, ad-hoc float reductions,
//! panicking library code, engine-parity gaps) and exits nonzero on any
//! unwaived finding.  See `docs/analysis.md` for the rule catalog and
//! waiver etiquette.
//!
//! Usage (from `rust/`):
//!
//! ```text
//! cargo run --bin detcheck                  # scan src/ and tests/
//! cargo run --bin detcheck -- src tests --json results/detcheck.json
//! ```

use racam::analysis;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match analysis::run_cli(&args) {
        Ok(report) => {
            print!("{}", report.render());
            if report.unwaived_count() > 0 {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
