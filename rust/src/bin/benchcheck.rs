//! `benchcheck` — guard the bench-artifact schema across runs.
//!
//! Every `exp` run writes `results/BENCH_<id>.json`; CI uploads them so
//! the perf trajectory diffs across PRs.  This tool fails CI with a
//! readable per-experiment diff when any schema field (a JSON key path or
//! a table column) disappears between runs:
//!
//! ```text
//! benchcheck check <results_dir> <manifest.json>   # CI gate
//! benchcheck write <results_dir> <manifest.json>   # refresh after an
//!                                                  # intentional change
//! ```
//!
//! The manifest (`rust/bench_schema.json`) is committed; `write`
//! regenerates it from freshly produced artifacts.

use racam::config::json;
use racam::report::schema;
use std::path::Path;

fn main() {
    if let Err(e) = run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Vec<String>) -> racam::Result<()> {
    let usage = "usage: benchcheck <check|write> <results_dir> <manifest.json>";
    let (mode, dir, manifest_path) = match args.as_slice() {
        [m, d, f] => (m.as_str(), Path::new(d), Path::new(f)),
        _ => anyhow::bail!("{usage}"),
    };
    match mode {
        "write" => {
            let manifest = schema::manifest_from_dir(dir)?;
            std::fs::write(manifest_path, manifest.pretty())?;
            println!("wrote {} from {}", manifest_path.display(), dir.display());
            Ok(())
        }
        "check" => {
            let manifest = json::parse(&std::fs::read_to_string(manifest_path)?)
                .map_err(|e| anyhow::anyhow!("{}: {e:?}", manifest_path.display()))?;
            let (problems, notes) = schema::check_dir(dir, &manifest)?;
            for n in &notes {
                println!("note: {n}");
            }
            if problems.is_empty() {
                println!(
                    "bench schema OK: every manifest field present in {}",
                    dir.display()
                );
                return Ok(());
            }
            eprintln!("bench schema regression ({} problem(s)):", problems.len());
            for p in &problems {
                eprintln!("  - {p}");
            }
            anyhow::bail!("bench artifact schema fields disappeared; see diff above")
        }
        other => anyhow::bail!("unknown mode '{other}'\n{usage}"),
    }
}
