//! `tracecheck` — validate an exported Chrome-trace JSON.
//!
//! CI generates a trace artifact from the release bench step
//! (`racam serve ... --trace-out results/trace.json`) and runs this tool
//! on it before uploading, so a malformed exporter fails the build
//! instead of shipping a trace the viewer rejects:
//!
//! ```text
//! tracecheck <trace.json> [more.json ...]
//! ```
//!
//! Checks (see [`racam::telemetry::validate_trace`]): the file parses as
//! JSON with a `traceEvents` array, every event's `ph` is one the
//! exporter emits, per-track (`pid`, `tid`) timestamps are monotonically
//! non-decreasing and finite, and every `B` span open has a matching `E`
//! close with the same name.

use racam::config::json;
use racam::telemetry::validate_trace;

fn main() {
    if let Err(e) = run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Vec<String>) -> racam::Result<()> {
    anyhow::ensure!(!args.is_empty(), "usage: tracecheck <trace.json> [more.json ...]");
    for path in &args {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let trace =
            json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: invalid JSON: {e:?}"))?;
        let check =
            validate_trace(&trace).map_err(|e| anyhow::anyhow!("{path}: invalid trace: {e:#}"))?;
        println!(
            "{path}: valid Chrome trace — {} events on {} tracks ({} spans), \
             per-track timestamps monotonic",
            check.events, check.tracks, check.spans
        );
    }
    Ok(())
}
