//! `experiments` — regenerate every paper table/figure (DESIGN.md's
//! experiment index).  `experiments all` writes results/<id>.{txt,csv}.

use racam::experiments;

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let ids: Vec<&str> =
        if arg == "all" { experiments::ALL_IDS.to_vec() } else { vec![Box::leak(arg.into_boxed_str())] };
    let mut failed = false;
    for id in ids {
        println!("\n=== {id} ===");
        match experiments::run(id) {
            Ok(tables) => {
                for t in tables {
                    println!("{}", t.render());
                }
            }
            Err(e) => {
                eprintln!("{id} failed: {e:#}");
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
