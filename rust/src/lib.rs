//! # RACAM — Reuse-Aware Computation and Automated Mapping for in-DRAM PIM
//!
//! Full-system reproduction of *"RACAM: Enhancing DRAM with Reuse-Aware
//! Computation and Automated Mapping for ML Inference"* (Ma et al., 2025).
//!
//! The crate is the **Layer-3 Rust coordinator** of a three-layer stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a DRAM-PIM simulator
//!   (both *functional*, computing bit-serial arithmetic bit-by-bit, and
//!   *analytical*, accounting latency the way the paper's hardware model
//!   does), the RACAM peripheral micro-architecture (locality buffers,
//!   bit-serial PEs, popcount reduction units, broadcast units), the extended
//!   PIM ISA, the automated mapping framework with exhaustive search, the
//!   LLM-to-kernel parser, GPU (H100) and Proteus baselines, the §5.2 area
//!   model, and a serving coordinator.
//! * **L2 (JAX, build-time)** — quantized GEMM/GEMV and a small transformer
//!   block, AOT-lowered to HLO text in `artifacts/`, loaded at runtime by
//!   [`runtime`] through PJRT and used as the numerical oracle.
//! * **L1 (Pallas, build-time)** — the tiled quantized-GEMM kernel the L2
//!   model calls; its VMEM-resident weight tile is the TPU analogue of
//!   RACAM's locality buffer (see DESIGN.md §Hardware-Adaptation).
//!
//! Python never runs on the request path: `make artifacts` runs once and the
//! Rust binary is self-contained afterwards.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`config`] | hardware + workload configuration (paper Table 2/3/4) |
//! | [`dram`] | DRAM substrate: geometry, DDR5 timing engine, SALP-MASA, commands |
//! | [`pim`] | RACAM peripherals: PE, locality buffer, popcount, broadcast, ISA, FSM, functional executor |
//! | [`mapping`] | §4 mapping framework: space enumeration, software + hardware models, and the shared `MappingService` (parallel exhaustive search, concurrent once-per-shape cache, warm-start persistence via `mapping::store`) |
//! | [`workloads`] | LLM parser, GEMM/GEMV workloads, inference scenarios, and the `CostModel` trait every priced system implements |
//! | [`baselines`] | H100 roofline and Proteus models (uniform `CostModel` impls) |
//! | [`area`] | §5.2 area estimation |
//! | [`metrics`] | latency breakdowns, utilization, counters |
//! | [`report`] | paper-style table renderers + CSV |
//! | [`runtime`] | artifact discovery; PJRT loader/executor behind the `pjrt` feature |
//! | [`coordinator`] | serving: per-shard `Server` running an event-driven iteration engine (simulated clock, chunked prefill via `config::ServingPolicy`, scheduler preemption, async intake), and a role-aware multi-worker `Coordinator` assembled by `ClusterBuilder` from a declarative `config::ClusterSpec` (shard groups, per-shard DRAM channel partitioning over shared mapping services, prefill/decode disaggregation with KV-transfer accounting) |
//! | [`telemetry`] | zero-cost observability: `Recorder` trait with a monomorphized no-op default, simulated-time event stream, deterministic log-bucketed metrics registry, Chrome-trace exporter + validator |
//! | [`traffic`] | open-loop workload generator (seeded PRNG, Poisson/bursty arrivals, trace replay) + SLO metrics (TTFT/TPOT/e2e tails, goodput, shed/preemption counts, utilization) |
//! | [`experiments`] | one entry point per paper table/figure |

pub mod analysis;
pub mod area;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod energy;
pub mod experiments;
pub mod mapping;
pub mod metrics;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod telemetry;
pub mod traffic;
pub mod workloads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
