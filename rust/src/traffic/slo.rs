//! SLO-graded serving metrics: tail latency percentiles, deadline goodput,
//! and per-shard utilization, computed from a [`ServerReport`].
//!
//! Serving-oriented PIM follow-ups (Sangam, MVDRAM) grade systems on
//! TTFT/TPOT tails under live load, not mean kernel latency; this module
//! is that grading layer for the coordinator.  All times are on the
//! simulated RACAM clock:
//!
//! * **TTFT** — arrival to first token, *including queueing delay* (the
//!   intrinsic prefill cost is `RequestResult::sim_ttft_ns`; the
//!   difference is time spent waiting for admission).
//! * **TPOT** — mean inter-token gap after the first token.
//! * **e2e** — arrival to completion.
//! * **goodput** — token throughput counting only requests that met their
//!   deadline (requests without a deadline always count).
//! * **utilization** — per shard, the busy fraction of its simulated
//!   makespan (idle = the clock jumping over arrival gaps).

use crate::coordinator::{ServerReport, ShardStats};
use crate::metrics::{fmt_ns, percentile_sorted};
use crate::report::Table;

/// Tail summary of one latency population.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl Percentiles {
    pub fn from(values: &[f64]) -> Percentiles {
        if values.is_empty() {
            return Percentiles::default();
        }
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Percentiles {
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            max: *sorted.last().expect("non-empty"),
        }
    }
}

/// SLO-graded summary of one serving run.
#[derive(Debug, Clone)]
pub struct SloSummary {
    pub requests: usize,
    pub total_tokens: usize,
    /// Arrival → first token (queueing + prefill), ns.
    pub ttft: Percentiles,
    /// Mean inter-token time per request (requests with ≥ 2 tokens), ns.
    pub tpot: Percentiles,
    /// Arrival → completion, ns.
    pub e2e: Percentiles,
    /// Fraction of requests that met their deadline (1.0 when none carry
    /// deadlines).
    pub slo_attainment: f64,
    /// Tokens/s over the simulated makespan, all requests.
    pub throughput_tokens_per_s: f64,
    /// Tokens/s counting only deadline-meeting requests.
    pub goodput_tokens_per_s: f64,
    /// Simulated makespan of the run (slowest shard's clock), ns.
    pub makespan_ns: f64,
    /// Per-shard (id, busy-fraction, mean batch occupancy).
    pub shard_utilization: Vec<(usize, f64, f64)>,
}

impl SloSummary {
    /// Grade a serving report.  Requests without deadlines count as
    /// meeting their SLO.
    pub fn from_report(report: &ServerReport) -> SloSummary {
        let ttft: Vec<f64> = report.results.iter().map(|r| r.ttft_ns()).collect();
        let e2e: Vec<f64> = report.results.iter().map(|r| r.e2e_ns()).collect();
        let tpot: Vec<f64> = report
            .results
            .iter()
            .filter(|r| r.tokens.len() >= 2)
            .map(|r| r.tpot_ns())
            .collect();
        let met = report.results.iter().filter(|r| r.met_deadline()).count();
        let good_tokens: usize = report
            .results
            .iter()
            .filter(|r| r.met_deadline())
            .map(|r| r.tokens.len())
            .sum();
        let makespan_ns = report
            .shards
            .iter()
            .map(|s: &ShardStats| if s.sim_clock_ns > 0.0 { s.sim_clock_ns } else { s.sim_ns })
            .fold(0.0f64, f64::max);
        let span_s = (makespan_ns / 1e9).max(f64::MIN_POSITIVE);
        SloSummary {
            requests: report.results.len(),
            total_tokens: report.total_tokens,
            ttft: Percentiles::from(&ttft),
            tpot: Percentiles::from(&tpot),
            e2e: Percentiles::from(&e2e),
            slo_attainment: if report.results.is_empty() {
                1.0
            } else {
                met as f64 / report.results.len() as f64
            },
            throughput_tokens_per_s: report.total_tokens as f64 / span_s,
            goodput_tokens_per_s: good_tokens as f64 / span_s,
            makespan_ns,
            shard_utilization: report
                .shards
                .iter()
                .map(|s| (s.shard, s.utilization(), s.occupancy))
                .collect(),
        }
    }

    /// One row of the scheduler × rate comparison tables (matches
    /// [`SloSummary::table_headers`]).
    pub fn table_row(&self, label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            self.requests.to_string(),
            fmt_ns(self.ttft.p50),
            fmt_ns(self.ttft.p99),
            fmt_ns(self.tpot.p50),
            fmt_ns(self.tpot.p99),
            fmt_ns(self.e2e.p99),
            format!("{:.0}", self.goodput_tokens_per_s),
            format!("{:.0}%", 100.0 * self.slo_attainment),
            format!(
                "{:.0}%",
                100.0
                    * if self.shard_utilization.is_empty() {
                        0.0
                    } else {
                        self.shard_utilization.iter().map(|(_, u, _)| u).sum::<f64>()
                            / self.shard_utilization.len() as f64
                    }
            ),
        ]
    }

    pub fn table_headers() -> Vec<&'static str> {
        vec![
            "run", "reqs", "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99", "e2e_p99",
            "goodput_tok/s", "slo_met", "util",
        ]
    }

    /// Per-shard utilization table for this run.
    pub fn shard_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["shard", "busy", "occupancy"]);
        for (shard, util, occ) in &self.shard_utilization {
            t.row(vec![
                shard.to_string(),
                format!("{:.0}%", 100.0 * util),
                format!("{:.0}%", 100.0 * occ),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{RequestResult, ServerReport, ShardStats};

    fn result(id: u64, arrival: f64, first: f64, finish: f64, n_tokens: usize) -> RequestResult {
        RequestResult {
            id,
            tokens: vec![1; n_tokens],
            sim_ttft_ns: first - arrival,
            sim_total_ns: finish - arrival,
            wall_ns: 1.0,
            arrival_ns: arrival,
            sim_first_token_at_ns: first,
            sim_finish_at_ns: finish,
            deadline_ns: None,
        }
    }

    fn report(results: Vec<RequestResult>, clock_ns: f64, idle_ns: f64) -> ServerReport {
        let total_tokens = results.iter().map(|r| r.tokens.len()).sum();
        ServerReport {
            sim_tokens_per_s: 0.0,
            wall_tokens_per_s: 0.0,
            total_tokens,
            results,
            shards: vec![ShardStats {
                shard: 0,
                requests: 1,
                tokens: total_tokens,
                sim_ns: clock_ns,
                wall_ns: 1.0,
                sim_clock_ns: clock_ns,
                sim_idle_ns: idle_ns,
                decode_iterations: 4,
                occupancy: 0.5,
            }],
        }
    }

    #[test]
    fn summary_computes_ttft_tpot_e2e() {
        // One request: arrives at 100, first token at 300, done at 700
        // with 5 tokens → ttft 200, e2e 600, tpot (700-300)/4 = 100.
        let rep = report(vec![result(0, 100.0, 300.0, 700.0, 5)], 700.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.ttft.p50, 200.0);
        assert_eq!(s.e2e.p50, 600.0);
        assert_eq!(s.tpot.p50, 100.0);
        assert_eq!(s.slo_attainment, 1.0);
        assert!((s.throughput_tokens_per_s - 5.0 / (700.0 / 1e9)).abs() < 1.0);
        assert_eq!(s.throughput_tokens_per_s, s.goodput_tokens_per_s);
    }

    #[test]
    fn goodput_excludes_missed_deadlines() {
        let mut late = result(0, 0.0, 10.0, 1000.0, 4);
        late.deadline_ns = Some(500.0);
        let on_time = result(1, 0.0, 10.0, 400.0, 4);
        let rep = report(vec![late, on_time], 1000.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.slo_attainment, 0.5);
        assert!((s.goodput_tokens_per_s - s.throughput_tokens_per_s / 2.0).abs() < 1e-6);
    }

    #[test]
    fn single_token_requests_skip_tpot() {
        let rep = report(vec![result(0, 0.0, 10.0, 10.0, 1)], 10.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.tpot.p50, 0.0);
        assert_eq!(s.tpot.max, 0.0);
    }

    #[test]
    fn empty_report_is_benign() {
        let s = SloSummary::from_report(&report(vec![], 0.0, 0.0));
        assert_eq!(s.requests, 0);
        assert_eq!(s.slo_attainment, 1.0);
        assert_eq!(s.ttft.p99, 0.0);
    }

    #[test]
    fn tables_render() {
        let rep = report(vec![result(0, 0.0, 10.0, 50.0, 3)], 100.0, 25.0);
        let s = SloSummary::from_report(&rep);
        let row = s.table_row("fcfs@100");
        assert_eq!(row.len(), SloSummary::table_headers().len());
        assert_eq!(row[0], "fcfs@100");
        let t = s.shard_table("util");
        assert_eq!(t.num_rows(), 1);
        assert!(t.render().contains("75%"), "{}", t.render());
    }
}
