//! SLO-graded serving metrics: tail latency percentiles, deadline goodput,
//! preemption/shed accounting, and per-shard utilization, computed from a
//! [`ServerReport`].
//!
//! Serving-oriented PIM follow-ups (Sangam, MVDRAM) grade systems on
//! TTFT/TPOT tails under live load, not mean kernel latency; this module
//! is that grading layer for the coordinator.  All times are on the
//! simulated RACAM clock:
//!
//! * **TTFT** — arrival to first token, *including queueing delay* (the
//!   intrinsic prefill cost is `RequestResult::sim_ttft_ns`; the
//!   difference is time spent waiting for admission).
//! * **TPOT** — mean inter-token gap after the first token.
//! * **e2e** — arrival to completion.
//! * **goodput** — token throughput counting only requests that met their
//!   deadline (requests without a deadline always count; shed requests
//!   never do).
//! * **utilization** — per shard, the busy fraction of its simulated
//!   makespan (idle = the clock jumping over arrival gaps).
//! * **shed / preemptions / chunk stalls** — what the serving policy did:
//!   requests given up on ([`Preemption::Shed`]), requests re-queued, and
//!   the simulated time decoders spent stalled behind prefill steps
//!   ([`ShardStats::chunk_stall_ns`]).
//!
//! * **availability** — under a fault schedule
//!   ([`crate::config::FaultSpec`]), the delivered fraction of all
//!   requests, with the recovery activity (retries, KV re-transfers,
//!   degradation sheds) and the per-group surviving-capacity timeline
//!   reported alongside (see `docs/robustness.md`).
//!
//! Latency populations (TTFT/TPOT/e2e) **exclude shed and failed
//! requests** — neither delivered, so their timestamps grade the
//! shedding/failover decision, not the serving path.  Shed work shows up
//! in `shed_requests`, failed work in `failed_requests`; both always miss
//! their SLO and are excluded from goodput.
//!
//! [`Preemption::Shed`]: crate::coordinator::Preemption

use crate::config::ShardRole;
use crate::coordinator::{RequestResult, ServerReport, ShardStats};
use crate::metrics::{fmt_ns, percentile_sorted};
use crate::report::Table;
use crate::telemetry::Metrics;

/// Sequential left-to-right sum — the documented reduction order for
/// every `f64` aggregate in the SLO tables, so reassociation can never
/// perturb a reported number (see docs/analysis.md, float-reduce).
fn seq_sum(values: impl Iterator<Item = f64>) -> f64 {
    values.fold(0.0, |acc, x| acc + x)
}

/// Tail summary of one latency population.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl Percentiles {
    pub fn from(values: &[f64]) -> Percentiles {
        if values.is_empty() {
            return Percentiles::default();
        }
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Percentiles {
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            mean: seq_sum(sorted.iter().copied()) / sorted.len() as f64,
            max: sorted.last().copied().unwrap_or_default(),
        }
    }
}

/// TTFT percentiles over the delivered (non-shed, non-failed) requests
/// matching a predicate — e.g. the short-request population of a
/// mixed-length workload (`|r| r.prompt_tokens <= 256`).
pub fn ttft_percentiles_where(
    report: &ServerReport,
    pred: impl Fn(&RequestResult) -> bool,
) -> Percentiles {
    let ttft: Vec<f64> = report
        .results
        .iter()
        .filter(|r| !r.shed && !r.failed && pred(r))
        .map(|r| r.ttft_ns())
        .collect();
    Percentiles::from(&ttft)
}

/// SLO-graded summary of one serving run.
#[derive(Debug, Clone)]
pub struct SloSummary {
    pub requests: usize,
    pub total_tokens: usize,
    /// Arrival → first token (queueing + prefill), ns; delivered requests.
    pub ttft: Percentiles,
    /// Mean inter-token time per request (requests with ≥ 2 tokens), ns.
    pub tpot: Percentiles,
    /// Arrival → completion, ns; delivered requests.
    pub e2e: Percentiles,
    /// Fraction of requests that met their deadline (1.0 when none carry
    /// deadlines; shed requests always miss).
    pub slo_attainment: f64,
    /// Tokens/s over the simulated makespan, all requests.
    pub throughput_tokens_per_s: f64,
    /// Tokens/s counting only deadline-meeting requests.
    pub goodput_tokens_per_s: f64,
    /// Simulated makespan of the run (slowest shard's clock), ns.
    pub makespan_ns: f64,
    /// Requests the serving policy shed instead of completing.
    pub shed_requests: usize,
    /// Running requests re-queued by preemption, summed over shards.
    pub preemptions: usize,
    /// Prefill steps executed, summed over shards.
    pub prefill_chunks: usize,
    /// Simulated time decoders spent stalled behind prefill steps, summed
    /// over shards, ns.
    pub chunk_stall_ns: f64,
    /// Simulated KV-transfer time charged on decode shards (the
    /// prefill→decode link of a disaggregated cluster), summed, ns.
    pub kv_transfer_ns: f64,
    /// Prefill→decode handoffs, summed over the link's *sending* side
    /// (each transferred request counts once).
    pub handoffs: usize,
    /// Requests that terminated `failed` under a fault schedule: crash
    /// evacuees whose retry budget ran out or that found no surviving
    /// shard (always 0 on a fault-free run).
    pub failed_requests: usize,
    /// Crash-evacuation re-dispatches onto surviving shards.
    pub retries: usize,
    /// KV transfers re-sent after a link-outage interruption.
    pub kv_retries: usize,
    /// Evacuated requests shed by the degradation controller instead of
    /// being retried.
    pub degrade_shed: usize,
    /// Delivered fraction of all requests — goodput-style availability
    /// under faults (1.0 when nothing was shed or failed).
    pub availability: f64,
    /// Per-group surviving-capacity timeline: one `(detection ns, group,
    /// surviving fresh-capable shards)` entry per shard crash.
    pub capacity_timeline: Vec<(f64, String, usize)>,
    /// Per-shard utilization rows, in shard order.
    pub shard_utilization: Vec<ShardUtilization>,
    /// Deterministic telemetry registry derived from the same report:
    /// event counters plus log-bucketed TTFT/TPOT histograms, merged in
    /// shard order so multi-threaded runs report identically.
    pub metrics: Metrics,
}

/// One shard's utilization row (group label and role ride along so
/// disaggregated runs can be read per group).
#[derive(Debug, Clone)]
pub struct ShardUtilization {
    pub shard: usize,
    pub group: String,
    pub role: ShardRole,
    /// Busy fraction of the shard's simulated makespan.
    pub busy: f64,
    /// Mean batch occupancy across decode iterations.
    pub occupancy: f64,
    /// Handoffs this shard participated in (sent or received).
    pub handoffs: usize,
    /// KV-transfer time charged on this (decode) shard, ns.
    pub kv_transfer_ns: f64,
}

impl SloSummary {
    /// Grade a serving report.  Requests without deadlines count as
    /// meeting their SLO; shed and failed requests count as missing it
    /// and are excluded from the latency populations.
    pub fn from_report(report: &ServerReport) -> SloSummary {
        let delivered: Vec<&RequestResult> =
            report.results.iter().filter(|r| !r.shed && !r.failed).collect();
        let ttft: Vec<f64> = delivered.iter().map(|r| r.ttft_ns()).collect();
        let e2e: Vec<f64> = delivered.iter().map(|r| r.e2e_ns()).collect();
        let tpot: Vec<f64> =
            delivered.iter().filter(|r| r.tokens.len() >= 2).map(|r| r.tpot_ns()).collect();
        let met = report.results.iter().filter(|r| r.met_deadline()).count();
        let good_tokens: usize = report
            .results
            .iter()
            .filter(|r| r.met_deadline())
            .map(|r| r.tokens.len())
            .sum();
        let makespan_ns = report
            .shards
            .iter()
            .map(|s: &ShardStats| if s.sim_clock_ns > 0.0 { s.sim_clock_ns } else { s.sim_ns })
            .fold(0.0f64, f64::max);
        let span_s = (makespan_ns / 1e9).max(f64::MIN_POSITIVE);
        SloSummary {
            requests: report.results.len(),
            total_tokens: report.total_tokens,
            ttft: Percentiles::from(&ttft),
            tpot: Percentiles::from(&tpot),
            e2e: Percentiles::from(&e2e),
            slo_attainment: if report.results.is_empty() {
                1.0
            } else {
                met as f64 / report.results.len() as f64
            },
            throughput_tokens_per_s: report.total_tokens as f64 / span_s,
            goodput_tokens_per_s: good_tokens as f64 / span_s,
            makespan_ns,
            shed_requests: report.results.iter().filter(|r| r.shed).count(),
            preemptions: report.shards.iter().map(|s| s.preemptions).sum(),
            prefill_chunks: report.shards.iter().map(|s| s.prefill_chunks).sum(),
            chunk_stall_ns: report.shards.iter().map(|s| s.chunk_stall_ns).sum(),
            kv_transfer_ns: report.shards.iter().map(|s| s.kv_transfer_ns).sum(),
            handoffs: report
                .shards
                .iter()
                .filter(|s| s.role != ShardRole::Decode)
                .map(|s| s.handoffs)
                .sum(),
            failed_requests: report.results.iter().filter(|r| r.failed).count(),
            retries: report.faults.retries,
            kv_retries: report.faults.kv_retries,
            degrade_shed: report.faults.degrade_shed,
            availability: if report.results.is_empty() {
                1.0
            } else {
                delivered.len() as f64 / report.results.len() as f64
            },
            capacity_timeline: report.faults.capacity_timeline.clone(),
            shard_utilization: report
                .shards
                .iter()
                .map(|s| ShardUtilization {
                    shard: s.shard,
                    group: s.group.clone(),
                    role: s.role,
                    busy: s.utilization(),
                    occupancy: s.occupancy,
                    handoffs: s.handoffs,
                    kv_transfer_ns: s.kv_transfer_ns,
                })
                .collect(),
            metrics: Metrics::from_report(report),
        }
    }

    /// One row of the scheduler × rate comparison tables (matches
    /// [`SloSummary::table_headers`]).
    pub fn table_row(&self, label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            self.requests.to_string(),
            fmt_ns(self.ttft.p50),
            fmt_ns(self.ttft.p99),
            fmt_ns(self.tpot.p50),
            fmt_ns(self.tpot.p99),
            fmt_ns(self.e2e.p99),
            format!("{:.0}", self.goodput_tokens_per_s),
            format!("{:.0}%", 100.0 * self.slo_attainment),
            self.shed_requests.to_string(),
            format!(
                "{:.0}%",
                100.0
                    * if self.shard_utilization.is_empty() {
                        0.0
                    } else {
                        seq_sum(self.shard_utilization.iter().map(|s| s.busy))
                            / self.shard_utilization.len() as f64
                    }
            ),
        ]
    }

    pub fn table_headers() -> Vec<&'static str> {
        vec![
            "run", "reqs", "ttft_p50", "ttft_p99", "tpot_p50", "tpot_p99", "e2e_p99",
            "goodput_tok/s", "slo_met", "shed", "util",
        ]
    }

    /// Utilization table for this run.  The default (`per_shard = false`)
    /// aggregates by shard *group* — the readable view of a disaggregated
    /// run, one row per role — with busy/occupancy averaged and
    /// handoff/KV-transfer totals summed within each group; `per_shard =
    /// true` keeps the old one-row-per-shard breakdown (group label
    /// attached).
    pub fn utilization_table(&self, title: &str, per_shard: bool) -> Table {
        if per_shard {
            let mut t = Table::new(
                title,
                &["shard", "group", "role", "busy", "occupancy", "handoffs", "kv_transfer"],
            );
            for s in &self.shard_utilization {
                t.row(vec![
                    s.shard.to_string(),
                    s.group.clone(),
                    s.role.label().into(),
                    format!("{:.0}%", 100.0 * s.busy),
                    format!("{:.0}%", 100.0 * s.occupancy),
                    s.handoffs.to_string(),
                    fmt_ns(s.kv_transfer_ns),
                ]);
            }
            return t;
        }
        let mut t = Table::new(
            title,
            &["group", "role", "shards", "busy", "occupancy", "handoffs", "kv_transfer"],
        );
        // Group rows in first-appearance (shard) order.
        let mut seen: Vec<&str> = Vec::new();
        for s in &self.shard_utilization {
            if !seen.contains(&s.group.as_str()) {
                seen.push(&s.group);
            }
        }
        for group in seen {
            let members: Vec<&ShardUtilization> =
                self.shard_utilization.iter().filter(|s| s.group == group).collect();
            let n = members.len() as f64;
            t.row(vec![
                group.to_string(),
                members[0].role.label().into(),
                members.len().to_string(),
                format!("{:.0}%", 100.0 * seq_sum(members.iter().map(|s| s.busy)) / n),
                format!(
                    "{:.0}%",
                    100.0 * seq_sum(members.iter().map(|s| s.occupancy)) / n
                ),
                members.iter().map(|s| s.handoffs).sum::<usize>().to_string(),
                fmt_ns(seq_sum(members.iter().map(|s| s.kv_transfer_ns))),
            ]);
        }
        t
    }

    /// Per-shard utilization table (the pre-disaggregation breakdown;
    /// equivalent to `utilization_table(title, true)`).
    pub fn shard_table(&self, title: &str) -> Table {
        self.utilization_table(title, true)
    }

    /// Availability section of a fault run: delivered/failed/shed
    /// counters, recovery activity, and the per-group surviving-capacity
    /// timeline (one row per shard crash).  Renders all-zero on a
    /// fault-free run, so callers can emit it unconditionally.
    pub fn availability_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["metric", "value"]);
        t.row(vec!["availability".into(), format!("{:.1}%", 100.0 * self.availability)]);
        let delivered = self.requests - self.shed_requests - self.failed_requests;
        t.row(vec!["delivered".into(), delivered.to_string()]);
        t.row(vec!["failed".into(), self.failed_requests.to_string()]);
        t.row(vec!["shed".into(), self.shed_requests.to_string()]);
        t.row(vec!["retries".into(), self.retries.to_string()]);
        t.row(vec!["kv_retries".into(), self.kv_retries.to_string()]);
        t.row(vec!["degrade_shed".into(), self.degrade_shed.to_string()]);
        for (at_ns, group, surviving) in &self.capacity_timeline {
            t.row(vec![
                format!("capacity[{group}] @ {}", fmt_ns(*at_ns)),
                format!("{surviving} fresh-capable shards"),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{RequestResult, ServerReport, ShardStats};

    fn result(id: u64, arrival: f64, first: f64, finish: f64, n_tokens: usize) -> RequestResult {
        RequestResult {
            id,
            tokens: vec![1; n_tokens],
            prompt_tokens: 8,
            sim_ttft_ns: first - arrival,
            sim_total_ns: finish - arrival,
            wall_ns: 1.0,
            arrival_ns: arrival,
            sim_first_token_at_ns: first,
            sim_finish_at_ns: finish,
            deadline_ns: None,
            shed: false,
            failed: false,
        }
    }

    fn report(results: Vec<RequestResult>, clock_ns: f64, idle_ns: f64) -> ServerReport {
        let total_tokens = results.iter().map(|r| r.tokens.len()).sum();
        ServerReport {
            sim_tokens_per_s: 0.0,
            wall_tokens_per_s: 0.0,
            total_tokens,
            results,
            shards: vec![ShardStats {
                shard: 0,
                group: "unified".into(),
                role: ShardRole::Unified,
                requests: 1,
                tokens: total_tokens,
                sim_ns: clock_ns,
                wall_ns: 1.0,
                sim_clock_ns: clock_ns,
                sim_idle_ns: idle_ns,
                decode_iterations: 4,
                occupancy: 0.5,
                prefill_chunks: 2,
                chunk_stall_ns: 3.0,
                preemptions: 0,
                shed: 0,
                handoffs: 0,
                kv_transfer_ns: 0.0,
            }],
            faults: Default::default(),
        }
    }

    #[test]
    fn summary_computes_ttft_tpot_e2e() {
        // One request: arrives at 100, first token at 300, done at 700
        // with 5 tokens → ttft 200, e2e 600, tpot (700-300)/4 = 100.
        let rep = report(vec![result(0, 100.0, 300.0, 700.0, 5)], 700.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.ttft.p50, 200.0);
        assert_eq!(s.e2e.p50, 600.0);
        assert_eq!(s.tpot.p50, 100.0);
        assert_eq!(s.slo_attainment, 1.0);
        assert!((s.throughput_tokens_per_s - 5.0 / (700.0 / 1e9)).abs() < 1.0);
        assert_eq!(s.throughput_tokens_per_s, s.goodput_tokens_per_s);
        assert_eq!(s.shed_requests, 0);
        assert_eq!(s.prefill_chunks, 2);
        assert_eq!(s.chunk_stall_ns, 3.0);
    }

    #[test]
    fn goodput_excludes_missed_deadlines() {
        let mut late = result(0, 0.0, 10.0, 1000.0, 4);
        late.deadline_ns = Some(500.0);
        let on_time = result(1, 0.0, 10.0, 400.0, 4);
        let rep = report(vec![late, on_time], 1000.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.slo_attainment, 0.5);
        assert!((s.goodput_tokens_per_s - s.throughput_tokens_per_s / 2.0).abs() < 1e-6);
    }

    #[test]
    fn shed_requests_leave_latency_populations() {
        // A shed request with a garbage first-token timestamp must not
        // pollute TTFT/e2e tails; it counts in shed_requests and misses
        // its SLO.
        let mut shed = result(0, 0.0, 0.0, 50.0, 1);
        shed.shed = true;
        let ok = result(1, 0.0, 10.0, 40.0, 4);
        let mut rep = report(vec![shed, ok], 100.0, 0.0);
        rep.shards[0].shed = 1;
        rep.shards[0].preemptions = 2;
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.requests, 2);
        assert_eq!(s.shed_requests, 1);
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.ttft.p99, 10.0, "only the delivered request grades TTFT");
        assert_eq!(s.e2e.max, 40.0);
        assert_eq!(s.slo_attainment, 0.5, "a shed request always misses its SLO");
        // Goodput excludes the shed request's tokens; throughput keeps them.
        assert!(s.goodput_tokens_per_s < s.throughput_tokens_per_s);
    }

    #[test]
    fn filtered_ttft_splits_populations_by_prompt_length() {
        let mut short = result(0, 0.0, 10.0, 40.0, 2);
        short.prompt_tokens = 16;
        let mut long = result(1, 0.0, 500.0, 900.0, 2);
        long.prompt_tokens = 4096;
        let rep = report(vec![short, long], 1000.0, 0.0);
        let s = ttft_percentiles_where(&rep, |r| r.prompt_tokens <= 256);
        assert_eq!(s.p99, 10.0);
        let l = ttft_percentiles_where(&rep, |r| r.prompt_tokens > 256);
        assert_eq!(l.p99, 500.0);
        let none = ttft_percentiles_where(&rep, |_| false);
        assert_eq!(none.p99, 0.0);
    }

    #[test]
    fn single_token_requests_skip_tpot() {
        let rep = report(vec![result(0, 0.0, 10.0, 10.0, 1)], 10.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.tpot.p50, 0.0);
        assert_eq!(s.tpot.max, 0.0);
    }

    #[test]
    fn empty_report_is_benign() {
        let s = SloSummary::from_report(&report(vec![], 0.0, 0.0));
        assert_eq!(s.requests, 0);
        assert_eq!(s.slo_attainment, 1.0);
        assert_eq!(s.ttft.p99, 0.0);
        assert_eq!(s.shed_requests, 0);
    }

    #[test]
    fn group_table_aggregates_disaggregated_shards() {
        // Two prefill + two decode shards: the default utilization view is
        // one row per group, with KV-transfer and handoff totals summed.
        let mut rep = report(vec![result(0, 0.0, 10.0, 50.0, 3)], 100.0, 0.0);
        let mk = |shard: usize, group: &str, role: ShardRole, busy_idle: f64, kv: f64| {
            let mut s = rep.shards[0].clone();
            s.shard = shard;
            s.group = group.into();
            s.role = role;
            s.sim_idle_ns = busy_idle;
            s.handoffs = 2;
            s.kv_transfer_ns = kv;
            s
        };
        rep.shards = vec![
            mk(0, "prefill", ShardRole::Prefill, 0.0, 0.0),
            mk(1, "prefill", ShardRole::Prefill, 50.0, 0.0),
            mk(2, "decode", ShardRole::Decode, 0.0, 7.0),
            mk(3, "decode", ShardRole::Decode, 0.0, 5.0),
        ];
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.kv_transfer_ns, 12.0);
        assert_eq!(s.handoffs, 4, "handoffs counted once, on the sending side");
        let grouped = s.utilization_table("by group", false);
        assert_eq!(grouped.num_rows(), 2, "one row per group");
        let rendered = grouped.render();
        assert!(rendered.contains("prefill"), "{rendered}");
        assert!(rendered.contains("decode"), "{rendered}");
        // Prefill group busy = mean(100%, 50%) = 75%.
        assert!(rendered.contains("75%"), "{rendered}");
        let per_shard = s.utilization_table("by shard", true);
        assert_eq!(per_shard.num_rows(), 4, "per-shard rows behind the flag");
    }

    #[test]
    fn failed_requests_grade_availability_not_latency() {
        // A failed request (crash evacuee whose retries ran out) has a
        // degenerate timeline — it must leave the latency populations,
        // miss its SLO, and show up in the availability accounting.
        let mut failed = result(0, 0.0, 777.0, 777.0, 0);
        failed.failed = true;
        let ok = result(1, 0.0, 10.0, 40.0, 4);
        let mut rep = report(vec![failed, ok], 100.0, 0.0);
        rep.faults.failed = 1;
        rep.faults.retries = 2;
        rep.faults.crashed_shards = 1;
        rep.faults.capacity_timeline.push((50.0, "unified".into(), 1));
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.failed_requests, 1);
        assert_eq!(s.retries, 2);
        assert_eq!(s.availability, 0.5);
        assert_eq!(s.ttft.p99, 10.0, "failed requests leave the latency populations");
        assert_eq!(s.slo_attainment, 0.5, "a failed request always misses its SLO");
        assert!(s.goodput_tokens_per_s < s.throughput_tokens_per_s);
        let rendered = s.availability_table("availability").render();
        assert!(rendered.contains("50.0%"), "{rendered}");
        assert!(rendered.contains("capacity[unified]"), "{rendered}");
        assert!(rendered.contains("1 fresh-capable shards"), "{rendered}");
    }

    #[test]
    fn fault_free_summary_reports_full_availability() {
        let rep = report(vec![result(0, 100.0, 300.0, 700.0, 5)], 700.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.availability, 1.0);
        assert_eq!(s.failed_requests, 0);
        assert_eq!(s.retries + s.kv_retries + s.degrade_shed, 0);
        assert!(s.capacity_timeline.is_empty());
        // The section renders unconditionally.
        assert!(s.availability_table("availability").render().contains("100.0%"));
    }

    #[test]
    fn summary_carries_the_metrics_registry() {
        let rep = report(vec![result(0, 100.0, 300.0, 700.0, 5)], 700.0, 0.0);
        let s = SloSummary::from_report(&rep);
        assert_eq!(s.metrics.requests, 1);
        assert_eq!(s.metrics.total_tokens, 5);
        assert_eq!(s.metrics.ttft_ns.len(), 1);
        // TTFT 200 ns lands in the log2 bucket covering [128, 255].
        assert!(s.metrics.ttft_ns.max() >= 200);
        assert_eq!(s.metrics.tpot_ns.len(), 1, "5 tokens ⇒ one TPOT sample");
    }

    #[test]
    fn tables_render() {
        let rep = report(vec![result(0, 0.0, 10.0, 50.0, 3)], 100.0, 25.0);
        let s = SloSummary::from_report(&rep);
        let row = s.table_row("fcfs@100");
        assert_eq!(row.len(), SloSummary::table_headers().len());
        assert_eq!(row[0], "fcfs@100");
        let shed_col = SloSummary::table_headers().iter().position(|h| *h == "shed").unwrap();
        assert_eq!(row[shed_col], "0");
        let t = s.shard_table("util");
        assert_eq!(t.num_rows(), 1);
        assert!(t.render().contains("75%"), "{}", t.render());
    }
}
