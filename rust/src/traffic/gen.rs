//! Open-loop workload generator: materializes a [`TrafficSpec`] into a
//! deterministic stream of timed [`Request`]s, and replays recorded traces
//! from JSON.
//!
//! Open-loop means arrivals do not depend on service progress: the stream
//! is fixed up front (like real users showing up), so queueing delay under
//! overload is *observed*, not masked by a closed feedback loop.  The
//! stream is a pure function of the spec — same seed, same spec, same
//! stream, regardless of shard count, platform, or how the requests are
//! later dispatched.

use super::rng::SplitMix64;
use crate::config::json::{self, Value};
use crate::config::{ArrivalProcess, LengthDist, TrafficSpec};
use crate::coordinator::Request;
use crate::Result;

/// Vocabulary the generator draws prompt token ids from (the synthetic
/// engines treat token ids modulo their own vocab, so any bound works;
/// this one keeps prompts printable in examples).
const PROMPT_VOCAB: u64 = 200;

/// Sample one length from a distribution (≥ 1 for prompts; outputs may
/// legitimately be 0 through `Fixed(0)`).
fn sample_len(dist: &LengthDist, rng: &mut SplitMix64) -> u64 {
    match dist {
        LengthDist::Fixed(n) => *n,
        LengthDist::Uniform { lo, hi } => rng.range(*lo, (*hi).max(*lo)),
        LengthDist::LogNormal { median, sigma, cap } => {
            let v = (*median as f64) * (sigma * rng.normal()).exp();
            (v.round() as u64).clamp(1, (*cap).max(1))
        }
    }
}

/// Materialize the request stream described by `spec`: ids are 0..n in
/// arrival order, arrival times are on the simulated clock (ns), and each
/// request carries `spec.deadline_ns` past its arrival if set.
pub fn generate(spec: &TrafficSpec) -> Vec<Request> {
    debug_assert!(spec.validate().is_ok(), "invalid traffic spec: {:?}", spec.validate());
    let mut rng = SplitMix64::new(spec.seed);
    let mut out = Vec::with_capacity(spec.requests as usize);
    let mut clock_ns = 0u64;
    for id in 0..spec.requests {
        match spec.arrival {
            ArrivalProcess::Poisson { rate_per_s } => {
                clock_ns += (rng.exp(rate_per_s) * 1e9) as u64;
            }
            ArrivalProcess::Bursty { rate_per_s, burst } => {
                // A whole burst shares one arrival epoch; epochs form a
                // Poisson process at rate/burst so the mean rate holds.
                if id % burst.max(1) as u64 == 0 {
                    let epoch_rate = rate_per_s / burst.max(1) as f64;
                    clock_ns += (rng.exp(epoch_rate) * 1e9) as u64;
                }
            }
        }
        let prompt_len = sample_len(&spec.prompt, &mut rng).max(1);
        let output_len = sample_len(&spec.output, &mut rng);
        let prompt: Vec<u32> =
            (0..prompt_len).map(|_| rng.range(0, PROMPT_VOCAB - 1) as u32).collect();
        let mut req = Request::new(id, prompt, output_len as usize).at(clock_ns);
        if let Some(budget) = spec.deadline_ns {
            // Budgets spread over [0.5×, 1.5×] the configured mean (see
            // `TrafficSpec::deadline_ns`): tight-SLO and relaxed-SLO
            // requests interleave, so EDF ≠ FCFS.
            let jittered = ((budget as f64) * (0.5 + rng.next_f64())) as u64;
            req = req.with_deadline(clock_ns.saturating_add(jittered.max(1)));
        }
        out.push(req);
    }
    out
}

/// Replay a recorded trace: a JSON array of entries like
/// `{"arrival_ms": 1.5, "prompt_tokens": 512, "output_tokens": 64,
/// "deadline_ms": 250}` (deadline optional, relative to arrival).  Prompt
/// *content* is synthesized deterministically from the entry index —
/// traces record shapes and timing, not token ids.
pub fn replay_trace(src: &str) -> Result<Vec<Request>> {
    let doc = json::parse(src).map_err(anyhow::Error::from)?;
    let Value::Arr(entries) = &doc else {
        anyhow::bail!("trace must be a JSON array of request entries");
    };
    let mut out = Vec::with_capacity(entries.len());
    for (id, e) in entries.iter().enumerate() {
        let arrival_ms = e.get("arrival_ms").map_err(anyhow::Error::from)?;
        let arrival_ns = (arrival_ms.as_f64().map_err(anyhow::Error::from)? * 1e6).round() as u64;
        let prompt_len =
            (e.get("prompt_tokens").and_then(|v| v.as_u32()).map_err(anyhow::Error::from)? as u64)
                .max(1);
        let output_len =
            e.get("output_tokens").and_then(|v| v.as_u32()).map_err(anyhow::Error::from)? as usize;
        let mut rng = SplitMix64::new(0x7 * (id as u64 + 1));
        let prompt: Vec<u32> =
            (0..prompt_len).map(|_| rng.range(0, PROMPT_VOCAB - 1) as u32).collect();
        let mut req = Request::new(id as u64, prompt, output_len).at(arrival_ns);
        if let Ok(d) = e.get("deadline_ms") {
            let budget = (d.as_f64().map_err(anyhow::Error::from)? * 1e6).round() as u64;
            req = req.with_deadline(arrival_ns.saturating_add(budget));
        }
        out.push(req);
    }
    // Serving assumes arrival order; traces may be recorded unsorted.
    out.sort_by_key(|r| (r.arrival_ns, r.id));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;

    fn spec(seed: u64) -> TrafficSpec {
        TrafficSpec {
            seed,
            requests: 40,
            arrival: ArrivalProcess::Poisson { rate_per_s: 100.0 },
            prompt: LengthDist::Uniform { lo: 4, hi: 64 },
            output: LengthDist::LogNormal { median: 16, sigma: 0.5, cap: 128 },
            deadline_ns: Some(50_000_000),
        }
    }

    #[test]
    fn stream_is_deterministic_per_seed() {
        assert_eq!(generate(&spec(1)), generate(&spec(1)));
        assert_ne!(generate(&spec(1)), generate(&spec(2)));
    }

    #[test]
    fn arrivals_are_monotone_and_ids_sequential() {
        let reqs = generate(&spec(3));
        assert_eq!(reqs.len(), 40);
        for (i, w) in reqs.windows(2).enumerate() {
            assert!(w[0].arrival_ns <= w[1].arrival_ns, "at {i}");
        }
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(!r.prompt.is_empty());
            // Budgets spread over [0.5x, 1.5x] the configured 50 ms mean.
            let budget = r.deadline_ns.unwrap() - r.arrival_ns;
            assert!((25_000_000..=75_000_000).contains(&budget), "budget {budget}");
        }
        // The spread actually varies (EDF order != arrival order).
        let budgets: std::collections::BTreeSet<u64> =
            reqs.iter().map(|r| r.deadline_ns.unwrap() - r.arrival_ns).collect();
        assert!(budgets.len() > 1, "deadline budgets must not be constant");
    }

    #[test]
    fn poisson_mean_rate_is_respected() {
        let mut s = spec(5);
        s.requests = 4000;
        s.deadline_ns = None;
        let reqs = generate(&s);
        let span_s = reqs.last().unwrap().arrival_ns as f64 / 1e9;
        let rate = reqs.len() as f64 / span_s;
        assert!((rate - 100.0).abs() < 8.0, "empirical rate {rate}");
    }

    #[test]
    fn bursty_arrivals_share_epochs() {
        let s = TrafficSpec {
            seed: 9,
            requests: 64,
            arrival: ArrivalProcess::Bursty { rate_per_s: 100.0, burst: 8 },
            prompt: LengthDist::Fixed(8),
            output: LengthDist::Fixed(4),
            deadline_ns: None,
        };
        let reqs = generate(&s);
        // Requests within a burst share one arrival timestamp.
        let distinct: std::collections::BTreeSet<u64> =
            reqs.iter().map(|r| r.arrival_ns).collect();
        assert_eq!(distinct.len(), 64 / 8);
    }

    #[test]
    fn scenario_spec_generates_paper_lengths() {
        let s = TrafficSpec::for_scenario(&Scenario::CONTEXT_UNDERSTANDING, 10.0, 5, 1);
        let reqs = generate(&s);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 8192);
            assert_eq!(r.max_new_tokens, 256);
        }
    }

    #[test]
    fn lognormal_lengths_are_clamped_and_spread() {
        let s = TrafficSpec {
            seed: 77,
            requests: 300,
            arrival: ArrivalProcess::Poisson { rate_per_s: 1000.0 },
            prompt: LengthDist::LogNormal { median: 64, sigma: 1.0, cap: 256 },
            output: LengthDist::Fixed(1),
            deadline_ns: None,
        };
        let lens: Vec<usize> = generate(&s).iter().map(|r| r.prompt.len()).collect();
        assert!(lens.iter().all(|&l| (1..=256).contains(&l)));
        let distinct: std::collections::BTreeSet<usize> = lens.iter().copied().collect();
        assert!(distinct.len() > 20, "lognormal should spread: {} lengths", distinct.len());
    }

    #[test]
    fn trace_replay_parses_sorts_and_deadlines() {
        let src = r#"[
            {"arrival_ms": 3.0, "prompt_tokens": 16, "output_tokens": 4},
            {"arrival_ms": 1.0, "prompt_tokens": 8, "output_tokens": 2, "deadline_ms": 10.0}
        ]"#;
        let reqs = replay_trace(src).unwrap();
        assert_eq!(reqs.len(), 2);
        // Sorted by arrival: the 1 ms entry first.
        assert_eq!(reqs[0].arrival_ns, 1_000_000);
        assert_eq!(reqs[0].prompt.len(), 8);
        assert_eq!(reqs[0].deadline_ns, Some(11_000_000));
        assert_eq!(reqs[1].arrival_ns, 3_000_000);
        assert_eq!(reqs[1].deadline_ns, None);
    }

    #[test]
    fn trace_replay_rejects_non_arrays() {
        assert!(replay_trace("{\"arrival_ms\": 1}").is_err());
        assert!(replay_trace("[{\"prompt_tokens\": 4}]").is_err());
    }
}
