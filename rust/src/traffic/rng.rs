//! Deterministic, seed-driven PRNG for the workload generator.
//!
//! The build is offline (no `rand` crate), so the generator carries its own
//! small generator: SplitMix64 (Steele et al., "Fast splittable
//! pseudorandom number generators") seeding an xorshift-style output mix.
//! SplitMix64 passes BigCrush for this use (sampling arrival gaps and
//! length distributions) and — critically for the determinism tests — its
//! output stream is a pure function of the seed, independent of platform,
//! shard count, or call-site interleaving.

/// SplitMix64: a 64-bit state advanced by a Weyl constant, finalized with
/// an xorshift-multiply mix.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Exponential variate with the given rate (events per unit time).
    /// Used for Poisson inter-arrival gaps.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // 1 - u in (0, 1] so ln never sees zero.
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Standard normal variate (Box–Muller; one of the pair is discarded to
    /// keep the generator state a simple function of the draw count).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64(); // (0, 1]
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_inclusive_and_covering() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            let v = r.range(10, 14);
            assert!((10..=14).contains(&v));
            seen[(v - 10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values of a small range appear");
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.exp(4.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
