//! Open-loop request generation and SLO-graded serving metrics.
//!
//! RACAM's evaluation (paper §5.3/§6) prices *static* inference: one
//! request, fixed prompt/output lengths, no queueing.  This module is the
//! serving-side complement — it turns the paper's workload descriptions
//! into live request streams and grades the coordinator the way serving
//! systems are graded: tail latency and goodput under load.
//!
//! ## Mapping to the paper
//!
//! | concept here | paper anchor |
//! |---|---|
//! | [`TrafficSpec`] prompt/output length distributions | §5.3 scenarios; [`Scenario::CODE_GENERATION`] (1024 in / 4096 out) and [`Scenario::CONTEXT_UNDERSTANDING`] (8192 in / 256 out) are the `Fixed` presets via [`TrafficSpec::for_scenario`] |
//! | kernel pricing behind every admitted request | §4.4's LLM parser + automated mapping (the shared `MappingService`) |
//! | per-shard simulated clock, prefill/decode bucket costs | §6's prefill/decode latency model, applied per request instead of per scenario |
//! | arrival processes (Poisson/bursty), trace replay | serving-PIM follow-ups (Sangam, MVDRAM) evaluate under request streams with latency SLOs; the paper itself has no arrival model — this is the extension point |
//!
//! ## Pieces
//!
//! * [`rng`] — seed-driven SplitMix64; the stream is a pure function of
//!   the [`TrafficSpec`], independent of shard count or platform.
//! * [`generate`] / [`replay_trace`] — materialize a spec (or a recorded
//!   JSON trace) into timed [`Request`]s for
//!   [`Coordinator::submit`](crate::coordinator::Coordinator::submit) or a
//!   live [`Intake`](crate::coordinator::Intake).
//! * [`slo`] — TTFT/TPOT/e2e percentiles, deadline goodput, shed and
//!   preemption counts, chunk-stall time, and per-shard utilization from a
//!   finished [`ServerReport`](crate::coordinator::ServerReport).
//!
//! The `exp traffic` experiment ties it together: FCFS vs length-bucketed
//! vs EDF admission at several arrival rates on the paper's model presets;
//! `exp prefill` compares chunked vs whole-prompt prefill (and deadline
//! preemption) under a long-prompt mixed workload.
//!
//! [`TrafficSpec`]: crate::config::TrafficSpec
//! [`TrafficSpec::for_scenario`]: crate::config::TrafficSpec::for_scenario
//! [`Scenario::CODE_GENERATION`]: crate::config::Scenario::CODE_GENERATION
//! [`Scenario::CONTEXT_UNDERSTANDING`]: crate::config::Scenario::CONTEXT_UNDERSTANDING
//! [`Request`]: crate::coordinator::Request

mod gen;
pub mod rng;
pub mod slo;

pub use gen::{generate, replay_trace};
pub use rng::SplitMix64;
pub use slo::{ttft_percentiles_where, Percentiles, SloSummary};
