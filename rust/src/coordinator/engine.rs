//! Token engines: produce the next token given the running hidden state.
//!
//! `HloDecodeEngine` (behind the `pjrt` feature) runs the AOT artifact
//! `decode_step.hlo.txt` — a tiny recurrent transformer-style step with
//! baked synthetic weights, lowered from JAX (with the Pallas quantized-GEMM
//! kernel on its hot path) — via PJRT.  [`SyntheticEngine`] is a
//! deterministic stand-in for tests that must run without artifacts.

#[cfg(feature = "pjrt")]
use crate::runtime::LoadedModule;
use crate::Result;

/// The decode-step contract: consume a hidden state, emit the next hidden
/// state and a token id.
pub trait TokenEngine {
    /// Hidden-state width.
    fn hidden(&self) -> usize;
    /// Vocabulary size.
    fn vocab(&self) -> usize;
    /// One decode step: returns (next_hidden, token_id).
    fn step(&mut self, hidden: &[f32]) -> Result<(Vec<f32>, u32)>;
    /// Initial hidden state for a prompt (toy embedding of the prompt).
    fn embed_prompt(&self, prompt: &[u32]) -> Vec<f32> {
        let h = self.hidden();
        let mut x = vec![0.0f32; h];
        for (i, &tok) in prompt.iter().enumerate() {
            x[(tok as usize + i) % h] += 1.0 / (1.0 + i as f32);
        }
        x
    }

    /// Feed the sampled token back into the hidden state (the embedding
    /// lookup of a real decoder); keeps greedy generation token-dependent
    /// instead of converging to the recurrence's fixed point.
    fn feed_token(&self, hidden: &mut [f32], token: u32) {
        let h = hidden.len();
        hidden[token as usize % h] += 0.5;
        hidden[(token as usize * 7 + 3) % h] -= 0.25;
    }
}

/// PJRT-backed engine: output layout is `[next_hidden(h) ; logits(v)]`.
#[cfg(feature = "pjrt")]
pub struct HloDecodeEngine {
    module: LoadedModule,
    hidden: usize,
    vocab: usize,
}

#[cfg(feature = "pjrt")]
impl HloDecodeEngine {
    pub fn new(module: LoadedModule, hidden: usize, vocab: usize) -> Self {
        HloDecodeEngine { module, hidden, vocab }
    }
}

#[cfg(feature = "pjrt")]
impl TokenEngine for HloDecodeEngine {
    fn hidden(&self) -> usize {
        self.hidden
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&mut self, hidden: &[f32]) -> Result<(Vec<f32>, u32)> {
        anyhow::ensure!(hidden.len() == self.hidden, "hidden-state width mismatch");
        let out = self.module.run_f32(&[(hidden, &[self.hidden as i64])])?;
        anyhow::ensure!(
            out.len() == self.hidden + self.vocab,
            "decode_step returned {} values, expected {}",
            out.len(),
            self.hidden + self.vocab
        );
        let (next, logits) = out.split_at(self.hidden);
        Ok((next.to_vec(), argmax(logits)))
    }
}

/// Deterministic synthetic engine (no artifacts needed): a fixed random
/// projection implemented in Rust.
pub struct SyntheticEngine {
    hidden: usize,
    vocab: usize,
}

impl SyntheticEngine {
    pub fn new(hidden: usize, vocab: usize) -> Self {
        SyntheticEngine { hidden, vocab }
    }
}

impl TokenEngine for SyntheticEngine {
    fn hidden(&self) -> usize {
        self.hidden
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&mut self, hidden: &[f32]) -> Result<(Vec<f32>, u32)> {
        // next[i] = tanh(0.9·x[(i+1) mod h] + 0.1·x[i] + 0.01·i-dither)
        let h = self.hidden;
        let mut next = vec![0.0f32; h];
        for i in 0..h {
            next[i] = (0.9 * hidden[(i + 1) % h] + 0.1 * hidden[i] + 0.01 * ((i % 7) as f32 - 3.0))
                .tanh();
        }
        // Toy logits: strided folds of the state.
        let logits: Vec<f32> = (0..self.vocab)
            .map(|v| {
                let mut s = 0.0;
                let mut j = v % h;
                for _ in 0..4 {
                    s += next[j];
                    j = (j + 17) % h;
                }
                s
            })
            .collect();
        Ok((next, argmax(&logits)))
    }
}

/// Zero-cost token engine for scheduler-scale benchmarks: emits token 0
/// with no hidden state, so a serving run measures the *engine loop*
/// (admission, calendars, pricing, preemption) rather than toy
/// hidden-state arithmetic — the mode `exp scale` uses to time the
/// scheduler step itself, the way vLLM benches its scheduler with
/// simulated model execution.  Deterministic by construction, so the
/// oracle/calendar equivalence checks hold under it too.
pub struct NullEngine;

impl TokenEngine for NullEngine {
    fn hidden(&self) -> usize {
        0
    }

    fn vocab(&self) -> usize {
        1
    }

    fn step(&mut self, _hidden: &[f32]) -> Result<(Vec<f32>, u32)> {
        Ok((Vec::new(), 0))
    }

    fn embed_prompt(&self, _prompt: &[u32]) -> Vec<f32> {
        // The default embedding indexes modulo the hidden width; with no
        // hidden state there is nothing to embed.
        Vec::new()
    }

    fn feed_token(&self, _hidden: &mut [f32], _token: u32) {}
}

/// Greedy sampling.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, v) in logits.iter().enumerate() {
        if *v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn synthetic_engine_is_deterministic() {
        let mut a = SyntheticEngine::new(32, 64);
        let mut b = SyntheticEngine::new(32, 64);
        let x = a.embed_prompt(&[1, 2, 3]);
        let (na, ta) = a.step(&x).unwrap();
        let (nb, tb) = b.step(&x).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(na, nb);
    }

    #[test]
    fn prompt_embedding_depends_on_prompt() {
        let e = SyntheticEngine::new(16, 16);
        assert_ne!(e.embed_prompt(&[0, 1]), e.embed_prompt(&[5, 9]));
        assert_eq!(e.embed_prompt(&[3]).len(), 16);
    }

    #[test]
    fn null_engine_generates_zero_tokens_without_state() {
        let mut e = NullEngine;
        assert_eq!(e.embed_prompt(&[3, 1, 4]), Vec::<f32>::new());
        let (h, t) = e.step(&[]).unwrap();
        assert!(h.is_empty());
        assert_eq!(t, 0);
        let mut empty: [f32; 0] = [];
        e.feed_token(&mut empty, 0); // must not index into the (empty) state
    }

    #[test]
    fn state_stays_bounded() {
        let mut e = SyntheticEngine::new(24, 48);
        let mut x = e.embed_prompt(&[7, 11, 13]);
        for _ in 0..100 {
            let (nx, _) = e.step(&x).unwrap();
            x = nx;
        }
        assert!(x.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }
}
