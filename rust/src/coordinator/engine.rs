//! Token engines: produce the next token given the running hidden state.
//!
//! `HloDecodeEngine` (behind the `pjrt` feature) runs the AOT artifact
//! `decode_step.hlo.txt` — a tiny recurrent transformer-style step with
//! baked synthetic weights, lowered from JAX (with the Pallas quantized-GEMM
//! kernel on its hot path) — via PJRT.  [`SyntheticEngine`] is a
//! deterministic stand-in for tests that must run without artifacts.

#[cfg(feature = "pjrt")]
use crate::runtime::LoadedModule;
use crate::Result;

/// The decode-step contract: consume a hidden state, emit the next hidden
/// state and a token id.
pub trait TokenEngine {
    /// Hidden-state width.
    fn hidden(&self) -> usize;
    /// Vocabulary size.
    fn vocab(&self) -> usize;
    /// One decode step: returns (next_hidden, token_id).
    fn step(&mut self, hidden: &[f32]) -> Result<(Vec<f32>, u32)>;

    /// One decode step *in place*: replace `hidden` with the next hidden
    /// state (sampled token already fed back) and return the token id.
    /// The default allocates via [`TokenEngine::step`]; engines on the
    /// serving hot path override it to reuse the caller's buffer, which
    /// is what keeps the decode loop allocation-free.  Must generate the
    /// exact token/state sequence of `step` + [`TokenEngine::feed_token`].
    fn step_in_place(&mut self, hidden: &mut Vec<f32>) -> Result<u32> {
        let (mut next, token) = self.step(hidden)?;
        self.feed_token(&mut next, token);
        *hidden = next;
        Ok(token)
    }

    /// Initial hidden state for a prompt (toy embedding of the prompt).
    fn embed_prompt(&self, prompt: &[u32]) -> Vec<f32> {
        let mut x = Vec::new();
        self.embed_prompt_into(prompt, &mut x);
        x
    }

    /// [`TokenEngine::embed_prompt`] into a caller-owned buffer (cleared
    /// and refilled) — the admission path recycles retired members'
    /// hidden-state buffers through this, so a million-request run
    /// allocates a bounded pool of them instead of one per request.
    fn embed_prompt_into(&self, prompt: &[u32], out: &mut Vec<f32>) {
        let h = self.hidden();
        out.clear();
        out.resize(h, 0.0);
        for (i, &tok) in prompt.iter().enumerate() {
            out[(tok as usize + i) % h] += 1.0 / (1.0 + i as f32);
        }
    }

    /// Feed the sampled token back into the hidden state (the embedding
    /// lookup of a real decoder); keeps greedy generation token-dependent
    /// instead of converging to the recurrence's fixed point.
    fn feed_token(&self, hidden: &mut [f32], token: u32) {
        let h = hidden.len();
        hidden[token as usize % h] += 0.5;
        hidden[(token as usize * 7 + 3) % h] -= 0.25;
    }
}

/// PJRT-backed engine: output layout is `[next_hidden(h) ; logits(v)]`.
#[cfg(feature = "pjrt")]
pub struct HloDecodeEngine {
    module: LoadedModule,
    hidden: usize,
    vocab: usize,
}

#[cfg(feature = "pjrt")]
impl HloDecodeEngine {
    pub fn new(module: LoadedModule, hidden: usize, vocab: usize) -> Self {
        HloDecodeEngine { module, hidden, vocab }
    }
}

#[cfg(feature = "pjrt")]
impl TokenEngine for HloDecodeEngine {
    fn hidden(&self) -> usize {
        self.hidden
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&mut self, hidden: &[f32]) -> Result<(Vec<f32>, u32)> {
        anyhow::ensure!(hidden.len() == self.hidden, "hidden-state width mismatch");
        let out = self.module.run_f32(&[(hidden, &[self.hidden as i64])])?;
        anyhow::ensure!(
            out.len() == self.hidden + self.vocab,
            "decode_step returned {} values, expected {}",
            out.len(),
            self.hidden + self.vocab
        );
        let (next, logits) = out.split_at(self.hidden);
        Ok((next.to_vec(), argmax(logits)))
    }
}

/// Deterministic synthetic engine (no artifacts needed): a fixed random
/// projection implemented in Rust.
pub struct SyntheticEngine {
    hidden: usize,
    vocab: usize,
    /// Double buffer for [`TokenEngine::step_in_place`]: the next state is
    /// computed here and swapped with the caller's buffer, so the decode
    /// hot loop never allocates.
    scratch: Vec<f32>,
}

impl SyntheticEngine {
    pub fn new(hidden: usize, vocab: usize) -> Self {
        SyntheticEngine { hidden, vocab, scratch: Vec::new() }
    }

    /// The recurrence: fill `next` from `hidden` and return the greedy
    /// token.  `next[i] = tanh(0.9·x[(i+1) mod h] + 0.1·x[i] + dither)`,
    /// logits are strided folds of the new state, argmax with
    /// first-max-wins ties — one definition shared by `step` and
    /// `step_in_place` so the two are bit-identical by construction.
    fn advance(&self, hidden: &[f32], next: &mut Vec<f32>) -> u32 {
        let h = self.hidden;
        next.clear();
        next.resize(h, 0.0);
        for i in 0..h {
            next[i] = (0.9 * hidden[(i + 1) % h] + 0.1 * hidden[i] + 0.01 * ((i % 7) as f32 - 3.0))
                .tanh();
        }
        // Toy logits folded online (same `>` comparison as `argmax`, so
        // the first maximum wins here too).
        let mut best = 0u32;
        let mut best_s = f32::NEG_INFINITY;
        for v in 0..self.vocab {
            let mut s = 0.0;
            let mut j = v % h;
            for _ in 0..4 {
                s += next[j];
                j = (j + 17) % h;
            }
            if s > best_s {
                best_s = s;
                best = v as u32;
            }
        }
        best
    }
}

impl TokenEngine for SyntheticEngine {
    fn hidden(&self) -> usize {
        self.hidden
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&mut self, hidden: &[f32]) -> Result<(Vec<f32>, u32)> {
        let mut next = Vec::new();
        let token = self.advance(hidden, &mut next);
        Ok((next, token))
    }

    fn step_in_place(&mut self, hidden: &mut Vec<f32>) -> Result<u32> {
        let mut next = std::mem::take(&mut self.scratch);
        let token = self.advance(hidden, &mut next);
        self.feed_token(&mut next, token);
        std::mem::swap(hidden, &mut next);
        self.scratch = next;
        Ok(token)
    }
}

/// Zero-cost token engine for scheduler-scale benchmarks: emits token 0
/// with no hidden state, so a serving run measures the *engine loop*
/// (admission, calendars, pricing, preemption) rather than toy
/// hidden-state arithmetic — the mode `exp scale` uses to time the
/// scheduler step itself, the way vLLM benches its scheduler with
/// simulated model execution.  Deterministic by construction, so the
/// oracle/calendar equivalence checks hold under it too.
pub struct NullEngine;

impl TokenEngine for NullEngine {
    fn hidden(&self) -> usize {
        0
    }

    fn vocab(&self) -> usize {
        1
    }

    fn step(&mut self, _hidden: &[f32]) -> Result<(Vec<f32>, u32)> {
        Ok((Vec::new(), 0))
    }

    fn step_in_place(&mut self, hidden: &mut Vec<f32>) -> Result<u32> {
        hidden.clear();
        Ok(0)
    }

    fn embed_prompt(&self, _prompt: &[u32]) -> Vec<f32> {
        // The default embedding indexes modulo the hidden width; with no
        // hidden state there is nothing to embed.
        Vec::new()
    }

    fn embed_prompt_into(&self, _prompt: &[u32], out: &mut Vec<f32>) {
        out.clear();
    }

    fn feed_token(&self, _hidden: &mut [f32], _token: u32) {}
}

/// Greedy sampling.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, v) in logits.iter().enumerate() {
        if *v > logits[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn synthetic_engine_is_deterministic() {
        let mut a = SyntheticEngine::new(32, 64);
        let mut b = SyntheticEngine::new(32, 64);
        let x = a.embed_prompt(&[1, 2, 3]);
        let (na, ta) = a.step(&x).unwrap();
        let (nb, tb) = b.step(&x).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(na, nb);
    }

    #[test]
    fn prompt_embedding_depends_on_prompt() {
        let e = SyntheticEngine::new(16, 16);
        assert_ne!(e.embed_prompt(&[0, 1]), e.embed_prompt(&[5, 9]));
        assert_eq!(e.embed_prompt(&[3]).len(), 16);
    }

    #[test]
    fn null_engine_generates_zero_tokens_without_state() {
        let mut e = NullEngine;
        assert_eq!(e.embed_prompt(&[3, 1, 4]), Vec::<f32>::new());
        let (h, t) = e.step(&[]).unwrap();
        assert!(h.is_empty());
        assert_eq!(t, 0);
        let mut empty: [f32; 0] = [];
        e.feed_token(&mut empty, 0); // must not index into the (empty) state
    }

    #[test]
    fn step_in_place_matches_step_plus_feedback() {
        // The allocation-free path must generate the exact sequence of
        // the allocating reference path (the serving engines' tokens and
        // hidden states are part of the bit-equivalence contract).
        let mut a = SyntheticEngine::new(32, 64);
        let mut b = SyntheticEngine::new(32, 64);
        let mut xa = a.embed_prompt(&[1, 2, 3]);
        let mut xb = b.embed_prompt(&[1, 2, 3]);
        for _ in 0..50 {
            let ta = a.step_in_place(&mut xa).unwrap();
            let (mut next, tb) = b.step(&xb).unwrap();
            b.feed_token(&mut next, tb);
            xb = next;
            assert_eq!(ta, tb);
            assert_eq!(xa, xb);
        }
    }

    #[test]
    fn embed_prompt_into_reuses_and_matches() {
        let e = SyntheticEngine::new(16, 16);
        let mut buf = vec![9.0; 64]; // stale content must be overwritten
        e.embed_prompt_into(&[3, 1, 4], &mut buf);
        assert_eq!(buf, e.embed_prompt(&[3, 1, 4]));
        let n = NullEngine;
        n.embed_prompt_into(&[1], &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn state_stays_bounded() {
        let mut e = SyntheticEngine::new(24, 48);
        let mut x = e.embed_prompt(&[7, 11, 13]);
        for _ in 0..100 {
            let (nx, _) = e.step(&x).unwrap();
            x = nx;
        }
        assert!(x.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }
}
