//! Serving coordinator: the Python-free request path.
//!
//! A [`Server`] owns (a) a token engine — either the AOT-compiled HLO
//! decode step executing through PJRT, or a synthetic engine for tests —
//! and (b) the RACAM timing pipeline (mapping engine over the paper's
//! hardware config), and drives batched requests token by token, reporting
//! real generated tokens alongside simulated RACAM/H100/Proteus latencies.

mod batcher;
mod engine;
mod server;

pub use batcher::{Batch, FcfsBatcher};
pub use engine::{HloDecodeEngine, SyntheticEngine, TokenEngine};
pub use server::{Request, RequestResult, Server, ServerReport};
