//! Serving coordinator: the Python-free request path.
//!
//! A [`Server`] is one worker shard: it owns (a) a token engine — either
//! the AOT-compiled HLO decode step executing through PJRT (behind the
//! `pjrt` feature), or a synthetic engine for tests — and (b) a handle on
//! the RACAM timing pipeline (the shared
//! [`MappingService`](crate::mapping::MappingService) over the paper's
//! hardware config), and drives batched requests token by token, reporting
//! real generated tokens alongside simulated RACAM latencies.
//!
//! [`Coordinator`] runs N such shards concurrently against one shared
//! mapping service — the multi-worker serving configuration — with a
//! pluggable admission [`Scheduler`] (FCFS today) and a merged
//! [`ServerReport`] carrying per-shard utilization ([`ShardStats`]).

mod batcher;
mod engine;
mod multi;
mod scheduler;
mod server;

pub use batcher::{Batch, FcfsBatcher};
#[cfg(feature = "pjrt")]
pub use engine::HloDecodeEngine;
pub use engine::{SyntheticEngine, TokenEngine};
pub use multi::Coordinator;
pub use scheduler::Scheduler;
pub use server::{Request, RequestResult, Server, ServerReport, ShardStats};
