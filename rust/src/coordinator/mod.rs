//! Serving coordinator: the Python-free request path.
//!
//! A [`Server`] is one worker shard: it owns (a) a token engine — either
//! the AOT-compiled HLO decode step executing through PJRT (behind the
//! `pjrt` feature), or a synthetic engine for tests — and (b) a handle on
//! the RACAM timing pipeline (the shared
//! [`MappingService`](crate::mapping::MappingService) over the paper's
//! hardware config), and drives batched requests token by token, reporting
//! real generated tokens alongside simulated RACAM latencies on a
//! per-shard simulated clock.
//!
//! [`Coordinator`] runs N such shards concurrently — the multi-worker
//! serving configuration — with per-shard DRAM channel partitioning, a
//! pluggable admission [`Scheduler`] ([`FcfsBatcher`], [`LengthBucketed`],
//! [`EdfScheduler`]), live mid-run request [`Intake`], and a merged
//! [`ServerReport`] carrying per-shard utilization ([`ShardStats`]).
//!
//! Clusters are declared, not hand-wired: a JSON-loadable
//! [`ClusterSpec`](crate::config::ClusterSpec) names shard *groups* (count,
//! [`ShardRole`](crate::config::ShardRole), scheduler, policy, channel
//! share) and [`ClusterBuilder`] assembles the coordinator from it.  Roles
//! enable prefill/decode **disaggregation**: `Prefill` shards run prompts
//! only and hand each finished request ([`Handoff`]) to a `Decode` shard
//! over a simulated KV-transfer link, whose cost lands on the decode
//! shard's clock as [`ShardStats::kv_transfer_ns`].  The pre-redesign
//! constructors survive as thin deprecated wrappers over the builder.
//!
//! Each shard's serving loop is governed by a
//! [`ServingPolicy`](crate::config::ServingPolicy): prefill advances in
//! bounded chunks that interleave with decode iterations (unset =
//! whole-prompt, the paper-faithful schedule), and schedulers may preempt
//! running requests through [`Scheduler::should_preempt`] ([`Preemption`];
//! EDF sheds past-deadline work).  Two interchangeable loop
//! implementations run that schedule
//! ([`EngineKind`](crate::config::EngineKind)): the default
//! **event-calendar engine** fast-forwards uniform lockstep-decode
//! stretches to the next material event (arrival release, membership
//! change, pricing-bucket edge, preemption horizon, fault onset) with
//! indexed heaps in place of per-iteration scans, and the
//! **per-iteration oracle** is the reference it must match bit-for-bit on
//! every simulated quantity (see `docs/serving.md`).  Open-loop request
//! streams and SLO-graded summaries over these reports live in
//! [`crate::traffic`].
//!
//! Clusters can also be run under a **deterministic fault schedule**
//! ([`FaultSpec`](crate::config::FaultSpec), installed with
//! [`Coordinator::set_faults`]): shard crashes with role-aware failover,
//! brownouts, KV-link outages/degradation, and per-group DRAM channel
//! loss, with the recovery accounting reported in [`FaultTally`] — see
//! `docs/robustness.md`.

mod batcher;
mod cluster;
mod engine;
mod multi;
mod scheduler;
mod server;

pub use batcher::{ctx_bucket, Batch, FcfsBatcher, BUCKET_TOKENS};
pub use cluster::{ClusterBuilder, ClusterCoordinator};
#[cfg(feature = "pjrt")]
pub use engine::HloDecodeEngine;
pub use engine::{NullEngine, SyntheticEngine, TokenEngine};
pub use multi::{Coordinator, Intake};
pub use scheduler::{EdfScheduler, LengthBucketed, Preemption, Scheduler};
pub use server::{
    BatchPoll, FaultTally, Handoff, Request, RequestResult, Server, ServerReport, ShardRun,
    ShardStats,
};
