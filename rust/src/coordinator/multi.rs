//! Multi-worker serving coordinator: N worker shards, each running the
//! continuous-batched decode loop of [`Server`], all pricing against a
//! shared [`MappingService`].
//!
//! The coordinator is the ROADMAP "sharding" step: requests are dispatched
//! deterministically to the least-loaded shard, shards run concurrently on
//! a fixed work-stealing worker pool ([`crate::runtime::executor`],
//! configured by [`HostExecutor`]), and the per-shard reports merge into a
//! single [`ServerReport`] with per-shard utilization.  Thread count is a
//! host-side knob only: each shard's simulation is single-threaded between
//! coordinator barriers and reports merge in shard order, so simulated
//! results are bit-identical across `--threads` settings.  Because the mapping cache
//! is shared, a kernel shape that appears on every shard is searched once
//! system-wide — the first shard to ask runs the (parallel) search, the
//! rest wait on the per-shape once-cell and reuse it.
//!
//! ## Construction
//!
//! Coordinators are assembled by [`ClusterBuilder`] from a declarative
//! [`ClusterSpec`]; the constructors on this type are thin deprecated
//! wrappers kept for the transition.  Shards may carry *roles*
//! ([`crate::config::ShardRole`]): dedicated prefill shards hand finished
//! prompts to dedicated decode shards over a simulated KV-transfer link
//! (see [`Coordinator::run_to_completion`]), while unified shards serve
//! the whole lifecycle exactly as before.
//!
//! ## Per-shard DRAM channels
//!
//! The builder partitions the DRAM channels of the hardware config across
//! shards ([`crate::config::partition_channels`]): a shard owning 3 of 8
//! channels prices its kernels against a 3-channel device, so per-shard
//! bandwidth is honest and N shards aggregate to exactly the full system.
//! Shards with equal channel counts share one mapping service; distinct
//! counts get their own (a mapping priced for 3 channels is not valid for
//! 2).  When a partition is impossible (more shards than channels) or the
//! caller supplies explicit services, every shard shares the full config —
//! the pre-partitioning behavior.
//!
//! ## Async admission
//!
//! [`Coordinator::intake`] opens a live channel per shard and returns an
//! [`Intake`] handle that can be moved to another thread and used while
//! `run_to_completion` is executing; shards admit these requests mid-run
//! and the run finishes when the handle (and any clones of its senders)
//! is dropped.

use super::cluster::ClusterBuilder;
use super::engine::TokenEngine;
use super::scheduler::Scheduler;
use super::server::{BatchPoll, Handoff, Request, Server, ServerReport, ShardRun};
use super::FcfsBatcher;
use crate::config::{
    partition_channels, ClusterSpec, HostExecutor, HwConfig, LlmSpec, ServingPolicy, ShardRole,
};
use crate::mapping::MappingService;
use crate::runtime::executor::{self, Poll, WorkerStats};
use crate::telemetry::{Event, EventKind, NopRecorder, Recorder};
use crate::Result;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

/// N-shard serving coordinator (see module docs).
///
/// The third parameter is the telemetry sink type shared by every shard
/// and the KV link ([`NopRecorder`] by default — zero-cost, see
/// [`crate::telemetry`]).  A recorded cluster is built with
/// [`ClusterBuilder::build_recorded`]; after a run, per-shard event
/// streams are read back through [`Coordinator::shard_recorder`] and the
/// KV-link stream through [`Coordinator::link_recorder`].
pub struct Coordinator<E: TokenEngine, S: Scheduler = FcfsBatcher, R: Recorder = NopRecorder> {
    shards: Vec<Server<E, S, R>>,
    /// One mapping-service handle per shard (clones share caches; shards
    /// with different channel partitions hold distinct services).
    services: Vec<MappingService>,
    /// The LLM whose kernels the shards price (also sizes the KV cache a
    /// disaggregated handoff ships across the KV link).
    spec: LlmSpec,
    /// Per-shard lifecycle roles (all `Unified` outside a
    /// [`ClusterBuilder`]-built cluster).
    roles: Vec<ShardRole>,
    /// KV-transfer link bandwidth between prefill and decode shards, GB/s.
    kv_link_gbps: f64,
    /// How shard serving loops map onto host worker threads (see
    /// [`HostExecutor`]); host-side only — never changes simulated results.
    executor: HostExecutor,
    /// Telemetry sink for the shared KV link (the coordinator owns the
    /// link, so its wire/release events live here, not on a shard).
    link_recorder: R,
    /// Per-worker host-side counters of the most recent
    /// [`Coordinator::run_to_completion`], indexed by pool worker id
    /// (waves of a disaggregated run accumulate per worker).
    worker_stats: Vec<WorkerStats>,
}

/// Live submission handle for a running coordinator: requests round-robin
/// across shard intake channels.  Drop it (and any clones of the senders)
/// to let `run_to_completion` finish.
pub struct Intake {
    senders: Vec<mpsc::Sender<Request>>,
    next: usize,
}

impl Intake {
    /// Submit to the next shard round-robin; returns `false` if every
    /// intake channel has closed (the coordinator stopped serving).
    pub fn submit(&mut self, mut req: Request) -> bool {
        for _ in 0..self.senders.len() {
            let shard = self.next;
            self.next = (self.next + 1) % self.senders.len();
            // A failed send hands the request back — no clone needed.
            match self.senders[shard].send(req) {
                Ok(()) => return true,
                Err(mpsc::SendError(r)) => req = r,
            }
        }
        false
    }

    /// Submit to a specific shard.
    pub fn submit_to(&self, shard: usize, req: Request) -> bool {
        self.senders[shard].send(req).is_ok()
    }

    pub fn num_shards(&self) -> usize {
        self.senders.len()
    }
}

impl<E: TokenEngine + Send> Coordinator<E, FcfsBatcher> {
    /// Build an FCFS coordinator over `hw` with per-shard DRAM channel
    /// partitioning (see module docs).  `engine_factory` is called once
    /// per shard (shard index passed in) — token engines hold mutable
    /// generation state, so each worker needs its own.
    #[deprecated(note = "declare a `config::ClusterSpec` and use `ClusterBuilder` instead")]
    pub fn new(
        hw: &HwConfig,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::new(ClusterSpec::unified(n_shards, max_batch), hw, spec)
            .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
            .build_with(engine_factory, |_| FcfsBatcher::new(max_batch))
    }

    /// Build a coordinator over an existing (possibly pre-warmed, possibly
    /// externally shared) mapping service; every shard prices against the
    /// full config behind it.
    #[deprecated(note = "declare a `config::ClusterSpec` and use \
                         `ClusterBuilder::with_spec_and_services` instead")]
    pub fn with_service(
        service: MappingService,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(n_shards, max_batch),
            spec,
            vec![service; n_shards],
        )
        .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
        .build_with(engine_factory, |_| FcfsBatcher::new(max_batch))
    }
}

impl<E: TokenEngine + Send, S: Scheduler, R: Recorder + Send> Coordinator<E, S, R> {
    /// One mapping service per shard under channel partitioning: shards
    /// with equal channel counts share a service, so a shape priced on one
    /// is reused by its peers.  Falls back to one full-config service for
    /// all shards when no partition exists.
    pub fn partitioned_services(hw: &HwConfig, n_shards: usize) -> Vec<MappingService> {
        match partition_channels(hw, n_shards) {
            Some(parts) => {
                let mut by_channels: HashMap<u32, MappingService> = HashMap::new();
                parts
                    .iter()
                    .map(|p| {
                        by_channels
                            .entry(p.dram.channels)
                            .or_insert_with(|| MappingService::for_config(p))
                            .clone()
                    })
                    .collect()
            }
            None => {
                let shared = MappingService::for_config(hw);
                vec![shared; n_shards]
            }
        }
    }

    /// Assemble a coordinator from fully configured shards (the
    /// [`ClusterBuilder`] back end; roles/groups/policies — and, for a
    /// recorded cluster, the per-shard recorders — are already set on
    /// each [`Server`]).  `link_recorder` receives the KV-link events of
    /// [`Coordinator::dispatch_handoffs`].
    pub(crate) fn from_parts(
        shards: Vec<Server<E, S, R>>,
        services: Vec<MappingService>,
        spec: LlmSpec,
        kv_link_gbps: f64,
        link_recorder: R,
    ) -> Self {
        assert!(!shards.is_empty(), "a coordinator needs at least one shard");
        let roles = shards.iter().map(|s| s.role()).collect();
        Coordinator {
            shards,
            services,
            spec,
            roles,
            kv_link_gbps,
            executor: HostExecutor::default(),
            link_recorder,
            worker_stats: Vec::new(),
        }
    }
}

impl<E: TokenEngine + Send, S: Scheduler> Coordinator<E, S> {
    /// Fully general constructor: a shared service plus per-shard
    /// scheduler construction (compare admission policies under identical
    /// pricing).
    #[deprecated(note = "declare a `config::ClusterSpec` and use \
                         `ClusterBuilder::with_spec_and_services` + `build_with` instead")]
    pub fn with_schedulers(
        service: MappingService,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
        scheduler_factory: impl FnMut(usize) -> S,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(n_shards, max_batch),
            spec,
            vec![service; n_shards],
        )
        .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
        .build_with(engine_factory, scheduler_factory)
    }

    /// One (possibly shared) mapping service per shard — the old seam for
    /// channel partitioning with reusable caches.
    #[deprecated(note = "declare a `config::ClusterSpec` and use \
                         `ClusterBuilder::with_spec_and_services` + `build_with` instead")]
    pub fn with_shard_services(
        services: Vec<MappingService>,
        spec: LlmSpec,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
        scheduler_factory: impl FnMut(usize) -> S,
    ) -> Self {
        assert!(!services.is_empty(), "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(services.len(), max_batch),
            spec,
            services,
        )
        .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
        .build_with(engine_factory, scheduler_factory)
    }
}

impl<E: TokenEngine + Send, S: Scheduler, R: Recorder + Send> Coordinator<E, S, R> {
    /// Configure the host executor (worker-thread count, stealing
    /// granularity).  Simulated results are identical for every setting;
    /// only host wall time changes.
    pub fn set_executor(&mut self, executor: HostExecutor) {
        self.executor = executor;
    }

    /// Builder-style [`Coordinator::set_executor`].
    pub fn with_executor(mut self, executor: HostExecutor) -> Self {
        self.set_executor(executor);
        self
    }

    /// Pin the worker pool to `threads` threads (see [`HostExecutor`]).
    pub fn set_threads(&mut self, threads: usize) {
        self.executor.threads = Some(threads);
    }

    /// The active host-executor configuration.
    pub fn executor(&self) -> HostExecutor {
        self.executor
    }

    /// Apply one [`ServingPolicy`] (chunked prefill, preemption) to every
    /// shard.  The default policy reproduces the whole-prefill schedule
    /// bit-for-bit; see `config::ServingPolicy`.
    pub fn set_policy(&mut self, policy: ServingPolicy) {
        for shard in &mut self.shards {
            shard.set_policy(policy);
        }
    }

    /// Builder-style [`Coordinator::set_policy`].
    pub fn with_policy(mut self, policy: ServingPolicy) -> Self {
        self.set_policy(policy);
        self
    }

    /// The serving policy of the shards (uniform across the coordinator).
    pub fn policy(&self) -> ServingPolicy {
        self.shards[0].policy()
    }

    /// The shard-0 mapping service (cache counters, warm-start/persist).
    /// With [`Coordinator::with_service`] this is *the* shared service;
    /// under channel partitioning shards may hold siblings — see
    /// [`Coordinator::services`].
    pub fn service(&self) -> &MappingService {
        &self.services[0]
    }

    /// Per-shard mapping-service handles.
    pub fn services(&self) -> &[MappingService] {
        &self.services
    }

    /// Cluster-wide mapping-cache counters `(hits, misses, warm_loads)`,
    /// counting every *distinct* service once (equal-channel shards alias
    /// one service; naive per-shard summation would multiply its counters
    /// by the alias count).
    pub fn mapping_counters(&self) -> (u64, u64, u64) {
        let mut distinct: Vec<&MappingService> = Vec::new();
        for svc in &self.services {
            if !distinct.iter().any(|d| d.shares_cache_with(svc)) {
                distinct.push(svc);
            }
        }
        distinct.iter().fold((0, 0, 0), |(h, m, w), s| {
            (h + s.hits(), m + s.misses(), w + s.warm_loads())
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Requests waiting for admission across all shards (queued or
    /// arriving later on the simulated clock).
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.pending()).sum()
    }

    /// Per-shard lifecycle roles.
    pub fn roles(&self) -> &[ShardRole] {
        &self.roles
    }

    /// Whether this cluster splits prefill and decode across shard groups.
    pub fn is_disaggregated(&self) -> bool {
        self.roles.iter().any(|r| matches!(r, ShardRole::Decode))
    }

    /// Dispatch a request to the least-loaded *fresh-prompt-eligible*
    /// shard (lowest index wins ties), which is deterministic for a given
    /// submission order.  Decode-only shards are skipped: they receive
    /// work exclusively through the prefill→decode KV handoff, never a
    /// fresh prompt.
    pub fn submit(&mut self, req: Request) {
        let shard = (0..self.shards.len())
            .filter(|&i| self.roles[i].accepts_fresh_prompts())
            .min_by_key(|&i| self.shards[i].pending())
            .expect("a cluster needs at least one prefill-capable shard"); // detcheck: allow(panic-hygiene) -- ClusterSpec::validate rejects clusters with no prefill-capable shard, and submit has no error channel
        self.shards[shard].submit(req);
    }

    /// Open live intake channels on every fresh-prompt-eligible shard and
    /// return the combined handle (decode-only shards are skipped — see
    /// [`Coordinator::submit`]).  Call before `run_to_completion`; the run
    /// blocks until the handle's senders are all dropped.
    pub fn intake(&mut self) -> Intake {
        Intake {
            senders: self
                .shards
                .iter_mut()
                .filter(|s| s.role().accepts_fresh_prompts())
                .map(|s| s.open_intake())
                .collect(),
            next: 0,
        }
    }

    /// Run the shards matching `pred` on the work-stealing worker pool:
    /// each shard becomes one resumable [`ShardRun`] task polled in
    /// batches of `exec.batch_rounds` scheduling rounds, so `threads`
    /// workers drive any number of shards (idle shards cost nothing, and a
    /// lagging shard is stolen by whichever worker frees up first).
    ///
    /// Reports come back **indexed by shard order**, not completion order
    /// — merging is deterministic however the workers interleave, and each
    /// shard's simulation is single-threaded between coordinator barriers,
    /// so results are bit-identical across every thread count.
    fn run_shards(
        exec: HostExecutor,
        shards: &mut [Server<E, S, R>],
        pred: impl Fn(ShardRole) -> bool,
    ) -> (Vec<Result<ServerReport>>, Vec<WorkerStats>) {
        let batch_rounds = exec.batch_rounds.max(1);
        let tasks: Vec<executor::Task<'_, Result<ServerReport>>> = shards
            .iter_mut()
            .filter(|s| pred(s.role()))
            .map(|shard| {
                let mut run = Some(ShardRun::new(shard));
                Box::new(move || {
                    // The executor retires a task at its first `Done`, so
                    // `run` is present on every poll; report a caller bug
                    // as a task error instead of panicking on a worker.
                    let Some(r) = run.as_mut() else {
                        return Poll::Done(Err(anyhow::anyhow!(
                            "shard task polled after completion"
                        )));
                    };
                    match r.poll(batch_rounds) {
                        Ok(BatchPoll::Progressed) => Poll::Pending,
                        Ok(BatchPoll::WouldBlock) => Poll::Blocked,
                        Ok(BatchPoll::Finished) => match run.take() {
                            Some(done) => Poll::Done(Ok(done.finish())),
                            None => Poll::Done(Err(anyhow::anyhow!(
                                "shard run consumed before finish"
                            ))),
                        },
                        Err(e) => Poll::Done(Err(e)),
                    }
                }) as executor::Task<'_, Result<ServerReport>>
            })
            .collect();
        if tasks.is_empty() {
            return (Vec::new(), Vec::new());
        }
        let threads = executor::resolve_threads(exec.threads).min(tasks.len());
        executor::run_tasks_with_stats(threads, tasks)
    }

    /// Move every finished prefill to a decode shard, pricing the KV-cache
    /// transfer over the cluster's link.  Handoffs are dispatched in
    /// (finish-time, id) order round-robin across decode shards, so the
    /// assignment is deterministic.
    ///
    /// The link is **one shared resource**: transfers serialize FIFO in
    /// prefill-finish order at `kv_link_gbps`, so a handoff finishing
    /// while the link is busy queues behind the in-flight transfer — the
    /// charged `kv_transfer_ns` is queueing + wire time, and concurrent
    /// finishes cannot extract more than the declared bandwidth.
    fn dispatch_handoffs(&mut self) {
        let decode_ids: Vec<usize> = (0..self.shards.len())
            .filter(|&i| matches!(self.roles[i], ShardRole::Decode))
            .collect();
        let mut handoffs: Vec<Handoff> = Vec::new();
        for shard in &mut self.shards {
            if matches!(shard.role(), ShardRole::Prefill) {
                handoffs.extend(shard.take_handoffs());
            }
        }
        handoffs.sort_by(|a, b| {
            a.prefill_finish_at_ns
                .total_cmp(&b.prefill_finish_at_ns)
                .then(a.req.id.cmp(&b.req.id))
        });
        let mut link_free_at_ns = 0.0f64;
        for (n, h) in handoffs.into_iter().enumerate() {
            let shard = decode_ids[n % decode_ids.len()];
            let kv_bytes = self.spec.kv_cache_bytes(h.req.prompt.len() as u64);
            // 1 GB/s ≡ 1 byte/ns, so the wire time is simply bytes / GB/s.
            let wire_ns = kv_bytes as f64 / self.kv_link_gbps;
            let start_ns = h.prefill_finish_at_ns.max(link_free_at_ns);
            link_free_at_ns = start_ns + wire_ns;
            let transfer_ns = link_free_at_ns - h.prefill_finish_at_ns;
            // The link track: wire occupancy, then the release onto the
            // chosen decode shard.  `start_ns = max(finish, link_free)`
            // is non-decreasing over the FIFO-sorted handoffs, so the
            // track's timestamps are monotonic by construction.
            self.link_recorder.record(Event::span(
                EventKind::KvWire,
                start_ns,
                wire_ns,
                h.req.id,
                kv_bytes as f64,
            ));
            self.link_recorder.record(Event::instant(
                EventKind::DecodeRelease,
                link_free_at_ns,
                h.req.id,
                shard as f64,
            ));
            self.shards[shard].submit_handoff(h, transfer_ns);
        }
    }

    /// Run every shard's serving loop to completion on the work-stealing
    /// worker pool ([`Coordinator::set_executor`]) and merge the reports.
    /// Each shard's simulation is single-threaded between the coordinator
    /// barriers below and reports merge in shard order, so the merged
    /// output is bit-identical for every thread count and interleaving.
    ///
    /// A unified cluster runs all shards in one concurrent wave (the
    /// pre-disaggregation behavior, bit-for-bit).  A disaggregated cluster
    /// runs in two deterministic waves: prefill (+ any unified) shards
    /// first, then the finished prompts cross the KV link and the decode
    /// shards drain them — arrival timestamps carry the pipeline timing,
    /// so no wall-clock race can change the simulated result.
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        #[allow(clippy::disallowed_methods)]
        let wall_start = Instant::now(); // detcheck: allow(wall-clock) -- the single per-run wall timer of a cluster run; feeds wall_ns only, never simulated results
        let exec = self.executor;
        self.worker_stats.clear();
        let reports = if !self.is_disaggregated() {
            let (reports, stats) = Self::run_shards(exec, &mut self.shards, |_| true);
            self.absorb_worker_stats(&stats);
            reports
        } else {
            let (mut first, stats) =
                Self::run_shards(exec, &mut self.shards, |r| r.accepts_fresh_prompts());
            self.absorb_worker_stats(&stats);
            self.dispatch_handoffs();
            let (second, stats) = Self::run_shards(exec, &mut self.shards, |r| {
                matches!(r, ShardRole::Decode)
            });
            self.absorb_worker_stats(&stats);
            first.extend(second);
            first
        };
        let mut merged = Vec::with_capacity(reports.len());
        for r in reports {
            merged.push(r?);
        }
        Ok(ServerReport::merge(merged, wall_start.elapsed().as_nanos() as f64))
    }

    /// Fold one wave's per-worker counters into the run's accumulator
    /// (worker *w* of every wave lands in row *w* — the pool is rebuilt
    /// per wave, but row `w` always describes "the w-th worker thread").
    fn absorb_worker_stats(&mut self, stats: &[WorkerStats]) {
        if self.worker_stats.len() < stats.len() {
            self.worker_stats.resize(stats.len(), WorkerStats::default());
        }
        for (acc, s) in self.worker_stats.iter_mut().zip(stats) {
            acc.absorb(s);
        }
    }

    /// Per-worker host-side counters of the most recent
    /// [`Coordinator::run_to_completion`] (empty before the first run).
    pub fn worker_stats(&self) -> &[WorkerStats] {
        &self.worker_stats
    }

    /// The KV-link telemetry sink (wire spans + decode releases of a
    /// disaggregated run; empty events on a unified cluster).
    pub fn link_recorder(&self) -> &R {
        &self.link_recorder
    }

    /// Shard `i`'s telemetry sink (its simulated event stream after a
    /// recorded run).
    pub fn shard_recorder(&self, shard: usize) -> &R {
        self.shards[shard].recorder()
    }
}

#[cfg(test)]
mod tests {
    // The deprecated constructors stay under test: they are the
    // bit-for-bit oracle the ClusterBuilder equivalence tests compare
    // against, and they must keep working until they are removed.
    #![allow(deprecated)]
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;
    use crate::coordinator::scheduler::EdfScheduler;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn coordinator(n_shards: usize, max_batch: usize) -> Coordinator<SyntheticEngine> {
        Coordinator::new(&racam_paper(), tiny_spec(), n_shards, max_batch, |_| {
            SyntheticEngine::new(64, 128)
        })
    }

    fn submit_all(c: &mut Coordinator<SyntheticEngine>, n: u64, tokens: usize) {
        for id in 0..n {
            c.submit(Request::new(id, vec![id as u32 % 7, 3, 9], tokens));
        }
    }

    #[test]
    fn completes_all_requests_across_shards() {
        let mut c = coordinator(3, 2);
        submit_all(&mut c, 7, 5);
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 7);
        assert_eq!(report.total_tokens, 35);
        assert_eq!(report.shards.len(), 3);
        // Least-loaded dispatch spreads the work: every shard served some.
        assert!(report.shards.iter().all(|s| s.requests > 0));
        assert_eq!(report.shards.iter().map(|s| s.tokens).sum::<usize>(), 35);
        // Results are id-sorted after the merge.
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn shard_count_does_not_change_generation() {
        let run = |shards: usize| {
            let mut c = coordinator(shards, 2);
            submit_all(&mut c, 6, 8);
            c.run_to_completion()
                .unwrap()
                .results
                .into_iter()
                .map(|r| (r.id, r.tokens))
                .collect::<Vec<_>>()
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(4));
    }

    #[test]
    fn shards_share_one_mapping_cache() {
        // Acceptance: a shape repeated across shards misses exactly once.
        let service = MappingService::for_config(&racam_paper());
        let mut c = Coordinator::with_service(service.clone(), tiny_spec(), 3, 2, |_| {
            SyntheticEngine::new(64, 128)
        });
        // Identical prompt lengths everywhere → identical prefill + decode
        // shapes on every shard.
        for id in 0..6 {
            c.submit(Request::new(id, vec![1, 2, 3], 4));
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 6);
        // Every cached shape was searched exactly once system-wide.
        assert_eq!(c.service().misses(), c.service().cache_len() as u64);
        // And the other shards did hit the shared cache.
        assert!(c.service().hits() > 0);
    }

    #[test]
    fn single_shard_coordinator_matches_plain_server() {
        use crate::coordinator::Server;
        use crate::workloads::RacamSystem;

        let mut c = coordinator(1, 2);
        submit_all(&mut c, 3, 6);
        let merged = c.run_to_completion().unwrap();

        let mut s = Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
        );
        for id in 0..3 {
            s.submit(Request::new(id, vec![id as u32 % 7, 3, 9], 6));
        }
        let plain = s.run_to_completion().unwrap();
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&merged), tok(&plain));
    }

    #[test]
    fn channel_partition_prices_shards_against_their_own_share() {
        // 3 shards over 8 channels → [3, 3, 2]: shards 0 and 1 share one
        // mapping service, shard 2 holds its own (distinct hardware).
        let c = coordinator(3, 2);
        let svcs = c.services();
        assert_eq!(svcs.len(), 3);
        assert_eq!(svcs[0].hw().hw.dram.channels, 3);
        assert_eq!(svcs[1].hw().hw.dram.channels, 3);
        assert_eq!(svcs[2].hw().hw.dram.channels, 2);
        let agg: u64 = svcs.iter().map(|s| s.hw().hw.capacity_bytes()).sum();
        assert_eq!(agg, racam_paper().capacity_bytes());
    }

    #[test]
    fn partitioned_shards_never_price_below_the_full_device() {
        // Honest per-shard bandwidth: the intrinsic service cost of the
        // same request on a 2-channel shard can never undercut the full
        // 8-channel device (fewer resources ⇒ no faster mapping exists —
        // the 8-channel search space contains every 2-channel candidate's
        // performance point or better).
        let costs = |shards: usize| {
            let mut c = coordinator(shards, 1);
            submit_all(&mut c, 4, 4);
            let rep = c.run_to_completion().unwrap();
            rep.results.iter().map(|r| (r.id, r.sim_total_ns)).collect::<Vec<_>>()
        };
        let full = costs(1);
        let quartered = costs(4);
        for ((id, f), (id2, q)) in full.iter().zip(&quartered) {
            assert_eq!(id, id2);
            assert!(
                *q >= f * 0.999,
                "req {id}: 2-channel shard priced {q} below full device {f}"
            );
        }
    }

    #[test]
    fn async_admission_completes_requests_submitted_after_run_start() {
        // Acceptance: a request submitted after the run starts completes
        // and is reflected in the merged report.
        let mut c = coordinator(2, 2);
        submit_all(&mut c, 4, 6);
        let mut intake = c.intake();
        #[allow(clippy::disallowed_methods)] // test harness thread
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(25));
            assert!(intake.submit(Request::new(100, vec![5, 4, 3], 6)));
            assert!(intake.submit(Request::new(101, vec![2, 2], 3)));
            // intake drops here, closing the channels.
        });
        let report = c.run_to_completion().unwrap();
        submitter.join().unwrap();
        assert_eq!(report.results.len(), 6);
        let late: Vec<u64> =
            report.results.iter().filter(|r| r.id >= 100).map(|r| r.id).collect();
        assert_eq!(late, vec![100, 101]);
        assert_eq!(report.total_tokens, 4 * 6 + 6 + 3);
        // The late requests actually generated tokens.
        assert!(report.results.iter().find(|r| r.id == 100).unwrap().tokens.len() == 6);
    }

    #[test]
    fn intake_reports_closed_channels() {
        let mut c = coordinator(1, 1);
        let mut intake = c.intake();
        // Replacing the intake drops the old receiver.
        let _tx2 = c.intake();
        assert!(!intake.submit(Request::new(0, vec![1], 1)));
    }

    #[test]
    fn policy_threads_through_every_shard() {
        use crate::config::ServingPolicy;

        // Chunked prefill through the coordinator: same tokens as the
        // default whole-prefill schedule, and the merged report carries
        // per-shard chunk counts.
        let run = |policy: ServingPolicy| {
            let mut c = coordinator(2, 2).with_policy(policy);
            for id in 0..4 {
                c.submit(Request::new(id, vec![id as u32; 600], 3));
            }
            c.run_to_completion().unwrap()
        };
        let whole = run(ServingPolicy::whole_prefill());
        let chunked = run(ServingPolicy::chunked(256));
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&whole), tok(&chunked));
        let chunks = |rep: &ServerReport| rep.shards.iter().map(|s| s.prefill_chunks).sum::<usize>();
        // 600-token prompts: 1 step each whole, 3 chunks each at 256.
        assert_eq!(chunks(&whole), 4);
        assert_eq!(chunks(&chunked), 12);
    }

    #[test]
    fn coordinator_merges_shed_counts_across_shards() {
        use crate::config::ServingPolicy;
        use crate::coordinator::scheduler::EdfScheduler;

        let service = MappingService::for_config(&racam_paper());
        let mut c: Coordinator<SyntheticEngine, EdfScheduler> = Coordinator::with_schedulers(
            service,
            tiny_spec(),
            2,
            1,
            |_| SyntheticEngine::new(64, 128),
            |_| EdfScheduler::new(),
        )
        .with_policy(ServingPolicy::whole_prefill().with_preemption());
        assert!(c.policy().preempt);
        // Two of the four requests carry deadlines that expire almost
        // immediately; wherever least-loaded dispatch lands them, they are
        // shed and the merged report must account for all of them.
        for shard in 0..2u64 {
            c.submit(Request::new(shard * 2, vec![1; 32], 48).with_deadline(u64::MAX));
            c.submit(Request::new(shard * 2 + 1, vec![2; 32], 48).with_deadline(1));
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 4);
        let shed_total: usize = report.shards.iter().map(|s| s.shed).sum();
        assert_eq!(shed_total, 2);
        assert_eq!(report.results.iter().filter(|r| r.shed).count(), 2);
    }

    #[test]
    fn coordinator_with_custom_scheduler_serves_all() {
        let service = MappingService::for_config(&racam_paper());
        let mut c: Coordinator<SyntheticEngine, EdfScheduler> = Coordinator::with_schedulers(
            service,
            tiny_spec(),
            2,
            2,
            |_| SyntheticEngine::new(64, 128),
            |_| EdfScheduler::new(),
        );
        for id in 0..5 {
            c.submit(Request::new(id, vec![1, 2], 3).with_deadline(1_000_000 * (5 - id)));
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.total_tokens, 15);
    }
}
