//! Multi-worker serving coordinator: N worker shards, each running the
//! continuous-batched decode loop of [`Server`], all pricing against a
//! shared [`MappingService`].
//!
//! The coordinator is the ROADMAP "sharding" step: requests are dispatched
//! deterministically to the least-loaded shard, shards run concurrently on
//! a fixed work-stealing worker pool ([`crate::runtime::executor`],
//! configured by [`HostExecutor`]), and the per-shard reports merge into a
//! single [`ServerReport`] with per-shard utilization.  Thread count is a
//! host-side knob only: each shard's simulation is single-threaded between
//! coordinator barriers and reports merge in shard order, so simulated
//! results are bit-identical across `--threads` settings.  Because the mapping cache
//! is shared, a kernel shape that appears on every shard is searched once
//! system-wide — the first shard to ask runs the (parallel) search, the
//! rest wait on the per-shape once-cell and reuse it.
//!
//! ## Construction
//!
//! Coordinators are assembled by [`ClusterBuilder`] from a declarative
//! [`ClusterSpec`]; the constructors on this type are thin deprecated
//! wrappers kept for the transition.  Shards may carry *roles*
//! ([`crate::config::ShardRole`]): dedicated prefill shards hand finished
//! prompts to dedicated decode shards over a simulated KV-transfer link
//! (see [`Coordinator::run_to_completion`]), while unified shards serve
//! the whole lifecycle exactly as before.
//!
//! ## Per-shard DRAM channels
//!
//! The builder partitions the DRAM channels of the hardware config across
//! shards ([`crate::config::partition_channels`]): a shard owning 3 of 8
//! channels prices its kernels against a 3-channel device, so per-shard
//! bandwidth is honest and N shards aggregate to exactly the full system.
//! Shards with equal channel counts share one mapping service; distinct
//! counts get their own (a mapping priced for 3 channels is not valid for
//! 2).  When a partition is impossible (more shards than channels) or the
//! caller supplies explicit services, every shard shares the full config —
//! the pre-partitioning behavior.
//!
//! ## Async admission
//!
//! [`Coordinator::intake`] opens a live channel per shard and returns an
//! [`Intake`] handle that can be moved to another thread and used while
//! `run_to_completion` is executing; shards admit these requests mid-run
//! and the run finishes when the handle (and any clones of its senders)
//! is dropped.

use super::cluster::ClusterBuilder;
use super::engine::TokenEngine;
use super::scheduler::Scheduler;
use super::server::{
    BatchPoll, FaultTally, Handoff, Request, RequestResult, Server, ServerReport, ShardRun,
};
use super::FcfsBatcher;
use crate::config::{
    partition_channels, ClusterSpec, FaultEvent, FaultSpec, HostExecutor, HwConfig, LlmSpec,
    RecoveryPolicy, ServingPolicy, ShardRole,
};
use crate::mapping::MappingService;
use crate::runtime::executor::{self, Poll, WorkerStats};
use crate::telemetry::{Event, EventKind, NopRecorder, Recorder};
use crate::workloads::RacamSystem;
use crate::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc;
use std::time::Instant;

/// N-shard serving coordinator (see module docs).
///
/// The third parameter is the telemetry sink type shared by every shard
/// and the KV link ([`NopRecorder`] by default — zero-cost, see
/// [`crate::telemetry`]).  A recorded cluster is built with
/// [`ClusterBuilder::build_recorded`]; after a run, per-shard event
/// streams are read back through [`Coordinator::shard_recorder`] and the
/// KV-link stream through [`Coordinator::link_recorder`].
pub struct Coordinator<E: TokenEngine, S: Scheduler = FcfsBatcher, R: Recorder = NopRecorder> {
    shards: Vec<Server<E, S, R>>,
    /// One mapping-service handle per shard (clones share caches; shards
    /// with different channel partitions hold distinct services).
    services: Vec<MappingService>,
    /// The LLM whose kernels the shards price (also sizes the KV cache a
    /// disaggregated handoff ships across the KV link).
    spec: LlmSpec,
    /// Per-shard lifecycle roles (all `Unified` outside a
    /// [`ClusterBuilder`]-built cluster).
    roles: Vec<ShardRole>,
    /// KV-transfer link bandwidth between prefill and decode shards, GB/s.
    kv_link_gbps: f64,
    /// How shard serving loops map onto host worker threads (see
    /// [`HostExecutor`]); host-side only — never changes simulated results.
    executor: HostExecutor,
    /// Telemetry sink for the shared KV link (the coordinator owns the
    /// link, so its wire/release events live here, not on a shard).
    link_recorder: R,
    /// Per-worker host-side counters of the most recent
    /// [`Coordinator::run_to_completion`], indexed by pool worker id
    /// (waves of a disaggregated run accumulate per worker).
    worker_stats: Vec<WorkerStats>,
    /// True once a non-empty [`FaultSpec`] is installed — gates the
    /// recovery loop so a fault-free run takes today's exact code path.
    faults_armed: bool,
    /// Recovery policy of the installed fault spec (retry budget, KV
    /// re-transfer backoff, degradation-controller ceiling).
    recovery: RecoveryPolicy,
    /// Declared KV-link outage windows `(start_ns, end_ns)`.
    link_outages: Vec<(f64, f64)>,
    /// Declared KV-link bandwidth-degradation windows
    /// `(start_ns, end_ns, factor)`, factor in `(0, 1]`.
    link_degrades: Vec<(f64, f64, f64)>,
    /// When the shared KV link next frees up, ns.  Persists across the
    /// waves of one run (recovery re-dispatch reuses the same link) and
    /// resets at the start of each run.
    link_free_at_ns: f64,
    /// Fault/recovery accounting of the current run.
    tally: FaultTally,
    /// Prefilled requests stranded by a dead decode tier `(request,
    /// stranded-at ns)`, awaiting the recovery loop.
    orphans: Vec<(Request, f64)>,
}

/// Live submission handle for a running coordinator: requests round-robin
/// across shard intake channels.  Drop it (and any clones of the senders)
/// to let `run_to_completion` finish.
pub struct Intake {
    senders: Vec<mpsc::Sender<Request>>,
    next: usize,
}

impl Intake {
    /// Submit to the next shard round-robin; returns `false` if every
    /// intake channel has closed (the coordinator stopped serving).
    pub fn submit(&mut self, mut req: Request) -> bool {
        for _ in 0..self.senders.len() {
            let shard = self.next;
            self.next = (self.next + 1) % self.senders.len();
            // A failed send hands the request back — no clone needed.
            match self.senders[shard].send(req) {
                Ok(()) => return true,
                Err(mpsc::SendError(r)) => req = r,
            }
        }
        false
    }

    /// Submit to a specific shard.
    pub fn submit_to(&self, shard: usize, req: Request) -> bool {
        self.senders[shard].send(req).is_ok()
    }

    pub fn num_shards(&self) -> usize {
        self.senders.len()
    }
}

impl<E: TokenEngine + Send> Coordinator<E, FcfsBatcher> {
    /// Build an FCFS coordinator over `hw` with per-shard DRAM channel
    /// partitioning (see module docs).  `engine_factory` is called once
    /// per shard (shard index passed in) — token engines hold mutable
    /// generation state, so each worker needs its own.
    #[deprecated(note = "declare a `config::ClusterSpec` and use `ClusterBuilder` instead")]
    pub fn new(
        hw: &HwConfig,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::new(ClusterSpec::unified(n_shards, max_batch), hw, spec)
            .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
            .build_with(engine_factory, |_| FcfsBatcher::new(max_batch))
    }

    /// Build a coordinator over an existing (possibly pre-warmed, possibly
    /// externally shared) mapping service; every shard prices against the
    /// full config behind it.
    #[deprecated(note = "declare a `config::ClusterSpec` and use \
                         `ClusterBuilder::with_spec_and_services` instead")]
    pub fn with_service(
        service: MappingService,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(n_shards, max_batch),
            spec,
            vec![service; n_shards],
        )
        .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
        .build_with(engine_factory, |_| FcfsBatcher::new(max_batch))
    }
}

impl<E: TokenEngine + Send, S: Scheduler, R: Recorder + Send> Coordinator<E, S, R> {
    /// One mapping service per shard under channel partitioning: shards
    /// with equal channel counts share a service, so a shape priced on one
    /// is reused by its peers.  Falls back to one full-config service for
    /// all shards when no partition exists.
    pub fn partitioned_services(hw: &HwConfig, n_shards: usize) -> Vec<MappingService> {
        match partition_channels(hw, n_shards) {
            Some(parts) => {
                let mut by_channels: HashMap<u32, MappingService> = HashMap::new();
                parts
                    .iter()
                    .map(|p| {
                        by_channels
                            .entry(p.dram.channels)
                            .or_insert_with(|| MappingService::for_config(p))
                            .clone()
                    })
                    .collect()
            }
            None => {
                let shared = MappingService::for_config(hw);
                vec![shared; n_shards]
            }
        }
    }

    /// Assemble a coordinator from fully configured shards (the
    /// [`ClusterBuilder`] back end; roles/groups/policies — and, for a
    /// recorded cluster, the per-shard recorders — are already set on
    /// each [`Server`]).  `link_recorder` receives the KV-link events of
    /// [`Coordinator::dispatch_handoffs`].
    pub(crate) fn from_parts(
        shards: Vec<Server<E, S, R>>,
        services: Vec<MappingService>,
        spec: LlmSpec,
        kv_link_gbps: f64,
        link_recorder: R,
    ) -> Self {
        assert!(!shards.is_empty(), "a coordinator needs at least one shard");
        let roles = shards.iter().map(|s| s.role()).collect();
        Coordinator {
            shards,
            services,
            spec,
            roles,
            kv_link_gbps,
            executor: HostExecutor::default(),
            link_recorder,
            worker_stats: Vec::new(),
            faults_armed: false,
            recovery: RecoveryPolicy::default(),
            link_outages: Vec::new(),
            link_degrades: Vec::new(),
            link_free_at_ns: 0.0,
            tally: FaultTally::default(),
            orphans: Vec::new(),
        }
    }
}

impl<E: TokenEngine + Send, S: Scheduler> Coordinator<E, S> {
    /// Fully general constructor: a shared service plus per-shard
    /// scheduler construction (compare admission policies under identical
    /// pricing).
    #[deprecated(note = "declare a `config::ClusterSpec` and use \
                         `ClusterBuilder::with_spec_and_services` + `build_with` instead")]
    pub fn with_schedulers(
        service: MappingService,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
        scheduler_factory: impl FnMut(usize) -> S,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(n_shards, max_batch),
            spec,
            vec![service; n_shards],
        )
        .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
        .build_with(engine_factory, scheduler_factory)
    }

    /// One (possibly shared) mapping service per shard — the old seam for
    /// channel partitioning with reusable caches.
    #[deprecated(note = "declare a `config::ClusterSpec` and use \
                         `ClusterBuilder::with_spec_and_services` + `build_with` instead")]
    pub fn with_shard_services(
        services: Vec<MappingService>,
        spec: LlmSpec,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
        scheduler_factory: impl FnMut(usize) -> S,
    ) -> Self {
        assert!(!services.is_empty(), "a coordinator needs at least one shard");
        assert!(max_batch >= 1, "max_batch must be at least 1");
        ClusterBuilder::with_spec_and_services(
            ClusterSpec::unified(services.len(), max_batch),
            spec,
            services,
        )
        .expect("a unified spec is always valid") // detcheck: allow(panic-hygiene) -- deprecated compatibility shim: a unified spec built from validated scalars cannot fail validation
        .build_with(engine_factory, scheduler_factory)
    }
}

impl<E: TokenEngine + Send, S: Scheduler, R: Recorder + Send> Coordinator<E, S, R> {
    /// Configure the host executor (worker-thread count, stealing
    /// granularity).  Simulated results are identical for every setting;
    /// only host wall time changes.
    pub fn set_executor(&mut self, executor: HostExecutor) {
        self.executor = executor;
    }

    /// Builder-style [`Coordinator::set_executor`].
    pub fn with_executor(mut self, executor: HostExecutor) -> Self {
        self.set_executor(executor);
        self
    }

    /// Pin the worker pool to `threads` threads (see [`HostExecutor`]).
    pub fn set_threads(&mut self, threads: usize) {
        self.executor.threads = Some(threads);
    }

    /// The active host-executor configuration.
    pub fn executor(&self) -> HostExecutor {
        self.executor
    }

    /// Apply one [`ServingPolicy`] (chunked prefill, preemption) to every
    /// shard.  The default policy reproduces the whole-prefill schedule
    /// bit-for-bit; see `config::ServingPolicy`.
    pub fn set_policy(&mut self, policy: ServingPolicy) {
        for shard in &mut self.shards {
            shard.set_policy(policy);
        }
    }

    /// Builder-style [`Coordinator::set_policy`].
    pub fn with_policy(mut self, policy: ServingPolicy) -> Self {
        self.set_policy(policy);
        self
    }

    /// The serving policy of the shards (uniform across the coordinator).
    pub fn policy(&self) -> ServingPolicy {
        self.shards[0].policy()
    }

    /// The shard-0 mapping service (cache counters, warm-start/persist).
    /// With [`Coordinator::with_service`] this is *the* shared service;
    /// under channel partitioning shards may hold siblings — see
    /// [`Coordinator::services`].
    pub fn service(&self) -> &MappingService {
        &self.services[0]
    }

    /// Per-shard mapping-service handles.
    pub fn services(&self) -> &[MappingService] {
        &self.services
    }

    /// Cluster-wide mapping-cache counters `(hits, misses, warm_loads)`,
    /// counting every *distinct* service once (equal-channel shards alias
    /// one service; naive per-shard summation would multiply its counters
    /// by the alias count).
    pub fn mapping_counters(&self) -> (u64, u64, u64) {
        let mut distinct: Vec<&MappingService> = Vec::new();
        for svc in &self.services {
            if !distinct.iter().any(|d| d.shares_cache_with(svc)) {
                distinct.push(svc);
            }
        }
        distinct.iter().fold((0, 0, 0), |(h, m, w), s| {
            (h + s.hits(), m + s.misses(), w + s.warm_loads())
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Requests waiting for admission across all shards (queued or
    /// arriving later on the simulated clock).
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.pending()).sum()
    }

    /// Per-shard lifecycle roles.
    pub fn roles(&self) -> &[ShardRole] {
        &self.roles
    }

    /// Whether this cluster splits prefill and decode across shard groups.
    pub fn is_disaggregated(&self) -> bool {
        self.roles.iter().any(|r| matches!(r, ShardRole::Decode))
    }

    /// Install a fault schedule (see `docs/robustness.md`): validates the
    /// spec, arms each shard event on its shard, builds the reduced-channel
    /// pricing runtimes for channel-loss groups, and keeps the link windows
    /// and recovery policy for the coordinator's own recovery loop.  An
    /// empty spec leaves the coordinator on the fault-free path,
    /// bit-for-bit.
    pub fn set_faults(&mut self, spec: &FaultSpec) -> Result<()> {
        spec.validate()?;
        if spec.is_empty() {
            return Ok(());
        }
        self.faults_armed = true;
        self.recovery = spec.recovery;
        for ev in &spec.events {
            match ev {
                FaultEvent::ShardCrash { shard, at_ns } => {
                    self.fault_shard(*shard)?.fault_crash_at(*at_ns);
                }
                FaultEvent::Brownout { shard, start_ns, end_ns, slowdown } => {
                    self.fault_shard(*shard)?.fault_brownout(*start_ns, *end_ns, *slowdown);
                }
                FaultEvent::LinkOutage { start_ns, end_ns } => {
                    self.link_outages.push((*start_ns, *end_ns));
                }
                FaultEvent::LinkDegrade { start_ns, end_ns, factor } => {
                    self.link_degrades.push((*start_ns, *end_ns, *factor));
                }
                FaultEvent::ChannelLoss { group, at_ns, channels_lost } => {
                    self.install_channel_loss(group, *at_ns, *channels_lost)?;
                }
            }
        }
        self.link_outages.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        Ok(())
    }

    /// Bounds-checked shard lookup for fault distribution.
    fn fault_shard(&mut self, shard: usize) -> Result<&mut Server<E, S, R>> {
        let n = self.shards.len();
        match self.shards.get_mut(shard) {
            Some(s) => Ok(s),
            None => anyhow::bail!("fault spec names shard {shard}, but the cluster has {n} shards"),
        }
    }

    /// Arm a channel-loss fault on every shard of `group`: each member's
    /// hardware loses `lost` DRAM channels at `at_ns` and re-prices its
    /// kernels through a [`MappingService`] built for the reduced device.
    /// Members with equal surviving channel counts share one derated
    /// service (the same aliasing rule as channel partitioning).
    fn install_channel_loss(&mut self, group: &str, at_ns: f64, lost: u32) -> Result<()> {
        let members: Vec<usize> = (0..self.shards.len())
            .filter(|&i| self.shards[i].group_label() == group)
            .collect();
        if members.is_empty() {
            anyhow::bail!("channel-loss fault names unknown shard group '{group}'");
        }
        let mut derated: Vec<(u32, MappingService)> = Vec::new();
        for i in members {
            let hw = self.services[i].hw().hw.clone();
            if hw.dram.channels <= lost {
                anyhow::bail!(
                    "channel-loss of {lost} channels would leave shard {i} (group '{group}') \
                     with none of its {} channels",
                    hw.dram.channels
                );
            }
            let left = hw.dram.channels - lost;
            let svc = match derated.iter().find(|(c, _)| *c == left) {
                Some((_, svc)) => svc.clone(),
                None => {
                    let mut reduced = hw;
                    reduced.dram.channels = left;
                    let svc = MappingService::for_config(&reduced);
                    derated.push((left, svc.clone()));
                    svc
                }
            };
            self.shards[i].fault_derate(at_ns, RacamSystem::with_service(svc), left);
        }
        Ok(())
    }

    /// Dispatch a request to the least-loaded *fresh-prompt-eligible*
    /// shard (lowest index wins ties), which is deterministic for a given
    /// submission order.  Decode-only shards are skipped: they receive
    /// work exclusively through the prefill→decode KV handoff, never a
    /// fresh prompt.
    pub fn submit(&mut self, req: Request) {
        let shard = (0..self.shards.len())
            .filter(|&i| self.roles[i].accepts_fresh_prompts())
            .min_by_key(|&i| self.shards[i].pending())
            .expect("a cluster needs at least one prefill-capable shard"); // detcheck: allow(panic-hygiene) -- ClusterSpec::validate rejects clusters with no prefill-capable shard, and submit has no error channel
        self.shards[shard].submit(req);
    }

    /// Open live intake channels on every fresh-prompt-eligible shard and
    /// return the combined handle (decode-only shards are skipped — see
    /// [`Coordinator::submit`]).  Call before `run_to_completion`; the run
    /// blocks until the handle's senders are all dropped.
    pub fn intake(&mut self) -> Intake {
        Intake {
            senders: self
                .shards
                .iter_mut()
                .filter(|s| s.role().accepts_fresh_prompts())
                .map(|s| s.open_intake())
                .collect(),
            next: 0,
        }
    }

    /// Run the shards matching `pred` on the work-stealing worker pool:
    /// each shard becomes one resumable [`ShardRun`] task polled in
    /// batches of `exec.batch_rounds` scheduling rounds, so `threads`
    /// workers drive any number of shards (idle shards cost nothing, and a
    /// lagging shard is stolen by whichever worker frees up first).
    ///
    /// Reports come back **indexed by shard order**, not completion order
    /// — merging is deterministic however the workers interleave, and each
    /// shard's simulation is single-threaded between coordinator barriers,
    /// so results are bit-identical across every thread count.
    fn run_shards(
        exec: HostExecutor,
        shards: &mut [Server<E, S, R>],
        pred: impl Fn(ShardRole) -> bool,
    ) -> (Vec<Result<ServerReport>>, Vec<WorkerStats>) {
        let batch_rounds = exec.batch_rounds.max(1);
        let tasks: Vec<executor::Task<'_, Result<ServerReport>>> = shards
            .iter_mut()
            .filter(|s| pred(s.role()))
            .map(|shard| {
                let mut run = Some(ShardRun::new(shard));
                Box::new(move || {
                    // The executor retires a task at its first `Done`, so
                    // `run` is present on every poll; report a caller bug
                    // as a task error instead of panicking on a worker.
                    let Some(r) = run.as_mut() else {
                        return Poll::Done(Err(anyhow::anyhow!(
                            "shard task polled after completion"
                        )));
                    };
                    match r.poll(batch_rounds) {
                        Ok(BatchPoll::Progressed) => Poll::Pending,
                        Ok(BatchPoll::WouldBlock) => Poll::Blocked,
                        Ok(BatchPoll::Finished) => match run.take() {
                            Some(done) => Poll::Done(Ok(done.finish())),
                            None => Poll::Done(Err(anyhow::anyhow!(
                                "shard run consumed before finish"
                            ))),
                        },
                        Err(e) => Poll::Done(Err(e)),
                    }
                }) as executor::Task<'_, Result<ServerReport>>
            })
            .collect();
        if tasks.is_empty() {
            return (Vec::new(), Vec::new());
        }
        let threads = executor::resolve_threads(exec.threads).min(tasks.len());
        executor::run_tasks_with_stats(threads, tasks)
    }

    /// Move every finished prefill to a decode shard, pricing the KV-cache
    /// transfer over the cluster's link.  Handoffs are dispatched in
    /// (finish-time, id) order round-robin across decode shards, so the
    /// assignment is deterministic.
    ///
    /// The link is **one shared resource**: transfers serialize FIFO in
    /// prefill-finish order at `kv_link_gbps`, so a handoff finishing
    /// while the link is busy queues behind the in-flight transfer — the
    /// charged `kv_transfer_ns` is queueing + wire time, and concurrent
    /// finishes cannot extract more than the declared bandwidth.
    ///
    /// Under a fault schedule, crashed decode shards drop out of the
    /// round-robin, outage windows delay or interrupt transfers (see
    /// [`Coordinator::price_link_transfer`]), and degradation windows
    /// stretch the wire time.  If no decode shard survives, the prefilled
    /// requests are stranded as orphans for the recovery loop.
    fn dispatch_handoffs(&mut self) {
        let decode_ids: Vec<usize> = (0..self.shards.len())
            .filter(|&i| {
                matches!(self.roles[i], ShardRole::Decode) && !self.shards[i].fault_crashed()
            })
            .collect();
        let mut handoffs: Vec<Handoff> = Vec::new();
        for shard in &mut self.shards {
            if matches!(shard.role(), ShardRole::Prefill) {
                handoffs.extend(shard.take_handoffs());
            }
        }
        handoffs.sort_by(|a, b| {
            a.prefill_finish_at_ns
                .total_cmp(&b.prefill_finish_at_ns)
                .then(a.req.id.cmp(&b.req.id))
        });
        for (n, h) in handoffs.into_iter().enumerate() {
            if decode_ids.is_empty() {
                // No surviving decode shard: the prefilled request joins
                // the recovery queue, stranded at its prefill finish.
                let at = h.prefill_finish_at_ns;
                self.orphans.push((h.req, at));
                continue;
            }
            let shard = decode_ids[n % decode_ids.len()];
            let kv_bytes = self.spec.kv_cache_bytes(h.req.prompt.len() as u64);
            // 1 GB/s ≡ 1 byte/ns, so the wire time is simply bytes / GB/s.
            let wire_base_ns = kv_bytes as f64 / self.kv_link_gbps;
            let (start_ns, wire_ns) =
                self.price_link_transfer(h.prefill_finish_at_ns, wire_base_ns, h.req.id);
            self.link_free_at_ns = start_ns + wire_ns;
            let transfer_ns = self.link_free_at_ns - h.prefill_finish_at_ns;
            // The link track: wire occupancy, then the release onto the
            // chosen decode shard.  `start_ns = max(finish, link_free)`
            // is non-decreasing over the FIFO-sorted handoffs, so the
            // track's timestamps are monotonic by construction.
            self.link_recorder.record(Event::span(
                EventKind::KvWire,
                start_ns,
                wire_ns,
                h.req.id,
                kv_bytes as f64,
            ));
            self.link_recorder.record(Event::instant(
                EventKind::DecodeRelease,
                self.link_free_at_ns,
                h.req.id,
                shard as f64,
            ));
            self.shards[shard].submit_handoff(h, transfer_ns);
        }
    }

    /// Price one KV transfer over the (possibly faulted) link: queue
    /// behind the in-flight transfer, wait out any outage in progress
    /// (pure queueing), stretch the wire time by the active degradation
    /// factor, and — when an outage opens mid-flight — lose the attempt
    /// and re-send after the outage with capped deterministic exponential
    /// backoff in simulated time ([`RecoveryPolicy::backoff_ns`]).
    /// Returns `(start_ns, wire_ns)` of the successful attempt.  With no
    /// link faults declared this reduces to exactly the fault-free
    /// arithmetic: `start = max(ready, link_free)`, `wire = base`.
    fn price_link_transfer(&mut self, ready_ns: f64, wire_base_ns: f64, req_id: u64) -> (f64, f64) {
        let mut start = ready_ns.max(self.link_free_at_ns);
        let mut attempt = 0u32;
        loop {
            // An outage already in progress delays the start; windows may
            // chain, so re-scan until the start settles.
            loop {
                let mut moved = false;
                for &(o_start, o_end) in &self.link_outages {
                    if o_start <= start && start < o_end {
                        start = o_end;
                        moved = true;
                    }
                }
                if !moved {
                    break;
                }
            }
            let mut wire = wire_base_ns;
            let factor = self.link_degrade_factor(start);
            if factor != 1.0 {
                wire /= factor;
            }
            // The earliest outage opening strictly inside the transfer
            // interrupts it.
            let mut cut: Option<(f64, f64)> = None;
            for &(o_start, o_end) in &self.link_outages {
                if o_start > start && o_start < start + wire {
                    let earlier = match cut {
                        Some((c, _)) => o_start < c,
                        None => true,
                    };
                    if earlier {
                        cut = Some((o_start, o_end));
                    }
                }
            }
            let Some((cut_at, cut_end)) = cut else {
                return (start, wire);
            };
            attempt += 1;
            self.tally.kv_retries += 1;
            self.link_recorder.record(Event::instant(
                EventKind::KvRetry,
                cut_at,
                req_id,
                attempt as f64,
            ));
            // Each retry strictly passes one more outage window, so the
            // loop terminates after at most `link_outages.len()` retries.
            start = cut_end + self.recovery.backoff_ns(attempt);
        }
    }

    /// Combined bandwidth-degradation factor at `at_ns` (1.0 = full
    /// bandwidth; overlapping windows compose multiplicatively in
    /// declaration order, each factor in `(0, 1]`).
    fn link_degrade_factor(&self, at_ns: f64) -> f64 {
        let mut f = 1.0f64;
        for &(d_start, d_end, factor) in &self.link_degrades {
            if d_start <= at_ns && at_ns < d_end {
                f *= factor;
            }
        }
        f
    }

    /// Run every shard's serving loop to completion on the work-stealing
    /// worker pool ([`Coordinator::set_executor`]) and merge the reports.
    /// Each shard's simulation is single-threaded between the coordinator
    /// barriers below and reports merge in shard order, so the merged
    /// output is bit-identical for every thread count and interleaving.
    ///
    /// A unified cluster runs all shards in one concurrent wave (the
    /// pre-disaggregation behavior, bit-for-bit).  A disaggregated cluster
    /// runs in two deterministic waves: prefill (+ any unified) shards
    /// first, then the finished prompts cross the KV link and the decode
    /// shards drain them — arrival timestamps carry the pipeline timing,
    /// so no wall-clock race can change the simulated result.
    /// Under a fault schedule ([`Coordinator::set_faults`]) the waves
    /// repeat as a **recovery loop**: after each full wave, requests
    /// evacuated from crashed shards are re-dispatched onto surviving
    /// fresh-prompt-eligible shards (bounded by the policy's retry
    /// budget), shed by the degradation controller when surviving
    /// capacity falls below the utilization ceiling, or terminated
    /// `failed`; surviving shards then resume from their own clocks.
    /// Everything is driven by simulated time, so the merged report stays
    /// bit-identical across engines and worker-pool sizes.
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        #[allow(clippy::disallowed_methods)]
        let wall_start = Instant::now(); // detcheck: allow(wall-clock) -- the single per-run wall timer of a cluster run; feeds wall_ns only, never simulated results
        let exec = self.executor;
        self.worker_stats.clear();
        self.link_free_at_ns = 0.0;
        self.tally = FaultTally::default();
        let mut acc: Vec<Option<ServerReport>> = Vec::new();
        acc.resize_with(self.shards.len(), || None);
        self.run_wave(exec, &mut acc)?;
        let (extra, retry_ledger) = if self.faults_armed {
            self.recovery_rounds(exec, &mut acc)?
        } else {
            (Vec::new(), BTreeMap::new())
        };
        let merged: Vec<ServerReport> = acc.into_iter().flatten().collect();
        let mut report = ServerReport::merge(merged, wall_start.elapsed().as_nanos() as f64);
        if self.faults_armed {
            // Terminal (failed / degrade-shed) results join the merged
            // population, and retried requests report their original
            // arrival — end-to-end latency spans the crash they survived.
            report.results.extend(extra);
            report.results.sort_by_key(|r| r.id);
            for r in &mut report.results {
                if let Some(&(_, original_arrival_ns)) = retry_ledger.get(&r.id) {
                    r.arrival_ns = original_arrival_ns;
                }
            }
            report.faults = std::mem::take(&mut self.tally);
        }
        Ok(report)
    }

    /// One full scheduling wave over the cluster: a unified cluster runs
    /// every shard once; a disaggregated cluster runs the fresh-prompt
    /// wave, crosses the KV link, then drains the decode wave.  Reports
    /// fold into `acc` per shard index (a recovery continuation wave
    /// re-runs shards, so a shard may accumulate several partial reports).
    fn run_wave(&mut self, exec: HostExecutor, acc: &mut [Option<ServerReport>]) -> Result<()> {
        if !self.is_disaggregated() {
            self.run_wave_into(exec, acc, |_| true)
        } else {
            self.run_wave_into(exec, acc, |r| r.accepts_fresh_prompts())?;
            self.dispatch_handoffs();
            self.run_wave_into(exec, acc, |r| matches!(r, ShardRole::Decode))
        }
    }

    /// Run the shards matching `pred` and fold their reports into `acc`.
    /// `run_shards` returns reports in shard order of the filtered set, so
    /// the k-th report belongs to the k-th shard satisfying `pred`.
    fn run_wave_into(
        &mut self,
        exec: HostExecutor,
        acc: &mut [Option<ServerReport>],
        pred: impl Fn(ShardRole) -> bool,
    ) -> Result<()> {
        let ids: Vec<usize> = (0..self.shards.len()).filter(|&i| pred(self.roles[i])).collect();
        let (reports, stats) = Self::run_shards(exec, &mut self.shards, pred);
        self.absorb_worker_stats(&stats);
        for (&i, r) in ids.iter().zip(reports) {
            let r = r?;
            match &mut acc[i] {
                Some(prev) => absorb_report(prev, r),
                None => acc[i] = Some(r),
            }
        }
        Ok(())
    }

    /// Drain crash evacuations until the cluster settles: collect the
    /// evacuees of newly crashed shards (plus any orphaned handoffs),
    /// re-dispatch / degrade-shed / fail each one, and run continuation
    /// waves for whatever was re-dispatched.  Returns the synthesized
    /// terminal results and the per-request retry ledger
    /// `id → (evacuations, original arrival ns)`.
    fn recovery_rounds(
        &mut self,
        exec: HostExecutor,
        acc: &mut [Option<ServerReport>],
    ) -> Result<(Vec<RequestResult>, BTreeMap<u64, (u32, f64)>)> {
        let n = self.shards.len();
        let mut extra: Vec<RequestResult> = Vec::new();
        let mut ledger: BTreeMap<u64, (u32, f64)> = BTreeMap::new();
        let mut counted = vec![false; n];
        let mut rr = 0usize;
        loop {
            // Evacuees in (orphans, shard index) order, id-sorted within a
            // shard — a deterministic re-dispatch order.
            let mut evac: Vec<(Request, f64)> = std::mem::take(&mut self.orphans);
            for i in 0..n {
                if !self.shards[i].fault_crashed() {
                    continue;
                }
                let detect = self.shards[i].crash_detected_at();
                if !counted[i] {
                    counted[i] = true;
                    self.tally.crashed_shards += 1;
                    let surviving = (0..n)
                        .filter(|&j| {
                            self.roles[j].accepts_fresh_prompts()
                                && !self.shards[j].fault_crashed()
                        })
                        .count();
                    self.tally.capacity_timeline.push((
                        detect,
                        self.shards[i].group_label().to_string(),
                        surviving,
                    ));
                }
                let mut reqs = self.shards[i].take_evacuated();
                reqs.sort_by_key(|r| r.id);
                evac.extend(reqs.into_iter().map(|r| (r, detect)));
            }
            if evac.is_empty() {
                return Ok((extra, ledger));
            }
            let eligible: Vec<usize> = (0..n)
                .filter(|&i| {
                    self.roles[i].accepts_fresh_prompts() && !self.shards[i].fault_crashed()
                })
                .collect();
            let total_fresh = (0..n).filter(|&i| self.roles[i].accepts_fresh_prompts()).count();
            let surviving_fraction = if total_fresh == 0 {
                0.0
            } else {
                eligible.len() as f64 / total_fresh as f64
            };
            let capacity_ok = surviving_fraction >= self.recovery.utilization_ceiling;
            let mut resubmitted = false;
            for (req, detect) in evac {
                let entry = ledger.entry(req.id).or_insert((0, req.arrival_ns as f64));
                entry.0 += 1;
                let (attempt, original_arrival_ns) = *entry;
                if eligible.is_empty() || attempt > self.recovery.retry_budget {
                    self.tally.failed += 1;
                    self.link_recorder.record(Event::instant(
                        EventKind::RequestFailed,
                        detect,
                        req.id,
                        attempt as f64,
                    ));
                    extra.push(terminal_result(&req, original_arrival_ns, detect, true));
                } else if !capacity_ok {
                    self.tally.degrade_shed += 1;
                    self.link_recorder.record(Event::instant(
                        EventKind::DegradeShed,
                        detect,
                        req.id,
                        surviving_fraction,
                    ));
                    extra.push(terminal_result(&req, original_arrival_ns, detect, false));
                } else {
                    let shard = eligible[rr % eligible.len()];
                    rr += 1;
                    self.tally.retries += 1;
                    self.link_recorder.record(Event::instant(
                        EventKind::FaultRequeue,
                        detect,
                        req.id,
                        attempt as f64,
                    ));
                    let mut r = req;
                    // The re-dispatch lands no earlier than the crash was
                    // detected (`ceil` keeps the release causal on the
                    // survivor's integer arrival clock).
                    r.arrival_ns = (r.arrival_ns as f64).max(detect).ceil() as u64;
                    self.shards[shard].submit(r);
                    resubmitted = true;
                }
            }
            if !resubmitted {
                return Ok((extra, ledger));
            }
            // Continuation wave: every shard resumes from its previous
            // makespan so its simulated clock never runs backwards.
            for i in 0..n {
                let floor = acc[i]
                    .as_ref()
                    .and_then(|r| r.shards.first())
                    .map_or(0.0, |s| s.sim_clock_ns);
                self.shards[i].set_clock_floor(floor);
            }
            self.run_wave(exec, acc)?;
        }
    }

    /// Fold one wave's per-worker counters into the run's accumulator
    /// (worker *w* of every wave lands in row *w* — the pool is rebuilt
    /// per wave, but row `w` always describes "the w-th worker thread").
    fn absorb_worker_stats(&mut self, stats: &[WorkerStats]) {
        if self.worker_stats.len() < stats.len() {
            self.worker_stats.resize(stats.len(), WorkerStats::default());
        }
        for (acc, s) in self.worker_stats.iter_mut().zip(stats) {
            acc.absorb(s);
        }
    }

    /// Per-worker host-side counters of the most recent
    /// [`Coordinator::run_to_completion`] (empty before the first run).
    pub fn worker_stats(&self) -> &[WorkerStats] {
        &self.worker_stats
    }

    /// The KV-link telemetry sink (wire spans + decode releases of a
    /// disaggregated run; empty events on a unified cluster).
    pub fn link_recorder(&self) -> &R {
        &self.link_recorder
    }

    /// Shard `i`'s telemetry sink (its simulated event stream after a
    /// recorded run).
    pub fn shard_recorder(&self, shard: usize) -> &R {
        self.shards[shard].recorder()
    }
}

/// Fold a continuation-wave report into a shard's accumulated report:
/// results concatenate, counters add, the simulated clock advances to the
/// newer makespan, and occupancy re-weights by decode iterations.
/// Throughput-style derived fields re-derive at the final
/// [`ServerReport::merge`].
fn absorb_report(acc: &mut ServerReport, next: ServerReport) {
    let ServerReport { results, total_tokens, shards, .. } = next;
    acc.results.extend(results);
    acc.total_tokens += total_tokens;
    let (Some(a), Some(b)) = (acc.shards.first_mut(), shards.first()) else {
        return;
    };
    if b.decode_iterations > 0 {
        let it_a = a.decode_iterations as f64;
        let it_b = b.decode_iterations as f64;
        a.occupancy = (a.occupancy * it_a + b.occupancy * it_b) / (it_a + it_b);
    }
    a.requests += b.requests;
    a.tokens += b.tokens;
    a.sim_ns += b.sim_ns;
    a.wall_ns += b.wall_ns;
    a.sim_clock_ns = a.sim_clock_ns.max(b.sim_clock_ns);
    a.sim_idle_ns += b.sim_idle_ns;
    a.decode_iterations += b.decode_iterations;
    a.prefill_chunks += b.prefill_chunks;
    a.chunk_stall_ns += b.chunk_stall_ns;
    a.preemptions += b.preemptions;
    a.shed += b.shed;
    a.handoffs += b.handoffs;
    a.kv_transfer_ns += b.kv_transfer_ns;
}

/// Synthesize the terminal result of a request the recovery loop could
/// not re-dispatch: `failed` (retry budget exhausted / no survivor) or
/// degradation-controller `shed`.  The request generated no tokens; its
/// timeline collapses onto the moment it was stranded.
fn terminal_result(req: &Request, original_arrival_ns: f64, at_ns: f64, failed: bool) -> RequestResult {
    RequestResult {
        id: req.id,
        tokens: Vec::new(),
        prompt_tokens: req.prompt.len(),
        sim_ttft_ns: 0.0,
        sim_total_ns: 0.0,
        wall_ns: 0.0,
        arrival_ns: original_arrival_ns,
        sim_first_token_at_ns: at_ns,
        sim_finish_at_ns: at_ns,
        deadline_ns: req.deadline_ns.map(|d| d as f64),
        shed: !failed,
        failed,
    }
}

#[cfg(test)]
mod tests {
    // The deprecated constructors stay under test: they are the
    // bit-for-bit oracle the ClusterBuilder equivalence tests compare
    // against, and they must keep working until they are removed.
    #![allow(deprecated)]
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;
    use crate::coordinator::scheduler::EdfScheduler;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn coordinator(n_shards: usize, max_batch: usize) -> Coordinator<SyntheticEngine> {
        Coordinator::new(&racam_paper(), tiny_spec(), n_shards, max_batch, |_| {
            SyntheticEngine::new(64, 128)
        })
    }

    fn submit_all(c: &mut Coordinator<SyntheticEngine>, n: u64, tokens: usize) {
        for id in 0..n {
            c.submit(Request::new(id, vec![id as u32 % 7, 3, 9], tokens));
        }
    }

    #[test]
    fn completes_all_requests_across_shards() {
        let mut c = coordinator(3, 2);
        submit_all(&mut c, 7, 5);
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 7);
        assert_eq!(report.total_tokens, 35);
        assert_eq!(report.shards.len(), 3);
        // Least-loaded dispatch spreads the work: every shard served some.
        assert!(report.shards.iter().all(|s| s.requests > 0));
        assert_eq!(report.shards.iter().map(|s| s.tokens).sum::<usize>(), 35);
        // Results are id-sorted after the merge.
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn shard_count_does_not_change_generation() {
        let run = |shards: usize| {
            let mut c = coordinator(shards, 2);
            submit_all(&mut c, 6, 8);
            c.run_to_completion()
                .unwrap()
                .results
                .into_iter()
                .map(|r| (r.id, r.tokens))
                .collect::<Vec<_>>()
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(4));
    }

    #[test]
    fn shards_share_one_mapping_cache() {
        // Acceptance: a shape repeated across shards misses exactly once.
        let service = MappingService::for_config(&racam_paper());
        let mut c = Coordinator::with_service(service.clone(), tiny_spec(), 3, 2, |_| {
            SyntheticEngine::new(64, 128)
        });
        // Identical prompt lengths everywhere → identical prefill + decode
        // shapes on every shard.
        for id in 0..6 {
            c.submit(Request::new(id, vec![1, 2, 3], 4));
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 6);
        // Every cached shape was searched exactly once system-wide.
        assert_eq!(c.service().misses(), c.service().cache_len() as u64);
        // And the other shards did hit the shared cache.
        assert!(c.service().hits() > 0);
    }

    #[test]
    fn single_shard_coordinator_matches_plain_server() {
        use crate::coordinator::Server;
        use crate::workloads::RacamSystem;

        let mut c = coordinator(1, 2);
        submit_all(&mut c, 3, 6);
        let merged = c.run_to_completion().unwrap();

        let mut s = Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
        );
        for id in 0..3 {
            s.submit(Request::new(id, vec![id as u32 % 7, 3, 9], 6));
        }
        let plain = s.run_to_completion().unwrap();
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&merged), tok(&plain));
    }

    #[test]
    fn channel_partition_prices_shards_against_their_own_share() {
        // 3 shards over 8 channels → [3, 3, 2]: shards 0 and 1 share one
        // mapping service, shard 2 holds its own (distinct hardware).
        let c = coordinator(3, 2);
        let svcs = c.services();
        assert_eq!(svcs.len(), 3);
        assert_eq!(svcs[0].hw().hw.dram.channels, 3);
        assert_eq!(svcs[1].hw().hw.dram.channels, 3);
        assert_eq!(svcs[2].hw().hw.dram.channels, 2);
        let agg: u64 = svcs.iter().map(|s| s.hw().hw.capacity_bytes()).sum();
        assert_eq!(agg, racam_paper().capacity_bytes());
    }

    #[test]
    fn partitioned_shards_never_price_below_the_full_device() {
        // Honest per-shard bandwidth: the intrinsic service cost of the
        // same request on a 2-channel shard can never undercut the full
        // 8-channel device (fewer resources ⇒ no faster mapping exists —
        // the 8-channel search space contains every 2-channel candidate's
        // performance point or better).
        let costs = |shards: usize| {
            let mut c = coordinator(shards, 1);
            submit_all(&mut c, 4, 4);
            let rep = c.run_to_completion().unwrap();
            rep.results.iter().map(|r| (r.id, r.sim_total_ns)).collect::<Vec<_>>()
        };
        let full = costs(1);
        let quartered = costs(4);
        for ((id, f), (id2, q)) in full.iter().zip(&quartered) {
            assert_eq!(id, id2);
            assert!(
                *q >= f * 0.999,
                "req {id}: 2-channel shard priced {q} below full device {f}"
            );
        }
    }

    #[test]
    fn async_admission_completes_requests_submitted_after_run_start() {
        // Acceptance: a request submitted after the run starts completes
        // and is reflected in the merged report.
        let mut c = coordinator(2, 2);
        submit_all(&mut c, 4, 6);
        let mut intake = c.intake();
        #[allow(clippy::disallowed_methods)] // test harness thread
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(25));
            assert!(intake.submit(Request::new(100, vec![5, 4, 3], 6)));
            assert!(intake.submit(Request::new(101, vec![2, 2], 3)));
            // intake drops here, closing the channels.
        });
        let report = c.run_to_completion().unwrap();
        submitter.join().unwrap();
        assert_eq!(report.results.len(), 6);
        let late: Vec<u64> =
            report.results.iter().filter(|r| r.id >= 100).map(|r| r.id).collect();
        assert_eq!(late, vec![100, 101]);
        assert_eq!(report.total_tokens, 4 * 6 + 6 + 3);
        // The late requests actually generated tokens.
        assert!(report.results.iter().find(|r| r.id == 100).unwrap().tokens.len() == 6);
    }

    #[test]
    fn intake_reports_closed_channels() {
        let mut c = coordinator(1, 1);
        let mut intake = c.intake();
        // Replacing the intake drops the old receiver.
        let _tx2 = c.intake();
        assert!(!intake.submit(Request::new(0, vec![1], 1)));
    }

    #[test]
    fn policy_threads_through_every_shard() {
        use crate::config::ServingPolicy;

        // Chunked prefill through the coordinator: same tokens as the
        // default whole-prefill schedule, and the merged report carries
        // per-shard chunk counts.
        let run = |policy: ServingPolicy| {
            let mut c = coordinator(2, 2).with_policy(policy);
            for id in 0..4 {
                c.submit(Request::new(id, vec![id as u32; 600], 3));
            }
            c.run_to_completion().unwrap()
        };
        let whole = run(ServingPolicy::whole_prefill());
        let chunked = run(ServingPolicy::chunked(256));
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&whole), tok(&chunked));
        let chunks = |rep: &ServerReport| rep.shards.iter().map(|s| s.prefill_chunks).sum::<usize>();
        // 600-token prompts: 1 step each whole, 3 chunks each at 256.
        assert_eq!(chunks(&whole), 4);
        assert_eq!(chunks(&chunked), 12);
    }

    #[test]
    fn coordinator_merges_shed_counts_across_shards() {
        use crate::config::ServingPolicy;
        use crate::coordinator::scheduler::EdfScheduler;

        let service = MappingService::for_config(&racam_paper());
        let mut c: Coordinator<SyntheticEngine, EdfScheduler> = Coordinator::with_schedulers(
            service,
            tiny_spec(),
            2,
            1,
            |_| SyntheticEngine::new(64, 128),
            |_| EdfScheduler::new(),
        )
        .with_policy(ServingPolicy::whole_prefill().with_preemption());
        assert!(c.policy().preempt);
        // Two of the four requests carry deadlines that expire almost
        // immediately; wherever least-loaded dispatch lands them, they are
        // shed and the merged report must account for all of them.
        for shard in 0..2u64 {
            c.submit(Request::new(shard * 2, vec![1; 32], 48).with_deadline(u64::MAX));
            c.submit(Request::new(shard * 2 + 1, vec![2; 32], 48).with_deadline(1));
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 4);
        let shed_total: usize = report.shards.iter().map(|s| s.shed).sum();
        assert_eq!(shed_total, 2);
        assert_eq!(report.results.iter().filter(|r| r.shed).count(), 2);
    }

    #[test]
    fn empty_fault_spec_keeps_the_fault_free_path_bit_identical() {
        let run = |faulted: bool| {
            let mut c = coordinator(2, 2);
            if faulted {
                c.set_faults(&FaultSpec::default()).unwrap();
            }
            submit_all(&mut c, 6, 5);
            c.run_to_completion().unwrap()
        };
        let baseline = run(false);
        let empty = run(true);
        assert_eq!(baseline.sim_divergence(&empty), None);
        assert!(empty.faults.is_empty());
    }

    #[test]
    fn shard_crash_requeues_inflight_requests_onto_survivors() {
        let mut c = coordinator(2, 2);
        let spec = FaultSpec {
            events: vec![FaultEvent::ShardCrash { shard: 0, at_ns: 0.0 }],
            ..FaultSpec::default()
        };
        c.set_faults(&spec).unwrap();
        submit_all(&mut c, 6, 5);
        let report = c.run_to_completion().unwrap();
        // Every request lands exactly once, none lost to the crash.
        assert_eq!(report.results.len(), 6);
        assert!(report.results.iter().all(|r| !r.shed && !r.failed));
        assert_eq!(report.total_tokens, 30);
        assert_eq!(report.faults.crashed_shards, 1);
        assert!(report.faults.retries > 0);
        assert_eq!(report.faults.failed, 0);
        // The capacity timeline records the crash: 1 of 2 shards left.
        assert_eq!(report.faults.capacity_timeline.len(), 1);
        assert_eq!(report.faults.capacity_timeline[0].2, 1);
        // Retried requests keep their original (zero) arrival.
        assert!(report.results.iter().all(|r| r.arrival_ns == 0.0));
    }

    #[test]
    fn crash_with_no_survivors_fails_requests() {
        let mut c = coordinator(1, 2);
        let spec = FaultSpec {
            events: vec![FaultEvent::ShardCrash { shard: 0, at_ns: 0.0 }],
            ..FaultSpec::default()
        };
        c.set_faults(&spec).unwrap();
        submit_all(&mut c, 4, 5);
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 4);
        assert!(report.results.iter().all(|r| r.failed && !r.shed));
        assert!(report.results.iter().all(|r| r.tokens.is_empty() && !r.met_deadline()));
        assert_eq!(report.faults.failed, 4);
        assert_eq!(report.faults.capacity_timeline[0].2, 0);
    }

    #[test]
    fn degradation_controller_sheds_when_capacity_falls_below_ceiling() {
        let mut c = coordinator(2, 2);
        let spec = FaultSpec {
            events: vec![FaultEvent::ShardCrash { shard: 0, at_ns: 0.0 }],
            recovery: crate::config::RecoveryPolicy {
                utilization_ceiling: 1.0,
                ..Default::default()
            },
            ..FaultSpec::default()
        };
        c.set_faults(&spec).unwrap();
        submit_all(&mut c, 6, 5);
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 6);
        // Half the capacity survived < ceiling 1.0: evacuees are shed, not
        // retried; the other shard's requests complete untouched.
        assert_eq!(report.faults.degrade_shed, 3);
        assert_eq!(report.faults.retries, 0);
        assert_eq!(report.results.iter().filter(|r| r.shed).count(), 3);
        assert_eq!(report.results.iter().filter(|r| !r.shed && !r.failed).count(), 3);
    }

    #[test]
    fn brownout_stretches_the_makespan_but_serves_everything() {
        let run = |spec: Option<FaultSpec>| {
            let mut c = coordinator(1, 2);
            if let Some(s) = spec {
                c.set_faults(&s).unwrap();
            }
            submit_all(&mut c, 4, 6);
            c.run_to_completion().unwrap()
        };
        let baseline = run(None);
        let slowed = run(Some(FaultSpec {
            events: vec![FaultEvent::Brownout {
                shard: 0,
                start_ns: 0.0,
                end_ns: 1e15,
                slowdown: 2.0,
            }],
            ..FaultSpec::default()
        }));
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&baseline), tok(&slowed));
        let clock = |rep: &ServerReport| rep.shards[0].sim_clock_ns;
        assert!(
            clock(&slowed) > clock(&baseline),
            "brownout must stretch the makespan: {} vs {}",
            clock(&slowed),
            clock(&baseline)
        );
    }

    #[test]
    fn link_outage_delays_kv_transfers_monotonically() {
        let disagg = |spec: Option<FaultSpec>| {
            let mut c =
                ClusterBuilder::new(ClusterSpec::disaggregated(1, 1, 2), &racam_paper(), tiny_spec())
                    .unwrap()
                    .build(|_| SyntheticEngine::new(64, 128));
            if let Some(s) = spec {
                c.set_faults(&s).unwrap();
            }
            for id in 0..4 {
                c.submit(Request::new(id, vec![id as u32 % 7, 3, 9], 4));
            }
            c.run_to_completion().unwrap()
        };
        let baseline = disagg(None);
        let outaged = disagg(Some(FaultSpec {
            events: vec![FaultEvent::LinkOutage { start_ns: 0.0, end_ns: 1e12 }],
            ..FaultSpec::default()
        }));
        assert_eq!(baseline.results.len(), 4);
        assert_eq!(outaged.results.len(), 4);
        assert!(outaged.results.iter().all(|r| !r.failed));
        let kv = |rep: &ServerReport| {
            rep.shards.iter().map(|s| s.kv_transfer_ns).fold(0.0, f64::max)
        };
        assert!(
            kv(&outaged) > kv(&baseline),
            "an outage window must delay KV transfers: {} vs {}",
            kv(&outaged),
            kv(&baseline)
        );
    }

    #[test]
    fn channel_loss_reprices_the_group_and_slows_it_down() {
        let run = |spec: Option<FaultSpec>| {
            let mut c = coordinator(1, 2);
            if let Some(s) = spec {
                c.set_faults(&s).unwrap();
            }
            submit_all(&mut c, 4, 6);
            c.run_to_completion().unwrap()
        };
        let baseline = run(None);
        let derated = run(Some(FaultSpec {
            events: vec![FaultEvent::ChannelLoss {
                group: "unified".into(),
                at_ns: 0.0,
                channels_lost: 6,
            }],
            ..FaultSpec::default()
        }));
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&baseline), tok(&derated));
        // 2 of 8 channels left: the same work cannot get cheaper.
        assert!(derated.shards[0].sim_clock_ns >= baseline.shards[0].sim_clock_ns);
    }

    #[test]
    fn fault_spec_rejects_unknown_shards_and_groups() {
        let mut c = coordinator(2, 2);
        let bad_shard = FaultSpec {
            events: vec![FaultEvent::ShardCrash { shard: 9, at_ns: 0.0 }],
            ..FaultSpec::default()
        };
        assert!(c.set_faults(&bad_shard).is_err());
        let bad_group = FaultSpec {
            events: vec![FaultEvent::ChannelLoss {
                group: "nope".into(),
                at_ns: 0.0,
                channels_lost: 1,
            }],
            ..FaultSpec::default()
        };
        assert!(c.set_faults(&bad_group).is_err());
    }

    #[test]
    fn faulted_runs_are_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let mut c = coordinator(3, 2);
            c.set_threads(threads);
            c.set_faults(&FaultSpec {
                events: vec![
                    FaultEvent::ShardCrash { shard: 1, at_ns: 0.0 },
                    FaultEvent::Brownout {
                        shard: 0,
                        start_ns: 0.0,
                        end_ns: 1e15,
                        slowdown: 1.5,
                    },
                ],
                ..FaultSpec::default()
            })
            .unwrap();
            submit_all(&mut c, 9, 4);
            c.run_to_completion().unwrap()
        };
        let one = run(1);
        let two = run(2);
        let four = run(4);
        assert_eq!(one.sim_divergence(&two), None);
        assert_eq!(one.sim_divergence(&four), None);
        assert_eq!(one.faults.crashed_shards, 1);
    }

    #[test]
    fn coordinator_with_custom_scheduler_serves_all() {
        let service = MappingService::for_config(&racam_paper());
        let mut c: Coordinator<SyntheticEngine, EdfScheduler> = Coordinator::with_schedulers(
            service,
            tiny_spec(),
            2,
            2,
            |_| SyntheticEngine::new(64, 128),
            |_| EdfScheduler::new(),
        );
        for id in 0..5 {
            c.submit(Request::new(id, vec![1, 2], 3).with_deadline(1_000_000 * (5 - id)));
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.total_tokens, 15);
    }
}
