//! Multi-worker serving coordinator: N worker shards, each running the
//! continuous-batched decode loop of [`Server`], all pricing against one
//! shared [`MappingService`].
//!
//! The coordinator is the ROADMAP "sharding" step: requests are dispatched
//! deterministically to the least-loaded shard, shards run concurrently on
//! OS threads, and the per-shard reports merge into a single
//! [`ServerReport`] with per-shard utilization.  Because the mapping cache
//! is shared, a kernel shape that appears on every shard is searched once
//! system-wide — the first shard to ask runs the (parallel) search, the
//! rest wait on the per-shape once-cell and reuse it.

use super::engine::TokenEngine;
use super::server::{Request, Server, ServerReport};
use crate::config::{HwConfig, LlmSpec};
use crate::mapping::MappingService;
use crate::workloads::RacamSystem;
use crate::Result;
use std::time::Instant;

/// N-shard serving coordinator (see module docs).
pub struct Coordinator<E: TokenEngine> {
    shards: Vec<Server<E>>,
    service: MappingService,
}

impl<E: TokenEngine + Send> Coordinator<E> {
    /// Build a coordinator with a fresh mapping service over `hw`.
    /// `engine_factory` is called once per shard (shard index passed in) —
    /// token engines hold mutable generation state, so each worker needs
    /// its own.
    pub fn new(
        hw: &HwConfig,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        engine_factory: impl FnMut(usize) -> E,
    ) -> Self {
        let service = MappingService::for_config(hw);
        Self::with_service(service, spec, n_shards, max_batch, engine_factory)
    }

    /// Build a coordinator over an existing (possibly pre-warmed, possibly
    /// externally shared) mapping service.
    pub fn with_service(
        service: MappingService,
        spec: LlmSpec,
        n_shards: usize,
        max_batch: usize,
        mut engine_factory: impl FnMut(usize) -> E,
    ) -> Self {
        assert!(n_shards >= 1, "a coordinator needs at least one shard");
        let shards = (0..n_shards)
            .map(|i| {
                let mut server = Server::new(
                    engine_factory(i),
                    RacamSystem::with_service(service.clone()),
                    spec.clone(),
                    max_batch,
                );
                server.set_shard(i);
                server
            })
            .collect();
        Coordinator { shards, service }
    }

    /// The shared mapping service (cache counters, warm-start/persist).
    pub fn service(&self) -> &MappingService {
        &self.service
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Requests waiting for admission across all shards.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.pending()).sum()
    }

    /// Dispatch a request to the least-loaded shard (lowest index wins
    /// ties), which is deterministic for a given submission order.
    pub fn submit(&mut self, req: Request) {
        let shard = (0..self.shards.len())
            .min_by_key(|&i| self.shards[i].pending())
            .expect("at least one shard");
        self.shards[shard].submit(req);
    }

    /// Run every shard's serving loop to completion on its own thread and
    /// merge the reports.  Token sequences are engine-deterministic per
    /// request, so the merged output is independent of thread interleaving.
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        let wall_start = Instant::now();
        let mut reports: Vec<Result<ServerReport>> = Vec::with_capacity(self.shards.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter_mut()
                .map(|shard| scope.spawn(move || shard.run_to_completion()))
                .collect();
            for h in handles {
                reports.push(h.join().expect("worker shard panicked"));
            }
        });
        let mut merged = Vec::with_capacity(reports.len());
        for r in reports {
            merged.push(r?);
        }
        Ok(ServerReport::merge(merged, wall_start.elapsed().as_nanos() as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn coordinator(n_shards: usize, max_batch: usize) -> Coordinator<SyntheticEngine> {
        Coordinator::new(&racam_paper(), tiny_spec(), n_shards, max_batch, |_| {
            SyntheticEngine::new(64, 128)
        })
    }

    fn submit_all(c: &mut Coordinator<SyntheticEngine>, n: u64, tokens: usize) {
        for id in 0..n {
            c.submit(Request { id, prompt: vec![id as u32 % 7, 3, 9], max_new_tokens: tokens });
        }
    }

    #[test]
    fn completes_all_requests_across_shards() {
        let mut c = coordinator(3, 2);
        submit_all(&mut c, 7, 5);
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 7);
        assert_eq!(report.total_tokens, 35);
        assert_eq!(report.shards.len(), 3);
        // Least-loaded dispatch spreads the work: every shard served some.
        assert!(report.shards.iter().all(|s| s.requests > 0));
        assert_eq!(report.shards.iter().map(|s| s.tokens).sum::<usize>(), 35);
        // Results are id-sorted after the merge.
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn shard_count_does_not_change_generation() {
        let run = |shards: usize| {
            let mut c = coordinator(shards, 2);
            submit_all(&mut c, 6, 8);
            c.run_to_completion()
                .unwrap()
                .results
                .into_iter()
                .map(|r| (r.id, r.tokens))
                .collect::<Vec<_>>()
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(4));
    }

    #[test]
    fn shards_share_one_mapping_cache() {
        // Acceptance: a shape repeated across shards misses exactly once.
        let service = MappingService::for_config(&racam_paper());
        let mut c = Coordinator::with_service(service.clone(), tiny_spec(), 3, 2, |_| {
            SyntheticEngine::new(64, 128)
        });
        // Identical prompt lengths everywhere → identical prefill + decode
        // shapes on every shard.
        for id in 0..6 {
            c.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 4 });
        }
        let report = c.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 6);
        // Every cached shape was searched exactly once system-wide.
        assert_eq!(c.service().misses(), c.service().cache_len() as u64);
        // And the other shards did hit the shared cache.
        assert!(c.service().hits() > 0);
    }

    #[test]
    fn single_shard_coordinator_matches_plain_server() {
        use crate::coordinator::Server;
        use crate::workloads::RacamSystem;

        let mut c = coordinator(1, 2);
        submit_all(&mut c, 3, 6);
        let merged = c.run_to_completion().unwrap();

        let mut s = Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            2,
        );
        for id in 0..3 {
            s.submit(Request { id, prompt: vec![id as u32 % 7, 3, 9], max_new_tokens: 6 });
        }
        let plain = s.run_to_completion().unwrap();
        let tok = |rep: &ServerReport| {
            rep.results.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
        };
        assert_eq!(tok(&merged), tok(&plain));
    }
}
