//! The serving loop: continuous-batched greedy decoding through a token
//! engine, with per-token RACAM latency accounting from the mapping engine
//! (the simulated-hardware clock) next to the host wall clock.

use super::batcher::FcfsBatcher;
use super::engine::TokenEngine;
use crate::config::LlmSpec;
use crate::metrics::LatencyBreakdown;
use crate::workloads::{decode_kernels, prefill_kernels, stage_latency, RacamSystem};
use crate::Result;
use std::collections::HashMap;
use std::time::Instant;

/// An inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

/// Completed request with its generation and accounting.
#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Simulated RACAM time to first token (prefill), ns.
    pub sim_ttft_ns: f64,
    /// Simulated RACAM end-to-end latency, ns.
    pub sim_total_ns: f64,
    /// Host wall-clock spent executing this request's share, ns.
    pub wall_ns: f64,
}

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub results: Vec<RequestResult>,
    pub sim_tokens_per_s: f64,
    pub wall_tokens_per_s: f64,
    pub total_tokens: usize,
}

/// The coordinator server.
pub struct Server<E: TokenEngine> {
    engine: E,
    racam: RacamSystem,
    spec: LlmSpec,
    batcher: FcfsBatcher,
}

struct Running {
    req: Request,
    hidden: Vec<f32>,
    tokens: Vec<u32>,
    sim_ns: f64,
    sim_ttft_ns: f64,
    wall_ns: f64,
}

impl<E: TokenEngine> Server<E> {
    /// `spec` names the LLM whose kernel shapes the RACAM clock prices
    /// (the toy engine generates real tokens; the simulator accounts what
    /// the full-size model would cost on RACAM hardware).
    pub fn new(engine: E, racam: RacamSystem, spec: LlmSpec, max_batch: usize) -> Self {
        Server { engine, racam, spec, batcher: FcfsBatcher::new(max_batch) }
    }

    pub fn submit(&mut self, req: Request) {
        self.batcher.submit(req);
    }

    /// Access the simulated-hardware pipeline (e.g. to persist its mapping
    /// cache after a run, §7 amortization).
    pub fn racam(&self) -> &RacamSystem {
        &self.racam
    }

    /// Drain all submitted requests to completion.
    pub fn run_to_completion(&mut self) -> Result<ServerReport> {
        let mut running: Vec<Running> = Vec::new();
        let mut done: Vec<RequestResult> = Vec::new();
        let wall_start = Instant::now();
        let mut decode_cache: HashMap<u64, LatencyBreakdown> = HashMap::new();

        loop {
            // Admit new work (continuous batching).
            for req in self.batcher.admit(running.len()) {
                let t0 = Instant::now();
                let hidden = self.engine.embed_prompt(&req.prompt);
                // Simulated prefill cost for this prompt length.
                let prefill =
                    stage_latency(&mut self.racam, &prefill_kernels(&self.spec, req.prompt.len() as u64));
                running.push(Running {
                    hidden,
                    tokens: Vec::new(),
                    sim_ns: prefill.total_ns(),
                    sim_ttft_ns: prefill.total_ns(),
                    wall_ns: t0.elapsed().as_nanos() as f64,
                    req,
                });
            }
            if running.is_empty() {
                break;
            }

            // One decode iteration across the batch.
            for r in &mut running {
                let t0 = Instant::now();
                let (mut next, token) = self.engine.step(&r.hidden)?;
                self.engine.feed_token(&mut next, token);
                r.hidden = next;
                r.tokens.push(token);
                r.wall_ns += t0.elapsed().as_nanos() as f64;

                let ctx = r.req.prompt.len() as u64 + r.tokens.len() as u64;
                // Simulated per-token decode cost (cached per context
                // bucket of 256 to bound search work).
                let bucket = ctx.div_ceil(256) * 256;
                let spec = &self.spec;
                let racam = &mut self.racam;
                let per_token = decode_cache
                    .entry(bucket)
                    .or_insert_with(|| stage_latency(racam, &decode_kernels(spec, bucket)));
                r.sim_ns += per_token.total_ns();
            }

            // Retire finished requests.
            let mut i = 0;
            while i < running.len() {
                if running[i].tokens.len() >= running[i].req.max_new_tokens {
                    let r = running.swap_remove(i);
                    done.push(RequestResult {
                        id: r.req.id,
                        tokens: r.tokens,
                        sim_ttft_ns: r.sim_ttft_ns,
                        sim_total_ns: r.sim_ns,
                        wall_ns: r.wall_ns,
                    });
                } else {
                    i += 1;
                }
            }
        }

        done.sort_by_key(|r| r.id);
        let total_tokens: usize = done.iter().map(|r| r.tokens.len()).sum();
        let sim_ns: f64 = done.iter().map(|r| r.sim_total_ns).sum();
        let wall_ns = wall_start.elapsed().as_nanos() as f64;
        Ok(ServerReport {
            sim_tokens_per_s: total_tokens as f64 / (sim_ns / 1e9).max(f64::MIN_POSITIVE),
            wall_tokens_per_s: total_tokens as f64 / (wall_ns / 1e9).max(f64::MIN_POSITIVE),
            total_tokens,
            results: done,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{racam_paper, LlmSpec, Precision};
    use crate::coordinator::engine::SyntheticEngine;

    fn tiny_spec() -> LlmSpec {
        LlmSpec {
            name: "tiny".into(),
            layers: 2,
            hidden: 256,
            heads: 4,
            kv_heads: 4,
            ffn: 512,
            gated_ffn: false,
            vocab: 512,
            prec: Precision::Int8,
        }
    }

    fn server(max_batch: usize) -> Server<SyntheticEngine> {
        Server::new(
            SyntheticEngine::new(64, 128),
            RacamSystem::new(&racam_paper()),
            tiny_spec(),
            max_batch,
        )
    }

    #[test]
    fn serves_all_requests() {
        let mut s = server(2);
        for id in 0..5 {
            s.submit(Request { id, prompt: vec![id as u32, 7], max_new_tokens: 6 });
        }
        let report = s.run_to_completion().unwrap();
        assert_eq!(report.results.len(), 5);
        assert_eq!(report.total_tokens, 30);
        for r in &report.results {
            assert_eq!(r.tokens.len(), 6);
            assert!(r.sim_ttft_ns > 0.0);
            assert!(r.sim_total_ns > r.sim_ttft_ns);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let run = |batch| {
            let mut s = server(batch);
            s.submit(Request { id: 0, prompt: vec![3, 1, 4], max_new_tokens: 8 });
            s.run_to_completion().unwrap().results[0].tokens.clone()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn longer_prompts_cost_more_simulated_prefill() {
        let mut s = server(1);
        s.submit(Request { id: 0, prompt: vec![1; 4], max_new_tokens: 1 });
        s.submit(Request { id: 1, prompt: vec![1; 512], max_new_tokens: 1 });
        let rep = s.run_to_completion().unwrap();
        assert!(rep.results[1].sim_ttft_ns > rep.results[0].sim_ttft_ns);
    }

    #[test]
    fn empty_server_reports_zero() {
        let mut s = server(1);
        let rep = s.run_to_completion().unwrap();
        assert_eq!(rep.total_tokens, 0);
        assert!(rep.results.is_empty());
    }
}
